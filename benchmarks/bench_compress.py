"""Dictionary-compression benchmark: GraphZip path vs plain commits.

Runs the two most dictionary-friendly registry scenarios
(`celebrity_cascade`: Hawkes-amplified copy cascades; `spam_storm`:
near-duplicate flood) through the closed-loop harness with the
dictionary-compression path off and on, and reports the paper-facing
deltas: compression ratio (Fig. 13 accounting with references),
dictionary hit rate, mean commit latency and dropped inserts.

Each (scenario, mode) cell is run TWICE with the same seed and only
the second run is reported: jit caches are process-wide, so the first
run absorbs all compile time and the second measures the steady-state
commit path — otherwise the compressed path (which adds kernels) would
be charged for its own compilation.

Rows land in BENCH_ingest.json via ``benchmarks.run --json`` (the
bench is in TRAJECTORY_BENCHES), so the ratio/latency trajectory is
tracked PR over PR.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.workloads import run_scenario

SCENARIOS = ("celebrity_cascade", "spam_storm")
TICKS = 80
NODE_CAP = 1 << 12
EDGE_CAP = 1 << 14
DICT_CAPACITY = 4096


def _cell(name: str, dict_compress: bool) -> Dict:
    rep = None
    for _ in range(2):  # warm run then measured run (module docstring)
        rep = run_scenario(
            name, ticks=TICKS, seed=3, speed=0.5,
            dict_compress=dict_compress, dict_capacity=DICT_CAPACITY,
            node_cap=NODE_CAP, edge_cap=EDGE_CAP,
            spill_dir=f"/tmp/repro_bench_compress_{name}_{int(dict_compress)}")
    return {
        "scenario": name,
        "dict_compress": dict_compress,
        "records": rep.total_records,
        "mean_compression": round(rep.mean_compression, 4),
        "dict_hit_rate": round(rep.dict_hit_rate, 4),
        "pattern_refs": rep.pattern_refs,
        "commit_ms_mean": round(rep.commit_ms_mean, 3),
        "dropped_inserts": rep.dropped_inserts,
        "instructions": rep.total_instructions,
        "store_edges": rep.store_edges,
    }


def bench_compress_dictionary() -> Tuple[List[Dict], Dict]:
    rows = []
    for name in SCENARIOS:
        rows.append(_cell(name, False))
        rows.append(_cell(name, True))
    derived: Dict = {}
    for name in SCENARIOS:
        off = next(r for r in rows if r["scenario"] == name
                   and not r["dict_compress"])
        on = next(r for r in rows if r["scenario"] == name
                  and r["dict_compress"])
        derived[name] = {
            "compression_ratio": on["mean_compression"],
            "ratio_delta": round(
                on["mean_compression"] - off["mean_compression"], 4),
            "dict_hit_rate": on["dict_hit_rate"],
            "pattern_refs": on["pattern_refs"],
            "commit_ms_delta": round(
                on["commit_ms_mean"] - off["commit_ms_mean"], 3),
            "dropped_delta": on["dropped_inserts"] - off["dropped_inserts"],
            # acceptance: ratio < 1 and (faster commits OR strictly
            # fewer drops at no-worse latency)
            "compresses": on["mean_compression"] < 1.0,
            "wins": bool(
                on["commit_ms_mean"] < off["commit_ms_mean"]
                or (on["dropped_inserts"] < off["dropped_inserts"]
                    and on["commit_ms_mean"] <= off["commit_ms_mean"])),
        }
    return rows, derived
