"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads results/dryrun/*.json (written by repro.launch.sweep) and derives

  compute    = flops_per_device / peak_flops          [s]
  memory     = bytes_per_device / hbm_bw              [s]
  collective = collective_bytes_per_device / link_bw  [s]

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.

Methodology notes (see EXPERIMENTS.md §Roofline):
  * flops/bytes are loop-expanded from the compiled HLO
    (repro.launch.hlo_analysis) because XLA's cost_analysis counts scan
    bodies once.
  * bytes follow XLA's operands+outputs convention on the optimised
    (fused) HLO.  The CPU backend materialises layout transposes a TPU
    would fold into the MXU; `memory_adj` excludes transpose/copy
    fusions and is the TPU-realistic lower estimate (both reported).
  * MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per trained token;
    decode/prefill use 2*N*D per generated/ingested token.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

CPU_LAYOUT_KINDS = ("fusion:transpose", "copy", "transpose")


def load_cells(outdir: str = "results/dryrun") -> List[dict]:
    cells = []
    for fn in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def model_flops_per_device(cell: dict) -> float:
    """6ND train / 2ND inference, per device."""
    from repro.configs.base import SHAPES

    shape = SHAPES[cell["shape"]]
    tokens = shape.global_batch * (shape.seq_len if cell["kind"] != "decode" else 1)
    n = cell["params_active"]
    mult = 6 if cell["kind"] == "train" else 2
    return mult * n * tokens / cell["n_chips"]


def derive(cell: dict) -> Optional[dict]:
    if cell.get("skipped"):
        return None
    flops = cell["flops_per_device"]
    bytes_ = cell["bytes_per_device"]
    adj = bytes_ - sum(
        v for k, v in cell.get("bytes_detail", {}).items() if k in CPU_LAYOUT_KINDS
    )
    coll = cell["collective_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_m_adj = adj / HBM_BW
    t_l = coll / LINK_BW
    dominant = max((t_c, "compute"), (t_m_adj, "memory"), (t_l, "collective"))[1]
    mf = model_flops_per_device(cell)
    bound = max(t_c, t_m_adj, t_l)
    # flash-kernel projection: the Pallas attention kernel keeps the S^2
    # softmax chain in VMEM on the TPU target (repro.kernels.flash_attention,
    # validated vs oracle in tests); HBM traffic loses that chain
    chain = cell.get("attn_chain_bytes_per_device", 0.0)
    t_m_kern = max(adj - chain, 0.0) / HBM_BW
    bound_kern = max(t_c, t_m_kern, t_l)
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": "2x16x16" if cell["multi_pod"] else "16x16",
        "compute_s": t_c,
        "memory_s": t_m,
        "memory_adj_s": t_m_adj,
        "memory_kern_s": t_m_kern,
        "collective_s": t_l,
        "dominant": dominant,
        "model_flops_ratio": mf / flops if flops else 0.0,
        "roofline_frac": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "roofline_frac_kern": (mf / PEAK_FLOPS) / bound_kern if bound_kern else 0.0,
        "hbm_gb": (cell["memory"]["argument_size_in_bytes"]
                   + cell["memory"]["temp_size_in_bytes"]
                   - cell["memory"].get("alias_size_in_bytes", 0)) / 2**30,
    }


def table(cells: List[dict], mesh: Optional[str] = "16x16") -> str:
    rows = []
    hdr = ("| arch | shape | mesh | compute s | memory s (adj / kern) | collective s | "
           "dominant | 6ND/HLO | frac | frac(kern) | HBM GiB/dev |")
    sep = "|" + "---|" * 11
    rows.append(hdr)
    rows.append(sep)
    for c in cells:
        if c.get("skipped"):
            if mesh is None or (not c["multi_pod"]) == (mesh == "16x16"):
                rows.append(
                    f"| {c['arch']} | {c['shape']} | - | - | - | - | SKIP | - | - | - | - |"
                )
            continue
        d = derive(c)
        if mesh is not None and d["mesh"] != mesh:
            continue
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['compute_s']:.3f} | "
            f"{d['memory_s']:.2f} ({d['memory_adj_s']:.2f} / {d['memory_kern_s']:.2f}) | "
            f"{d['collective_s']:.3f} | "
            f"{d['dominant']} | {d['model_flops_ratio']:.2f} | "
            f"{d['roofline_frac']:.2%} | {d['roofline_frac_kern']:.2%} | {d['hbm_gb']:.1f} |"
        )
    return "\n".join(rows)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16", "all"])
    args = ap.parse_args()
    cells = load_cells(args.outdir)
    if not cells:
        print("no dry-run results found; run: python -m repro.launch.sweep")
        return 1
    print(table(cells, None if args.mesh == "all" else args.mesh))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
