"""Scenario-family benchmarks: one row per registered workload.

Runs every registry scenario through the closed-loop harness at CI
size (short ticks, small store) and reports the numbers the perf
trajectory tracks per scenario: sustained throughput, spill/drop
counts, buffer-mode transitions and table-pressure throttles.  The
rows land in BENCH_ingest.json via `benchmarks.run --json`, so the
trajectory records how each adversarial stream fares PR over PR.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.workloads import list_scenarios, run_scenario

# CI-sized run: enough ticks for every burst mechanism to engage
# (flash steps fire by t=45) without dominating the bench suite
TICKS = 80
NODE_CAP = 1 << 12
EDGE_CAP = 1 << 14


def bench_scenarios() -> Tuple[List[Dict], Dict]:
    rows = []
    for scn in list_scenarios():
        rep = run_scenario(
            scn.name, ticks=TICKS, seed=3, speed=0.5,
            node_cap=NODE_CAP, edge_cap=EDGE_CAP,
            spill_dir=f"/tmp/repro_bench_workload_{scn.name}")
        rows.append({
            "scenario": scn.name,
            "records": rep.total_records,
            "records_per_stream_s": round(rep.records_per_stream_s, 1),
            "records_per_wall_s": round(rep.records_per_wall_s, 1),
            "mean_compression": round(rep.mean_compression, 3),
            "mu_mean": round(rep.mu_mean, 3),
            "mu_p95": round(rep.mu_p95, 3),
            "spills": rep.spill_events,
            "drains": rep.drain_events,
            "dropped_inserts": rep.dropped_inserts,
            "pressure_throttles": rep.pressure_throttles,
            "transitions": rep.n_transitions,
            "actions": dict(sorted(rep.action_counts.items())),
        })
    bursty = [r for r in rows if r["scenario"] != "steady_state"]
    derived = {
        "scenarios": len(rows),
        "total_records": sum(r["records"] for r in rows),
        "bursty_scenarios_transitioned": sum(
            1 for r in bursty if r["transitions"] > 0),
        "max_records_per_stream_s": max(
            (r["records_per_stream_s"] for r in rows), default=0.0),
    }
    return rows, derived
