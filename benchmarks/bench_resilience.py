"""Resilience benchmark: checkpoint cost, recovery time, retry storm.

Three numbers the fault-tolerance layer (repro.resilience) is judged
on, recorded into BENCH_ingest.json's perf trajectory:

  * checkpoint save/restore latency on a CI-sized flash_crowd pipeline
    (blocking save, so the number is the full capture+write cost —
    the background path hides most of it from the tick loop);
  * recovery: kill mid-run, restore the latest checkpoint, and time
    restore->first successful commit (the paper's ingestion pipeline
    must come back fast after a collector dies);
  * retry storm: throughput of `retry_archive` replaying a backlog of
    archived batches once the store connection returns.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from typing import Dict, List, Tuple

TICKS = 48
CRASH_AT = 24
EVERY = 8
NODE_CAP = 1 << 12
EDGE_CAP = 1 << 14


def bench_resilience() -> Tuple[List[Dict], Dict]:
    from repro.resilience import (
        FaultPlan, PipelineCheckpointer, PipelineKilled, RetryPolicy)
    from repro.workloads import run_scenario

    work = tempfile.mkdtemp(prefix="repro_bench_resil_")
    kw = dict(ticks=TICKS, seed=3, node_cap=NODE_CAP, edge_cap=EDGE_CAP,
              retry=RetryPolicy(jitter=0.0), checkpoint_every=EVERY)

    # ---- checkpoint save/restore latency (blocking, after a warm run)
    from repro.api import PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig
    from repro.workloads.source import ScenarioSource

    src = ScenarioSource("flash_crowd", seed=3)
    pipe = (PipelineBuilder(IngestConfig(store_nodes=NODE_CAP,
                                         store_edges=EDGE_CAP))
            .with_source(src)
            .simulated_consumer(speed=0.5)
            .spill_dir(f"{work}/spill_lat")
            .build())
    pipe.run(max_ticks=24)
    ck = PipelineCheckpointer(f"{work}/ck_lat", every=EVERY)
    t0 = time.perf_counter()
    ck.save(24, pipe, src, blocking=True)
    save_s = time.perf_counter() - t0
    src2 = ScenarioSource("flash_crowd", seed=3)
    pipe2 = (PipelineBuilder(IngestConfig(store_nodes=NODE_CAP,
                                          store_edges=EDGE_CAP))
             .with_source(src2)
             .simulated_consumer(speed=0.5)
             .spill_dir(f"{work}/spill_lat2")
             .build())
    t0 = time.perf_counter()
    ck.restore(pipe2, src2)
    restore_s = time.perf_counter() - t0

    # ---- recovery time: kill mid-run, resume, first commit ----------
    plan = FaultPlan(crash_at_tick=CRASH_AT)
    try:
        run_scenario("flash_crowd", fault_plan=plan,
                     checkpoint_dir=f"{work}/ck_rec",
                     spill_dir=f"{work}/spill_rec", **kw)
    except PipelineKilled:
        pass
    t0 = time.perf_counter()
    rec = run_scenario("flash_crowd", fault_plan=plan.without_crash(),
                       checkpoint_dir=f"{work}/ck_rec", resume=True,
                       spill_dir=f"{work}/spill_rec", **kw)
    recover_s = time.perf_counter() - t0

    # ---- retry storm: replay an archived backlog in one drain -------
    from repro.core.edge_table import from_raw_batch
    from repro.core.ingestor import GraphIngestor
    from repro.core.transform import create_edges, tweet_mapping
    from repro.graphstore.store import init_store

    state = {"down": True}
    ing = GraphIngestor(init_store(NODE_CAP, EDGE_CAP),
                        fail_hook=lambda: state["down"],
                        retry_policy=RetryPolicy(jitter=0.0),
                        max_archive=16, archive_dir=f"{work}/arch",
                        degrade_after=1)
    backlog = 32
    for i in range(backlog):
        recs = [{"id": f"b{i}_{j}", "user": f"u{i}_{j}", "hashtags": ["x"],
                 "mentions": []} for j in range(8)]
        et = from_raw_batch(create_edges(recs, tweet_mapping()), 64)
        ing.push(et, now=1e6 * i)  # gate always open: probe + archive
    state["down"] = False
    t0 = time.perf_counter()
    replayed = ing.retry_archive(now=1e12)
    storm_s = time.perf_counter() - t0

    shutil.rmtree(work, ignore_errors=True)

    rows = [{
        "us_per_call": round(save_s * 1e6, 1),  # headline: save latency
        "checkpoint_save_ms": round(save_s * 1e3, 2),
        "checkpoint_restore_ms": round(restore_s * 1e3, 2),
        "recover_to_done_s": round(recover_s, 3),
        "resumed_from_tick": rec.resumed_from_tick,
        "retry_storm_batches": replayed,
        "retry_storm_batches_per_s": round(replayed / max(storm_s, 1e-9), 1),
        "archive_spilled_to_disk": backlog > 16,
    }]
    derived = {
        "checkpoint_save_ms": rows[0]["checkpoint_save_ms"],
        "checkpoint_restore_ms": rows[0]["checkpoint_restore_ms"],
        "recover_to_done_s": rows[0]["recover_to_done_s"],
        "retry_storm_batches_per_s": rows[0]["retry_storm_batches_per_s"],
        "no_batch_lost": ing.archived_total
        == ing.replayed + ing.archive_depth,
        "resume_digest_nonempty": bool(rec.store_digest),
    }
    return rows, derived
