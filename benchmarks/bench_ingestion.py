"""Paper-experiment benchmarks: one function per table/figure.

  Figs 1-3, 7  -> bench_uncontrolled()   (meltdown baseline)
  Fig 12       -> bench_controlled()     (bounded CPU at cpu_max 35%/55%)
  Fig 13       -> bench_compression()    (ratio vs buffer, burst effect)
  Table I/Fig11-> bench_prediction()     (model zoo fits, MAE/MSE/RMSE)
  Fig 14       -> bench_ingestor_node()  (pipeline-side health + throughput)

Each returns (rows, derived) where rows are CSV-able dicts.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.api import PipelineBuilder
from repro.configs.paper_ingest import IngestConfig
from repro.core import predictor as P
from repro.ingest.sources import BurstyTweetSource


def _run(uncontrolled: bool, compress: bool, cpu_max: float = 0.55,
         ticks: int = 250, seed: int = 3, speed: float = 1.0):
    pipe = (
        PipelineBuilder(IngestConfig(cpu_max=cpu_max))
        .with_source(BurstyTweetSource(seed=seed))
        .uncontrolled(uncontrolled)
        .compressed(compress)
        .simulated_consumer(speed=speed)
        .spill_dir(f"/tmp/repro_bench_{uncontrolled}_{compress}_{cpu_max}")
        .build()
    )
    t0 = time.perf_counter()
    rep = pipe.run(max_ticks=ticks)
    dt = time.perf_counter() - t0
    return rep, pipe, dt


def bench_uncontrolled() -> Tuple[List[Dict], Dict]:
    """Figs 1-3 & 7: direct ingestion melts the consumer down."""
    rep, pipe, dt = _run(uncontrolled=True, compress=False, speed=0.5)
    mu = rep.samples["mu"]
    d = {
        "mu_mean": float(mu.mean()),
        "mu_max": float(mu.max()),
        "pinned_frac": float((mu > 0.95).mean()),
        "delay_max_s": float(rep.samples["delay_s"].max()),
        "records": rep.total_records,
    }
    return [d], d


def bench_controlled() -> Tuple[List[Dict], Dict]:
    """Fig 12: CPU bounded at cpu_max = 0.35 and 0.55."""
    rows = []
    for cpu_max in (0.35, 0.55):
        rep, pipe, dt = _run(uncontrolled=False, compress=True,
                             cpu_max=cpu_max, speed=0.5)
        mu = rep.samples["mu"]
        # fraction of samples above the bound + epsilon (control quality)
        viol = float((mu > cpu_max + 0.15).mean())
        rows.append({
            "cpu_max": cpu_max,
            "mu_mean": float(mu.mean()),
            "mu_p95": float(np.percentile(mu, 95)),
            "mu_max": float(mu.max()),
            "violation_frac": viol,
            "spills": rep.spill_events,
            "drains": rep.drain_events,
            "delay_max_s": float(rep.samples["delay_s"].max()),
        })
    derived = {"bounded": all(r["violation_frac"] < 0.1 for r in rows)}
    return rows, derived


def bench_compression() -> Tuple[List[Dict], Dict]:
    """Fig 13: compression ratio vs effective buffer size; burst effect."""
    rep, pipe, dt = _run(uncontrolled=False, compress=True, ticks=300)
    crs = rep.compression_ratios
    beta_e = rep.samples["beta_e"][: len(crs)]
    rows = []
    # bin by effective buffer size like the Fig 13 scatter
    qs = np.quantile(beta_e, [0, 0.25, 0.5, 0.75, 1.0]) if len(beta_e) else []
    for lo, hi in zip(qs[:-1], qs[1:]):
        sel = (beta_e >= lo) & (beta_e <= hi)
        if sel.any():
            rows.append({
                "beta_e_bin": f"{lo:.0f}-{hi:.0f}",
                "cr_mean": float(crs[sel].mean()),
                "cr_min": float(crs[sel].min()),
                "cr_max": float(crs[sel].max()),
                "n": int(sel.sum()),
            })
    derived = {
        "mean_compression": float(crs.mean()),
        "range": [float(np.percentile(crs, 5)), float(np.percentile(crs, 95))],
        "paper_mean": 0.2497,
        "paper_range": [0.15, 0.35],
    }
    return rows, derived


def bench_prediction() -> Tuple[List[Dict], Dict]:
    """Table I + Fig 11: fit every mu_exp model form on controlled-run
    traces at three cpu_max settings, report MAE/MSE/RMSE."""
    rows = []
    best = {}
    for cpu_max in (0.40, 0.50, 0.55):
        # consumer at full speed so every setting admits enough traffic
        # to fit on (cpu_max=0.40 at half speed throttles permanently --
        # the paper saw the same degeneracy below cpu_max~30%, Fig 11)
        rep, pipe, dt = _run(uncontrolled=False, compress=True,
                             cpu_max=cpu_max, ticks=300, speed=1.0)
        mu = rep.samples["mu"]
        beta_e = np.maximum(rep.samples["beta_e"], 1.0)
        mu_prev = np.concatenate([[0.0], mu[:-1]])
        sel = beta_e > 1.0
        if sel.sum() < 20:
            continue
        for name, feat in P.TABLE1_MODELS.items():
            X = np.stack(feat(mu_prev[sel], beta_e[sel]), axis=1)
            coef, mae, mse, rmse = P.fit_offline(X, mu[sel] * 100)  # percent, like paper
            rows.append({
                "model": name, "cpu_max": int(cpu_max * 100),
                "mae": round(mae, 3), "mse": round(mse, 3), "rmse": round(rmse, 3),
                "A": round(float(coef[0]), 4), "B": round(float(coef[1]), 4),
                "intercept": round(float(coef[2]), 4),
            })
        by_model = {r["model"]: r["mae"] for r in rows if r["cpu_max"] == int(cpu_max * 100)}
        best[int(cpu_max * 100)] = min(by_model, key=by_model.get)
    # Eq. 2: phi2 quadratic vs linear comparison
    rep, pipe, _ = _run(uncontrolled=False, compress=True, ticks=300)
    rho = rep.samples["rho"]
    dens = rep.samples["density"]
    beta_e = rep.samples["beta_e"]
    sel = beta_e > 1
    Xq = np.stack([rho[sel], dens[sel] ** 2, np.ones(sel.sum())], axis=1)
    Xl = np.stack([rho[sel], dens[sel], np.ones(sel.sum())], axis=1)
    _, mae_q, _, _ = P.fit_offline(Xq, beta_e[sel])
    _, mae_l, _, _ = P.fit_offline(Xl, beta_e[sel])
    derived = {
        "best_mu_model_per_cpu_max": best,
        "paper_best": "a_mu_log (mu = A*mu[n-1] + B*log(beta))",
        "eq2_phi2_quadratic_mae": round(mae_q, 2),
        "eq2_phi2_linear_mae": round(mae_l, 2),
    }
    return rows, derived


def bench_ingest_trajectory() -> Tuple[List[Dict], Dict]:
    """Perf trajectory of the GRAPHPUSH hot path (BENCH_ingest.json):
    per-commit wall time, adaptive probe budget, dropped inserts, and
    incremental-snapshot maintenance cost (delta applies vs the full
    rebuilds they replace).  Written to BENCH_ingest.json by
    `benchmarks.run --json` so later PRs can diff the trajectory."""
    import jax

    from repro.api import GraphStoreSink, PipelineBuilder
    from repro.ingest.sources import BurstyTweetSource
    from repro.configs.paper_ingest import IngestConfig
    from repro.query.snapshot import build_snapshot

    cfg = IngestConfig(store_nodes=1 << 12, store_edges=1 << 14)
    pipe = (PipelineBuilder(cfg)
            .with_source(BurstyTweetSource(seed=7, mean_rate=60.0))
            .with_sink(GraphStoreSink(node_cap=1 << 12, edge_cap=1 << 14))
            .with_query_sink(depth=4, width=256, answer_every=10**9)
            .spill_dir("/tmp/repro_bench_trajectory")
            .build())
    snap_ms = []
    qsink = pipe.sink
    tick = [0]

    def every_tick(ev):
        if ev.kind != "commit":
            return
        tick[0] += 1
        if tick[0] % 10 == 0:
            # query-while-ingesting: time the maintained-snapshot serve
            t0 = time.perf_counter()
            jax.block_until_ready(qsink.snapshot().n_edges)
            delta_ms = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            jax.block_until_ready(build_snapshot(qsink.store).n_edges)
            full_ms = (time.perf_counter() - t0) * 1e3
            snap_ms.append({"commit": tick[0],
                            "serve_ms": round(delta_ms, 2),
                            "full_rebuild_ms": round(full_ms, 2)})

    pipe.metrics.subscribe(every_tick)
    rep = pipe.run(max_ticks=120)
    commits = qsink.ingestor.commits
    trajectory = [{
        "commit": i,
        "wall_ms": round(c.busy_s * 1e3, 2),
        "probe_rounds": c.probe_rounds,
        "dropped_inserts": c.dropped,
        "instructions": c.instructions,
    } for i, c in enumerate(commits) if c.ok]
    m = qsink.maintainer
    derived = {
        "commits": len(trajectory),
        "records": rep.total_records,
        "commit_ms_mean": round(float(np.mean([t["wall_ms"] for t in trajectory])), 2)
        if trajectory else 0.0,
        "dropped_total": sum(t["dropped_inserts"] for t in trajectory),
        "probe_rounds_max": max((t["probe_rounds"] for t in trajectory), default=0),
        "snapshot_full_builds": m.full_builds,
        "snapshot_delta_applies": m.delta_applies,
        "trajectory": trajectory,
        "snapshot_trajectory": snap_ms,
    }
    row = {k: v for k, v in derived.items()
           if k not in ("trajectory", "snapshot_trajectory")}
    return [row], derived


def bench_ingestor_node() -> Tuple[List[Dict], Dict]:
    """Fig 14 + throughput: pipeline-side resource use and rates."""
    import resource

    rep, pipe, dt = _run(uncontrolled=False, compress=True, ticks=200)
    maxrss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    rows = [{
        "records_per_s_wall": rep.total_records / max(rep.wall_s, 1e-9),
        "instr_per_s_wall": rep.total_instructions / max(rep.wall_s, 1e-9),
        "maxrss_mb": round(maxrss_mb, 1),
        "commits": len(pipe.sink.ingestor.commits),
        "commit_busy_mean_ms": 1e3 * float(np.mean([c.busy_s for c in pipe.sink.ingestor.commits]))
        if pipe.sink.ingestor.commits else 0.0,
    }]
    return rows, rows[0]
