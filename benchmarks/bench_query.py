"""Query-subsystem benches: sketch update throughput, snapshot build
time, and query latency vs graph size (refs: Gou et al. 2018 GSS;
Pacaci et al. 2021 streaming graph queries)."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, iters=10, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _tables(rng, n, n_keys, cap):
    from repro.core.edge_table import from_raw_batch
    from repro.core.transform import RawEdgeBatch

    src = rng.integers(1, n_keys, size=n).astype(np.uint64)
    dst = rng.integers(1, n_keys, size=n).astype(np.uint64)
    et = rng.integers(0, 3, size=n).astype(np.int32)
    raw = RawEdgeBatch(src=src, dst=dst, etype=et,
                       src_type=np.zeros(n, np.int32),
                       dst_type=np.zeros(n, np.int32), n_records=n)
    return from_raw_batch(raw, cap)


def bench_sketch_update() -> Tuple[List[Dict], Dict]:
    """Ingestion-time sketch: edge instructions absorbed per second."""
    from repro.query.sketch import init_sketch, sketch_update

    rng = np.random.default_rng(0)
    rows = []
    for n in (1024, 8192):
        tbl = _tables(rng, n, n_keys=n // 4, cap=n)
        sk = init_sketch(depth=4, width=256)
        # time the full update (blocking on the whole pytree): returning
        # a single scalar would let XLA dead-code-eliminate the scatter
        us = _time(sketch_update, sk, tbl)
        rows.append({"batch_edges": n, "us_per_call": round(us, 1),
                     "edges_per_s": round(n / us * 1e6)})
    return rows, {"peak_edges_per_s": max(r["edges_per_s"] for r in rows)}


def _filled_store(rng, node_cap, edge_cap, n_edges):
    from repro.graphstore.store import init_store, ingest_step

    store = init_store(node_cap, edge_cap)
    per = 4096
    for _ in range(max(1, n_edges // per)):
        store, _ = ingest_step(store, _tables(rng, per, n_keys=node_cap // 4,
                                              cap=per))
    return store


def bench_snapshot_build() -> Tuple[List[Dict], Dict]:
    """Hash-table -> CSR compaction time vs store size, and the
    incremental path: apply_delta (one commit merged into the CSR)
    vs the full rebuild it replaces."""
    from repro.graphstore.store import ingest_step
    from repro.query.snapshot import apply_delta, build_snapshot

    rng = np.random.default_rng(0)
    rows = []
    for node_cap, edge_cap, n_edges in ((1 << 12, 1 << 14, 8192),
                                        (1 << 14, 1 << 16, 32768)):
        store = _filled_store(rng, node_cap, edge_cap, n_edges)
        us = _time(build_snapshot, store, iters=5)
        # incremental maintenance: merge one more commit as a delta
        snap = jax.block_until_ready(build_snapshot(store))
        tbl = _tables(rng, 2048, n_keys=node_cap // 4, cap=2048)
        store2, stats = ingest_step(store, tbl)
        us_delta = _time(lambda s, d: apply_delta(s, d)[0].n_edges,
                         snap, stats["delta"], iters=5)
        us_full = _time(lambda s: build_snapshot(s).n_edges, store2, iters=5)
        rows.append({
            "node_cap": node_cap, "edge_cap": edge_cap,
            "stored_edges": int(store.n_edges),
            "us_per_call": round(us, 1),
            "edges_per_s": round(int(store.n_edges) / us * 1e6),
            "us_delta_apply": round(us_delta, 1),
            "us_full_rebuild": round(us_full, 1),
            "delta_speedup": round(us_full / max(us_delta, 1e-9), 2),
        })
    return rows, {"peak_edges_per_s": max(r["edges_per_s"] for r in rows),
                  "delta_speedup": [r["delta_speedup"] for r in rows]}


def bench_query_latency() -> Tuple[List[Dict], Dict]:
    """Engine op latency on a compacted snapshot."""
    from repro.query.engine import (
        degree_distribution, edge_lookup, k_hop, top_k_degree, triangle_count,
    )
    from repro.query.snapshot import build_snapshot

    rng = np.random.default_rng(0)
    rows = []
    for node_cap, edge_cap, n_edges in ((1 << 11, 1 << 13, 4096),
                                        (1 << 12, 1 << 14, 12288)):
        store = _filled_store(rng, node_cap, edge_cap, n_edges)
        snap = build_snapshot(store)
        seeds = jnp.asarray(np.asarray(snap.node_key)[:4], snap.node_key.dtype)
        qs = jnp.asarray(rng.integers(1, node_cap // 4, size=256),
                         snap.node_key.dtype)
        qd = jnp.asarray(rng.integers(1, node_cap // 4, size=256),
                         snap.node_key.dtype)
        row = {
            "stored_edges": int(store.n_edges),
            "degree_dist_us": round(_time(
                lambda s: degree_distribution(s, num_bins=64), snap), 1),
            "top_k_us": round(_time(lambda s: top_k_degree(s, 10)[0], snap), 1),
            "k_hop2_us": round(_time(
                lambda s, x: k_hop(s, x, hops=2), snap, seeds), 1),
            "edge_lookup256_us": round(_time(
                lambda s, a, b: edge_lookup(s, a, b), snap, qs, qd), 1),
            "triangle_us": round(_time(
                lambda s: triangle_count(s), snap, iters=3), 1),
        }
        rows.append(row)
    return rows, {"ops": ["degree_dist", "top_k", "k_hop2",
                          "edge_lookup256", "triangle"]}
