"""Lineage benchmarks: tracking overhead + the freshness SLI rows.

Two benches (both land in BENCH_ingest.json's trajectory):

  * `bench_lineage_overhead` — the PR acceptance bar: per-batch
    tagging, watermark bookkeeping and hop logs cost <3% wall time
    over a telemetry-only run of the same CI-sized steady_state
    workload (telemetry is the fair baseline — lineage rides on the
    same hub/registry, so the delta isolates the lineage layer).
  * `bench_lineage_freshness` — the freshness SLIs per scenario.
    Lags are stream-time and counter-deterministic per seed, so the
    regression gate can hold them to tight tolerances: a batch that
    starts routing through a slower path moves these numbers, host
    noise does not.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.lineage import LineageTracker
from repro.telemetry import TelemetryRegistry
from repro.workloads import run_scenario

TICKS = 60
NODE_CAP = 1 << 12
EDGE_CAP = 1 << 14
ACCEPTANCE_PCT = 3.0

FRESHNESS_SCENARIOS = ("steady_state", "flash_crowd")


def _run(lineage=None) -> Tuple[float, object]:
    t0 = time.perf_counter()
    rep = run_scenario(
        "steady_state", ticks=TICKS, seed=3, speed=0.5,
        node_cap=NODE_CAP, edge_cap=EDGE_CAP,
        spill_dir="/tmp/repro_bench_lineage",
        telemetry=TelemetryRegistry(),
        lineage=lineage)
    return time.perf_counter() - t0, rep


def bench_lineage_overhead() -> Tuple[List[Dict], Dict]:
    _run()  # warm: JIT compilation must not land in either side
    off_s = min(_run()[0], _run()[0])

    trk = LineageTracker()
    on_a, rep = _run(lineage=trk)
    on_b, _ = _run(lineage=LineageTracker())
    on_s = min(on_a, on_b)

    overhead_pct = 100.0 * (on_s - off_s) / max(off_s, 1e-9)
    rows = [{
        "scenario": "steady_state",
        "ticks": TICKS,
        "lineage_off_s": round(off_s, 4),
        "lineage_on_s": round(on_s, 4),
        "overhead_pct": round(overhead_pct, 2),
        "batches_tracked": trk.batches_opened,
        "records_tracked": trk.records_in,
        "records": rep.total_records,
    }]
    derived = {
        "overhead_pct": round(overhead_pct, 2),
        "within_acceptance": overhead_pct < ACCEPTANCE_PCT,
        "acceptance_pct": ACCEPTANCE_PCT,
        "batches_tracked": trk.batches_opened,
    }
    return rows, derived


def bench_lineage_freshness() -> Tuple[List[Dict], Dict]:
    rows: List[Dict] = []
    for scenario in FRESHNESS_SCENARIOS:
        trk = LineageTracker()
        rep = run_scenario(
            scenario, ticks=TICKS, seed=3, speed=0.5,
            node_cap=NODE_CAP, edge_cap=EDGE_CAP,
            spill_dir=f"/tmp/repro_bench_lineage_{scenario}",
            lineage=trk)
        rows.append({
            "scenario": scenario,
            "ticks": TICKS,
            "ingest_lag_ms_p50": rep.ingest_lag_ms_p50,
            "ingest_lag_ms_p99": rep.ingest_lag_ms_p99,
            "queryable_lag_ms_p99": rep.queryable_lag_ms_p99,
            "path_mix": dict(rep.path_mix),
            "records_in": rep.records_in,
            "records_committed": rep.records_committed,
            "records_in_flight": rep.records_in_flight,
            "conservation_ok": not rep.conservation_warning,
            "watermark_queryable": rep.watermark_final.get("queryable"),
        })
    # the gated SLIs come from the steady_state row: deterministic,
    # and the scenario every other overhead bench anchors on
    steady = rows[0]
    derived = {
        "ingest_lag_ms_p50": steady["ingest_lag_ms_p50"],
        "ingest_lag_ms_p99": steady["ingest_lag_ms_p99"],
        "queryable_lag_ms_p99": steady["queryable_lag_ms_p99"],
        "conservation_ok": all(r["conservation_ok"] for r in rows),
    }
    return rows, derived
