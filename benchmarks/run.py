"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call where timing
is meaningful; structural benches print the primary metric instead).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only compression
  PYTHONPATH=src python -m benchmarks.run --only store_ingest,snapshot_build

With ``--json`` the full results go to the given file AND the ingest
perf trajectory (per-commit wall time, probe rounds, dropped inserts,
snapshot delta-apply vs full-rebuild timings, per-scenario workload
rows) is merge-appended as a new run entry into ``BENCH_ingest.json``
next to it — earlier runs are preserved, so the file accumulates the
perf trajectory PR over PR instead of only holding the latest run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# bench names whose results belong in the BENCH_ingest.json trajectory
TRAJECTORY_BENCHES = ("ingest_trajectory", "store_ingest", "snapshot_build",
                      "workload_scenarios", "compress_dictionary",
                      "telemetry_overhead", "resilience_chaos",
                      "monitor_overhead", "lineage_overhead",
                      "lineage_freshness")

BENCHES = [
    # (name, module, function, paper ref)
    ("uncontrolled_meltdown", "benchmarks.bench_ingestion", "bench_uncontrolled", "Figs 1-3,7"),
    ("controlled_bounded_cpu", "benchmarks.bench_ingestion", "bench_controlled", "Fig 12"),
    ("graph_compression", "benchmarks.bench_ingestion", "bench_compression", "Fig 13"),
    ("prediction_models", "benchmarks.bench_ingestion", "bench_prediction", "Table I, Fig 11"),
    ("ingestor_node_health", "benchmarks.bench_ingestion", "bench_ingestor_node", "Fig 14"),
    ("ingest_trajectory", "benchmarks.bench_ingestion", "bench_ingest_trajectory", "Alg 3 hot path (BENCH_ingest.json)"),
    ("dedup_throughput", "benchmarks.bench_kernels", "bench_dedup_throughput", "Alg 1 hot path"),
    ("store_ingest", "benchmarks.bench_kernels", "bench_store_ingest", "Alg 3 hot path"),
    ("attention_paths", "benchmarks.bench_kernels", "bench_attention_paths", "LM substrate"),
    ("ssd_chunked_speedup", "benchmarks.bench_kernels", "bench_ssd_vs_naive", "LM substrate"),
    ("workload_scenarios", "benchmarks.bench_workloads", "bench_scenarios", "scenario family (Alg 2 under adversarial streams)"),
    ("compress_dictionary", "benchmarks.bench_compress", "bench_compress_dictionary", "GraphZip dictionary compression (Fig 13 + refs)"),
    ("telemetry_overhead", "benchmarks.bench_telemetry", "bench_telemetry_overhead", "observability cost (spans on vs off, steady_state)"),
    ("monitor_overhead", "benchmarks.bench_monitor", "bench_monitor_overhead", "online health-monitor cost + controller score (repro.monitor)"),
    ("lineage_overhead", "benchmarks.bench_lineage", "bench_lineage_overhead", "watermark/provenance tracking cost (repro.lineage)"),
    ("lineage_freshness", "benchmarks.bench_lineage", "bench_lineage_freshness", "freshness SLIs per scenario (repro.lineage)"),
    ("resilience_chaos", "benchmarks.bench_resilience", "bench_resilience", "checkpoint/resume + backoff retry (repro.resilience)"),
    ("sketch_update", "benchmarks.bench_query", "bench_sketch_update", "GSS/TCM sketch (Gou 2018)"),
    ("snapshot_build", "benchmarks.bench_query", "bench_snapshot_build", "store->CSR compaction"),
    ("query_latency", "benchmarks.bench_query", "bench_query_latency", "streaming graph queries (Pacaci 2021)"),
]


def merge_bench_ingest(path: str, traj: dict) -> int:
    """Append `traj` as a new run entry in the BENCH_ingest.json perf
    trajectory, preserving earlier runs (a legacy single-run file is
    wrapped as run 0).  Returns the new run count."""
    runs = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev, dict) and isinstance(prev.get("runs"), list):
                runs = prev["runs"]
            elif isinstance(prev, dict) and prev:
                runs = [{"run": 0, "note": "legacy single-run format",
                         "benches": prev}]
        except (OSError, ValueError) as e:
            # unreadable trajectory: keep the evidence (the file is the
            # repo's perf history — never silently discard it), start a
            # fresh trajectory, and say so loudly
            n = 0
            while os.path.exists(f"{path}.bak-{n}"):
                n += 1
            bak = f"{path}.bak-{n}"
            os.replace(path, bak)
            print(f"WARNING: {path} is corrupt ({e}); renamed it to "
                  f"{bak} and starting a fresh trajectory", file=sys.stderr)
    runs.append({
        "run": len(runs),
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "benches": traj,
    })
    with open(path, "w") as f:
        json.dump({"runs": runs}, f, indent=2, default=str)
    return len(runs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of bench names")
    ap.add_argument("--json", default=None, help="also dump results to file")
    args = ap.parse_args()
    only = [s for s in (args.only or "").split(",") if s]

    import importlib

    all_results = {}
    print("name,us_per_call,derived")
    n_failed = 0
    for name, mod, fn, ref in BENCHES:
        if only and not any(s in name for s in only):
            continue
        t0 = time.perf_counter()
        try:
            rows, derived = getattr(importlib.import_module(mod), fn)()
        except Exception as e:  # one broken bench must not abort the suite
            n_failed += 1
            print(f"{name},,{json.dumps({'error': repr(e)})}")
            all_results[name] = {"error": repr(e), "paper_ref": ref}
            continue
        us = (time.perf_counter() - t0) * 1e6
        us_field = ""
        if rows and "us_per_call" in rows[0]:
            us_field = f"{rows[0]['us_per_call']}"
        elif rows and "us_per_commit" in rows[0]:
            us_field = f"{rows[0]['us_per_commit']}"
        # long per-commit series stay out of stdout (BENCH_ingest.json)
        show = {k: v for k, v in derived.items() if not k.endswith("trajectory")}
        print(f"{name},{us_field},{json.dumps(show, default=str)}")
        for r in rows:
            print(f"  {name}.row,,{json.dumps(r, default=str)}")
        all_results[name] = {"rows": rows, "derived": derived, "paper_ref": ref,
                             "bench_wall_us": us}
    # roofline table from dry-run artifacts, if present
    try:
        from benchmarks.roofline import load_cells, table

        cells = load_cells()
        if cells:
            print("\n== roofline (single-pod) ==")
            print(table(cells, "16x16"))
    except Exception as e:  # dry-run results absent: fine
        print(f"(roofline table skipped: {e})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_results, f, indent=2, default=str)
        print(f"(wrote {len(all_results)} bench results to {args.json})")
        # ingest perf-trajectory file: the hot-path regression record
        traj = {
            name: all_results[name]
            for name in TRAJECTORY_BENCHES
            if name in all_results
        }
        if traj:
            path = os.path.join(os.path.dirname(os.path.abspath(args.json)),
                                "BENCH_ingest.json")
            n = merge_bench_ingest(path, traj)
            print(f"(appended ingest perf trajectory to {path}: "
                  f"run {n - 1}, {n} total)")
    if n_failed:
        print(f"({n_failed} bench(es) failed; see error rows above)")
        sys.exit(1)


if __name__ == "__main__":
    main()
