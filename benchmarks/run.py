"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call where timing
is meaningful; structural benches print the primary metric instead).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only compression
  PYTHONPATH=src python -m benchmarks.run --only store_ingest,snapshot_build

With ``--json`` the full results go to the given file AND the ingest
perf trajectory (per-commit wall time, probe rounds, dropped inserts,
snapshot delta-apply vs full-rebuild timings) is written to
``BENCH_ingest.json`` next to it, so later PRs can diff hot-path
regressions.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = [
    # (name, module, function, paper ref)
    ("uncontrolled_meltdown", "benchmarks.bench_ingestion", "bench_uncontrolled", "Figs 1-3,7"),
    ("controlled_bounded_cpu", "benchmarks.bench_ingestion", "bench_controlled", "Fig 12"),
    ("graph_compression", "benchmarks.bench_ingestion", "bench_compression", "Fig 13"),
    ("prediction_models", "benchmarks.bench_ingestion", "bench_prediction", "Table I, Fig 11"),
    ("ingestor_node_health", "benchmarks.bench_ingestion", "bench_ingestor_node", "Fig 14"),
    ("ingest_trajectory", "benchmarks.bench_ingestion", "bench_ingest_trajectory", "Alg 3 hot path (BENCH_ingest.json)"),
    ("dedup_throughput", "benchmarks.bench_kernels", "bench_dedup_throughput", "Alg 1 hot path"),
    ("store_ingest", "benchmarks.bench_kernels", "bench_store_ingest", "Alg 3 hot path"),
    ("attention_paths", "benchmarks.bench_kernels", "bench_attention_paths", "LM substrate"),
    ("ssd_chunked_speedup", "benchmarks.bench_kernels", "bench_ssd_vs_naive", "LM substrate"),
    ("sketch_update", "benchmarks.bench_query", "bench_sketch_update", "GSS/TCM sketch (Gou 2018)"),
    ("snapshot_build", "benchmarks.bench_query", "bench_snapshot_build", "store->CSR compaction"),
    ("query_latency", "benchmarks.bench_query", "bench_query_latency", "streaming graph queries (Pacaci 2021)"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of bench names")
    ap.add_argument("--json", default=None, help="also dump results to file")
    args = ap.parse_args()
    only = [s for s in (args.only or "").split(",") if s]

    import importlib

    all_results = {}
    print("name,us_per_call,derived")
    n_failed = 0
    for name, mod, fn, ref in BENCHES:
        if only and not any(s in name for s in only):
            continue
        t0 = time.perf_counter()
        try:
            rows, derived = getattr(importlib.import_module(mod), fn)()
        except Exception as e:  # one broken bench must not abort the suite
            n_failed += 1
            print(f"{name},,{json.dumps({'error': repr(e)})}")
            all_results[name] = {"error": repr(e), "paper_ref": ref}
            continue
        us = (time.perf_counter() - t0) * 1e6
        us_field = ""
        if rows and "us_per_call" in rows[0]:
            us_field = f"{rows[0]['us_per_call']}"
        elif rows and "us_per_commit" in rows[0]:
            us_field = f"{rows[0]['us_per_commit']}"
        # long per-commit series stay out of stdout (BENCH_ingest.json)
        show = {k: v for k, v in derived.items() if not k.endswith("trajectory")}
        print(f"{name},{us_field},{json.dumps(show, default=str)}")
        for r in rows:
            print(f"  {name}.row,,{json.dumps(r, default=str)}")
        all_results[name] = {"rows": rows, "derived": derived, "paper_ref": ref,
                             "bench_wall_us": us}
    # roofline table from dry-run artifacts, if present
    try:
        from benchmarks.roofline import load_cells, table

        cells = load_cells()
        if cells:
            print("\n== roofline (single-pod) ==")
            print(table(cells, "16x16"))
    except Exception as e:  # dry-run results absent: fine
        print(f"(roofline table skipped: {e})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_results, f, indent=2, default=str)
        print(f"(wrote {len(all_results)} bench results to {args.json})")
        # ingest perf-trajectory file: the hot-path regression record
        traj = {
            name: all_results[name]
            for name in ("ingest_trajectory", "store_ingest", "snapshot_build")
            if name in all_results
        }
        if traj:
            path = os.path.join(os.path.dirname(os.path.abspath(args.json)),
                                "BENCH_ingest.json")
            with open(path, "w") as f:
                json.dump(traj, f, indent=2, default=str)
            print(f"(wrote ingest perf trajectory to {path})")
    if n_failed:
        print(f"({n_failed} bench(es) failed; see error rows above)")
        sys.exit(1)


if __name__ == "__main__":
    main()
