"""Telemetry-overhead benchmark: spans on vs. off on steady_state.

The PR-7 acceptance bar is that full span telemetry (tick/filter/
decide/transform/commit sub-spans + the controller audit trail) costs
<3% wall time on the steady_state scenario, and that the disabled
path is free.  This bench runs the same CI-sized steady_state
workload three ways — telemetry off (the default), telemetry on, and
telemetry on again (min-of-two to damp host noise) — and reports the
overhead plus the per-stage commit breakdown that lands in
BENCH_ingest.json's trajectory.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.telemetry import TelemetryRegistry
from repro.workloads import run_scenario

TICKS = 60
NODE_CAP = 1 << 12
EDGE_CAP = 1 << 14
ACCEPTANCE_PCT = 3.0


def _run(telemetry=None) -> Tuple[float, object]:
    t0 = time.perf_counter()
    rep = run_scenario(
        "steady_state", ticks=TICKS, seed=3, speed=0.5,
        node_cap=NODE_CAP, edge_cap=EDGE_CAP,
        spill_dir="/tmp/repro_bench_telemetry",
        telemetry=telemetry)
    return time.perf_counter() - t0, rep


def bench_telemetry_overhead() -> Tuple[List[Dict], Dict]:
    _run()  # warm: JIT compilation must not land in either side
    off_s = min(_run()[0], _run()[0])

    reg = TelemetryRegistry()
    on_a, rep = _run(telemetry=reg)
    on_b, _ = _run(telemetry=TelemetryRegistry())
    on_s = min(on_a, on_b)

    overhead_pct = 100.0 * (on_s - off_s) / max(off_s, 1e-9)
    commit_stages = {
        name: {k: round(float(v), 4) for k, v in st.items()}
        for name, st in rep.stage_latency_ms.items()
        if name.startswith(("commit.", "transform."))
    }
    rows = [{
        "scenario": "steady_state",
        "ticks": TICKS,
        "telemetry_off_s": round(off_s, 4),
        "telemetry_on_s": round(on_s, 4),
        "overhead_pct": round(overhead_pct, 2),
        "spans_recorded": len(reg.events),
        "stages": len(rep.stage_latency_ms),
        "audit_decisions": rep.audit_decisions,
        "records": rep.total_records,
    }]
    derived = {
        "overhead_pct": round(overhead_pct, 2),
        "within_acceptance": overhead_pct < ACCEPTANCE_PCT,
        "acceptance_pct": ACCEPTANCE_PCT,
        "spans_recorded": len(reg.events),
        "commit_breakdown_ms": commit_stages,
    }
    return rows, derived
