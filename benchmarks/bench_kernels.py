"""Kernel microbenches: us/call of the jnp reference paths on CPU (the
Pallas kernels themselves run in interpret mode here — their numbers
are structural, not perf) plus the ingest-path throughput that feeds
the paper's pipeline."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, iters=20, warmup=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_dedup_throughput() -> Tuple[List[Dict], Dict]:
    from repro.core.compression import dedup_with_counts

    rows = []
    rng = np.random.default_rng(0)
    for n in (1024, 8192, 65536):
        keys = jnp.asarray(rng.integers(0, n // 4, size=n).astype(np.uint32))
        valid = jnp.ones((n,), bool)
        f = jax.jit(dedup_with_counts)
        us = _time(lambda k, v: f(k, v).keys, keys, valid)
        rows.append({"n": n, "us_per_call": round(us, 1),
                     "keys_per_s": round(n / us * 1e6)})
    return rows, {"peak_keys_per_s": max(r["keys_per_s"] for r in rows)}


def bench_store_ingest() -> Tuple[List[Dict], Dict]:
    from repro.core.edge_table import build_edge_table
    from repro.graphstore.store import count_probe_loops, init_store, ingest_step

    rng = np.random.default_rng(0)
    rows = []
    probe_loops = None
    for n in (1024, 8192):
        src = jnp.asarray(rng.integers(1, 5000, size=n).astype(np.uint32))
        dst = jnp.asarray(rng.integers(1, 5000, size=n).astype(np.uint32))
        et = jnp.ones((n,), jnp.int32)
        tbl = build_edge_table(src, dst, et, jnp.ones((n,), bool))
        store = init_store(1 << 18, 1 << 19)
        if probe_loops is None:
            probe_loops = count_probe_loops(tbl)

        def step(s, t):
            return ingest_step(s, t)[0].n_nodes

        us = _time(step, store, tbl, iters=10)
        _, stats = ingest_step(store, tbl)
        rows.append({"batch_edges": n, "us_per_commit": round(us, 1),
                     "edges_per_s": round(n / us * 1e6),
                     "probe_rounds": int(stats["probe_rounds"]),
                     "dropped_inserts": int(stats["dropped_inserts"])})
    # probe_loops is the structural contract of the fused commit: two
    # sweeps (nodes + edges) instead of the seed's six
    return rows, {"peak_edges_per_s": max(r["edges_per_s"] for r in rows),
                  "probe_loops_per_commit": probe_loops,
                  "seed_probe_loops_per_commit": 6}


def bench_attention_paths() -> Tuple[List[Dict], Dict]:
    from repro.models.layers import _sdpa_chunked, _sdpa_full

    rows = []
    B, n, m, h = 1, 4, 2, 64
    for S in (512, 2048):
        q = jax.random.normal(jax.random.key(0), (B, S, n, h), jnp.float32)
        k = jax.random.normal(jax.random.key(1), (B, S, m, h), jnp.float32)
        v = jax.random.normal(jax.random.key(2), (B, S, m, h), jnp.float32)
        f_full = jax.jit(lambda q, k, v: _sdpa_full(q, k, v, True, None))
        f_chunk = jax.jit(lambda q, k, v: _sdpa_chunked(q, k, v, True, None, 256))
        rows.append({
            "S": S,
            "full_us": round(_time(f_full, q, k, v, iters=5), 1),
            "chunked_us": round(_time(f_chunk, q, k, v, iters=5), 1),
        })
    return rows, {}


def bench_ssd_vs_naive() -> Tuple[List[Dict], Dict]:
    """Chunked SSD vs sequential scan: the 6.3x-class algorithmic win."""
    from repro.kernels.ref import ssd_scan_ref
    from repro.models.mamba2 import ssd_chunked

    BH, S, nh, p, N = 2, 2048, 2, 32, 16
    xh = jax.random.normal(jax.random.key(0), (BH, S, nh, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (BH, S, nh)))
    A = -jnp.abs(jax.random.normal(jax.random.key(2), (nh,)))
    Bs = jax.random.normal(jax.random.key(3), (BH, S, N))
    Cs = jax.random.normal(jax.random.key(4), (BH, S, N))
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    us_c = _time(f_chunk, xh, dt, A, Bs, Cs, iters=5)

    x_f = xh.transpose(0, 2, 1, 3).reshape(BH * nh, S, p)
    dt_f = dt.transpose(0, 2, 1).reshape(BH * nh, S)
    A_f = jnp.tile(A, (BH,))
    B_f = jnp.repeat(Bs, nh, axis=0)
    C_f = jnp.repeat(Cs, nh, axis=0)
    f_seq = jax.jit(lambda *a: ssd_scan_ref(*a)[0])
    us_s = _time(f_seq, x_f, dt_f, A_f, B_f, C_f, iters=2)
    rows = [{"S": S, "chunked_us": round(us_c, 1), "sequential_us": round(us_s, 1),
             "speedup": round(us_s / us_c, 2)}]
    return rows, rows[0]
