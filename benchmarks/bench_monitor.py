"""Monitor-overhead benchmark: health monitoring on vs. off.

The ISSUE-9 acceptance bar is that the online judge (detector bank +
SLO tracker + audit scoring on top of span telemetry) costs <3% wall
time versus the telemetry-only pipeline on steady_state.  Both sides
run with telemetry ON so the bench isolates the monitor's own cost —
the per-tick series assembly, two O(1) detectors per series, and the
SLO window arithmetic — not the span-recording cost already priced by
bench_telemetry.  Derived results carry the controller score so the
perf gate (`repro.monitor.regression`) can hold decision quality to
its trajectory alongside wall time.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.telemetry import TelemetryRegistry
from repro.workloads import run_scenario

TICKS = 60
NODE_CAP = 1 << 12
EDGE_CAP = 1 << 14
ACCEPTANCE_PCT = 3.0


def _run(monitor=False) -> Tuple[float, object]:
    t0 = time.perf_counter()
    rep = run_scenario(
        "steady_state", ticks=TICKS, seed=3, speed=0.5,
        node_cap=NODE_CAP, edge_cap=EDGE_CAP,
        spill_dir="/tmp/repro_bench_monitor",
        telemetry=TelemetryRegistry(), monitor=monitor)
    return time.perf_counter() - t0, rep


def bench_monitor_overhead() -> Tuple[List[Dict], Dict]:
    _run()  # warm: JIT compilation must not land in either side
    off_s = min(_run()[0], _run()[0])

    on_a, rep = _run(monitor=True)
    on_b, _ = _run(monitor=True)
    on_s = min(on_a, on_b)

    overhead_pct = 100.0 * (on_s - off_s) / max(off_s, 1e-9)
    slo_missed = sorted(n for n, s in rep.slo_summary.items()
                        if not s.get("met", True))
    rows = [{
        "scenario": "steady_state",
        "ticks": TICKS,
        "monitor_off_s": round(off_s, 4),
        "monitor_on_s": round(on_s, 4),
        "overhead_pct": round(overhead_pct, 2),
        "health_events": len(rep.health_events),
        "burst_onset_tick": rep.burst_onset_tick,
        "slo_breaches": rep.slo_breaches,
        "slo_alerts": rep.slo_alerts,
        "controller_score": round(rep.controller_score, 4),
        "records": rep.total_records,
    }]
    derived = {
        "overhead_pct": round(overhead_pct, 2),
        "within_acceptance": overhead_pct < ACCEPTANCE_PCT,
        "acceptance_pct": ACCEPTANCE_PCT,
        "controller_score": round(rep.controller_score, 4),
        "decisions": rep.decision_quality.get("decisions", 0),
        "health_events": len(rep.health_events),
        "slo_missed": slo_missed,
    }
    return rows, derived
