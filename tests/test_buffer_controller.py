"""Buffer controller (Algorithm 2) + predictor (Eq. 2/4-5) behaviour."""
import numpy as np
import pytest

from repro.configs.paper_ingest import IngestConfig
from repro.core import predictor as P
from repro.core.buffer import BufferController, PerfMon


def test_rls_recovers_linear_model(rng):
    """mu = A*mu_prev + B*log(beta) + c recovered from noisy samples."""
    A, B, c = 0.3, 0.08, 0.05
    s = P.init_mu_model(0.0, 0.0, 0.0)
    mu_prev = 0.2
    for _ in range(400):
        beta = float(rng.uniform(100, 20000))
        mu = A * mu_prev + B * np.log(beta) + c + rng.normal(0, 0.005)
        s = P.rls_update(s, P.mu_features(mu_prev, beta), np.float32(mu), lam=1.0)
        mu_prev = mu
    theta = np.asarray(s.theta)
    assert abs(theta[0] - A) < 0.05
    assert abs(theta[1] - B) < 0.02
    assert abs(theta[2] - c) < 0.1


def test_beta_model_paper_seed():
    """Eq. 2 seeded with the paper's fitted K=0.597, R=1.48."""
    s = P.init_beta_model()
    v = float(P.predict_beta_e(s, rho=0.5, d=2.0))
    assert abs(v - (0.597 * 0.5 + 1.48 * 4.0)) < 1e-4


def test_controller_beta_stays_in_bounds():
    cfg = IngestConfig(beta_min=100, beta_max=5000, beta_init=1500)
    ctl = BufferController(cfg, spill_dir="/tmp/repro_spill_test1")
    rng = np.random.default_rng(0)
    for i in range(200):
        ctl.perfmon.observe_rate(float(i), float(rng.uniform(10, 3000)))
        ctl.perfmon.observe_mu(float(rng.uniform(0, 1)))
        dec = ctl.decide(edge_table_size=float(rng.uniform(10, 1e4)), density=rng.uniform(0, 1))
        assert cfg.beta_min <= dec.beta <= cfg.beta_max
        assert dec.action in ("push", "hold", "throttle", "drain+push")


def test_controller_grows_buffer_under_load():
    cfg = IngestConfig(beta_init=1000, beta_max=50_000)
    ctl = BufferController(cfg, spill_dir="/tmp/repro_spill_test2")
    # saturate observed load -> predictions go high -> buffer grows
    for i in range(16):
        ctl.perfmon.observe_mu(0.99)
        ctl.perfmon.observe_rate(float(i), 5000.0)
    b0 = ctl.beta
    dec = ctl.decide(edge_table_size=40_000, density=0.5)
    assert dec.action in ("hold", "throttle")
    assert ctl.beta > b0


def test_controller_shrinks_buffer_when_calm():
    cfg = IngestConfig(beta_init=10_000, beta_min=200)
    ctl = BufferController(cfg, spill_dir="/tmp/repro_spill_test3")
    for i in range(16):
        ctl.perfmon.observe_mu(0.05)
        ctl.perfmon.observe_rate(float(i), 10.0)
    b0 = ctl.beta
    dec = ctl.decide(edge_table_size=50, density=0.1)
    assert dec.action in ("push", "drain+push")
    assert ctl.beta < b0


def test_throttle_requires_rising_slope():
    """Step 3: spill only when load exceeds the hard limit AND rising."""
    cfg = IngestConfig(cpu_max=0.5, theta2=0.2)
    ctl = BufferController(cfg, spill_dir="/tmp/repro_spill_test4")
    # falling load history -> slope < 0 -> no throttle even if mu high
    for i, mu in enumerate(np.linspace(0.95, 0.55, 16)):
        ctl.perfmon.observe_mu(float(mu))
        ctl.perfmon.observe_rate(float(i), 100.0)
    dec = ctl.decide(edge_table_size=1e5, density=0.9)
    assert dec.action != "throttle"


def test_spill_roundtrip(tmp_path):
    from repro.core.buffer import SpillStore

    sp = SpillStore(str(tmp_path / "spill"))
    sp.flush([{"id": 1}, {"id": 2}])
    sp.flush([{"id": 3}])
    assert sp.depth == 2
    out = sp.drain(2)
    assert [r["id"] for r in out] == [1, 2, 3]
    assert sp.depth == 0


def test_offline_fit_table1_shapes(rng):
    """Table I reproduction machinery: every model form fits cleanly."""
    mu_prev = rng.uniform(0.1, 0.9, size=200)
    beta = rng.uniform(100, 1e4, size=200)
    y = 0.2 * mu_prev + 0.05 * np.log(beta) + rng.normal(0, 0.01, 200)
    for name, feat in P.TABLE1_MODELS.items():
        X = np.stack(feat(mu_prev, beta), axis=1)
        coef, mae, mse, rmse = P.fit_offline(X, y)
        assert np.isfinite([mae, mse, rmse]).all(), name
    # the log model (paper's best) should fit this synthetic data best
    Xg = np.stack(P.TABLE1_MODELS["a_mu_log"](mu_prev, beta), axis=1)
    _, mae_g, _, _ = P.fit_offline(Xg, y)
    Xb = np.stack(P.TABLE1_MODELS["b_mu_beta2"](mu_prev, beta), axis=1)
    _, mae_b, _, _ = P.fit_offline(Xb, y)
    assert mae_g < mae_b
