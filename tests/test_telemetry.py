"""Telemetry tests (PR 7): histogram bucket math, the null-span
zero-allocation discipline, MetricsHub emit/hook semantics, the
sharded counter-forwarding fix, the controller audit trail, and the
Chrome-trace/JSONL exporters."""
import json
import tracemalloc

import pytest

from repro.api import (
    GraphStoreSink,
    MetricsHub,
    PipelineBuilder,
)
from repro.configs.paper_ingest import IngestConfig
from repro.ingest.sources import BurstyTweetSource
from repro.telemetry import (
    INPUT_KEYS,
    NBUCKETS,
    NULL_REGISTRY,
    NULL_SPAN,
    Histogram,
    TelemetryRegistry,
    bucket_index,
    bucket_lower_ns,
    bucket_upper_ns,
    validate_chrome_trace,
)
from repro.workloads import run_scenario


# ---------------------------------------------------------------------------
# histogram bucket math (exact integer boundaries)
# ---------------------------------------------------------------------------


def test_bucket_index_exact_at_powers_of_two():
    assert bucket_index(0) == 0
    assert bucket_index(1) == 1
    for k in range(1, NBUCKETS - 2):
        # 2**k ns sits at the *bottom* of the half-open bucket k+1
        assert bucket_index(2 ** k) == k + 1
        assert bucket_index(2 ** k - 1) == k
        assert bucket_index(2 ** k + 1) == k + 1


def test_bucket_bounds_round_trip():
    for i in range(1, NBUCKETS - 1):
        assert bucket_index(bucket_lower_ns(i)) == i
        assert bucket_index(bucket_upper_ns(i) - 1) == i
    assert bucket_lower_ns(0) == 0 and bucket_upper_ns(0) == 1
    # durations past the last boundary clip into the final bucket
    assert bucket_index(1 << 100) == NBUCKETS - 1


def test_histogram_percentiles_conservative_and_clamped():
    h = Histogram()
    for _ in range(100):
        h.record_ns(1000)
    # all mass in one bucket: percentile reports its upper bound,
    # clamped to the observed max so it never exceeds real data
    assert h.percentile_ns(0.5) == 1000
    assert h.percentile_ns(0.99) == 1000
    assert h.count == 100 and h.sum_ns == 100_000 and h.max_ns == 1000
    st = h.stats()
    assert st["count"] == 100 and st["p95_ms"] == pytest.approx(1e-3)


def test_histogram_merge_adds_exactly():
    a, b = Histogram(), Histogram()
    a.record_ns(10)
    b.record_ns(10_000)
    b.record_ns(7)
    a.merge(b)
    assert a.count == 3
    assert a.sum_ns == 10_017
    assert a.max_ns == 10_000
    assert sum(a.counts) == 3


# ---------------------------------------------------------------------------
# span API: disabled path allocates nothing, enabled path records
# ---------------------------------------------------------------------------


def test_disabled_span_is_the_null_singleton():
    reg = TelemetryRegistry(enabled=False)
    assert reg.span("a") is NULL_SPAN
    assert reg.span("b") is NULL_SPAN
    assert NULL_REGISTRY.span("x") is NULL_SPAN
    with reg.span("a"):
        pass
    reg.observe("a", 1e-3)
    reg.count("a")
    assert reg.events == [] and reg.stage_names() == []
    assert reg.counters["a"] == 0  # count() is gated too


def test_disabled_path_zero_allocation_per_tick():
    """The telemetry-off hot path must not construct span objects:
    tracemalloc, filtered to spans.py, sees zero new allocations."""
    import repro.telemetry.spans as spans_mod

    reg = TelemetryRegistry(enabled=False)
    for _ in range(16):  # warm any lazy interpreter state
        with reg.span("tick"):
            reg.count("x")
    filt = (tracemalloc.Filter(True, spans_mod.__file__),)
    tracemalloc.start()
    before = tracemalloc.take_snapshot().filter_traces(filt)
    for _ in range(200):
        with reg.span("tick"):
            pass
        reg.observe("commit.total", 1e-6)
        reg.count("x")
    after = tracemalloc.take_snapshot().filter_traces(filt)
    tracemalloc.stop()
    grown = [d for d in after.compare_to(before, "lineno") if d.size_diff > 0]
    assert grown == [], f"disabled path allocated: {grown}"


def test_enabled_span_records_duration_and_event():
    reg = TelemetryRegistry()
    with reg.span("stage"):
        x = sum(range(1000))
    assert x is not None
    h = reg.hist("stage")
    assert h.count == 1 and h.sum_ns > 0
    assert len(reg.events) == 1
    name, shard, t0, t1 = reg.events[0]
    assert name == "stage" and shard is None and t1 >= t0


def test_timed_decorator_and_observe():
    reg = TelemetryRegistry()

    @reg.timed("fn")
    def work(n):
        return n * 2

    assert work(21) == 42
    assert reg.hist("fn").count == 1
    reg.observe("ext", 0.25)
    # an externally measured 0.25 s lands in the right log bucket
    assert reg.hist("ext").count == 1
    assert bucket_lower_ns(bucket_index(reg.hist("ext").sum_ns)) \
        <= int(0.25e9) < bucket_upper_ns(bucket_index(reg.hist("ext").sum_ns))


def test_child_registry_shares_spans_owns_counters():
    root = TelemetryRegistry()
    c0, c1 = root.child(0), root.child(1)
    with c0.span("tick"):
        pass
    with c1.span("tick"):
        pass
    c0.count("push")
    c1.count("push")
    c1.count("push")
    # spans land in the shared root store, shard-tagged
    assert root.hist("tick", shard=0).count == 1
    assert root.hist("tick", shard=1).count == 1
    assert root.aggregate("tick").count == 2
    assert root.shards() == [0, 1]
    # counters stay per-child (ShardedReport sums per-shard hubs)
    assert c0.counters["push"] == 1 and c1.counters["push"] == 2
    assert root.counters["push"] == 0
    # enable state mirrors through the root
    c0.enabled = False
    assert root.span("x") is NULL_SPAN and c1.span("x") is NULL_SPAN


def test_event_list_is_bounded():
    reg = TelemetryRegistry(max_events=5)
    for _ in range(9):
        with reg.span("s"):
            pass
    assert len(reg.events) == 5
    assert reg.events_dropped == 4
    assert reg.hist("s").count == 9  # histograms never drop


# ---------------------------------------------------------------------------
# MetricsHub emit semantics (satellite: pinned by tests)
# ---------------------------------------------------------------------------


def test_metrics_hub_counts_without_hooks():
    hub = MetricsHub()
    hub.emit("tick", 0.0)
    hub.emit("commit-failed", 1.0, error="x")
    hub.emit("commit-failed", 2.0, error="y")
    assert hub.counters["tick"] == 1
    assert hub.counters["commit-failed"] == 2
    assert hub.counters["never-emitted"] == 0


def test_metrics_hub_mid_run_subscriber_sees_subsequent_events():
    hub = MetricsHub()
    early, late = [], []
    hub.subscribe(early.append)
    hub.emit("tick", 0.0)
    hub.subscribe(late.append)  # joins mid-run
    hub.emit("push", 1.0, n=3)
    assert [e.kind for e in early] == ["tick", "push"]
    assert [e.kind for e in late] == ["push"]  # no replay of history
    assert late[0].payload == {"n": 3}
    assert hub.counters["tick"] == 1 and hub.counters["push"] == 1


def test_commit_failed_events_counted_end_to_end():
    """Injected commit failures surface as commit-failed counter hits."""
    cfg = IngestConfig()
    sink = GraphStoreSink(node_cap=1 << 10, edge_cap=1 << 11,
                          fail_hook=lambda: True)
    pipe = (PipelineBuilder(cfg)
            .with_source(BurstyTweetSource(seed=5))
            .with_sink(sink)
            .spill_dir("/tmp/repro_spill_tel_fail")
            .build())
    pipe.run(max_ticks=15)
    assert pipe.metrics.counters["commit-failed"] > 0
    assert pipe.metrics.counters["commit"] == 0


# ---------------------------------------------------------------------------
# sharded counter forwarding (satellite: the _forward fix)
# ---------------------------------------------------------------------------


def test_sharded_forward_routes_through_aggregate_emit():
    """Shard-loop events must land in the aggregate hub's counters
    (the pre-fix `_forward` invoked hooks directly and undercounted),
    and keep their shard tag for subscribers."""
    events = []
    pipe = (PipelineBuilder(IngestConfig())
            .with_source(BurstyTweetSource(seed=7))
            .sharded(2)
            .on_event(events.append)
            .spill_dir("/tmp/repro_spill_tel_fwd")
            .build())
    pipe.run(max_ticks=20)
    agg = pipe.metrics.counters
    assert agg["sample"] > 0 and agg["push"] > 0
    # aggregate counts == sum of the per-shard hub counts
    for kind in ("sample", "push", "commit"):
        assert agg[kind] == sum(h.counters[kind] for h in pipe._hubs), kind
    # shard tag preserved on the forwarded payload
    tags = {e.payload.get("shard") for e in events if e.kind == "sample"}
    assert tags == {0, 1}


# ---------------------------------------------------------------------------
# scenario-level acceptance: trace + audit + report breakdown
# ---------------------------------------------------------------------------

CORE_STAGES = ("tick", "filter", "decide", "transform.dedup", "commit.upsert")


@pytest.fixture(scope="module")
def flash_run(tmp_path_factory):
    d = tmp_path_factory.mktemp("telemetry")
    reg = TelemetryRegistry()
    rep = run_scenario(
        "flash_crowd", ticks=40, seed=0, shards=2,
        node_cap=1 << 12, edge_cap=1 << 14,
        spill_dir=str(d / "spill"),
        telemetry=reg,
        trace=str(d / "trace.json"),
        trace_jsonl=str(d / "spans.jsonl"),
    )
    return reg, rep, d


def test_run_scenario_emits_valid_chrome_trace(flash_run):
    reg, rep, d = flash_run
    ok, msg = validate_chrome_trace(str(d / "trace.json"),
                                    require_stages=CORE_STAGES)
    assert ok, msg
    trace = json.load(open(d / "trace.json"))
    evs = trace["traceEvents"]
    # per-shard timelines: spans on at least two distinct shard tracks
    span_tids = {e["tid"] for e in evs if e.get("ph") == "X"}
    assert len(span_tids) >= 2
    # audit decisions ride along as instant events with full args
    instants = [e for e in evs if e.get("ph") == "i"]
    assert instants and all("mu_pred" in e["args"] for e in instants)


def test_run_scenario_jsonl_sink_parses(flash_run):
    reg, rep, d = flash_run
    kinds = set()
    with open(d / "spans.jsonl") as f:
        for line in f:
            kinds.add(json.loads(line)["type"])
    assert {"span", "audit", "histogram", "counter"} <= kinds


def test_audit_trail_carries_full_input_vector(flash_run):
    reg, rep, d = flash_run
    assert rep.audit_decisions == len(reg.audit) > 0
    for rec in reg.audit:
        assert set(INPUT_KEYS) <= set(rec.inputs), rec
        assert rec.action in ("push", "hold", "throttle", "drain+push")
        if rec.action == "throttle":
            assert rec.reason in ("load", "pressure")
    # predicted-vs-realized: resolved records carry the measured outcome
    resolved = [r for r in reg.audit if r.mu_real is not None]
    assert len(resolved) >= len(reg.audit) - 2  # all but a trailing open one
    assert any(r.beta_e_real is not None and r.beta_e_real > 0
               for r in resolved)


def test_report_carries_stage_latency_breakdown(flash_run):
    reg, rep, d = flash_run
    assert rep.telemetry_enabled
    for stage in CORE_STAGES:
        assert stage in rep.stage_latency_ms, stage
        st = rep.stage_latency_ms[stage]
        assert st["count"] > 0 and st["p95_ms"] >= st["p50_ms"] >= 0
    assert "commit.wait" in rep.stage_latency_ms
    # the breakdown survives the JSON round-trip and the text summary
    assert json.dumps(rep.to_dict())
    assert "telemetry:" in rep.summary()


def test_run_scenario_telemetry_off_by_default():
    rep = run_scenario("steady_state", ticks=10,
                       node_cap=1 << 10, edge_cap=1 << 11,
                       spill_dir="/tmp/repro_spill_tel_off")
    assert not rep.telemetry_enabled
    assert rep.stage_latency_ms == {} and rep.audit_decisions == 0
    assert "telemetry:" not in rep.summary()


def test_compressed_run_records_dictionary_spans():
    reg = TelemetryRegistry()
    run_scenario("spam_storm", ticks=25, dict_compress=True,
                 node_cap=1 << 12, edge_cap=1 << 14,
                 spill_dir="/tmp/repro_spill_tel_dict", telemetry=reg)
    names = reg.stage_names()
    assert "dict.admit" in names
    assert any(n.startswith("rewrite.") for n in names)


def test_sketch_guided_run_records_sketch_spans():
    reg = TelemetryRegistry()
    run_scenario("flash_crowd", ticks=25, sketch_guided=True,
                 node_cap=1 << 12, edge_cap=1 << 14,
                 spill_dir="/tmp/repro_spill_tel_sketch", telemetry=reg)
    assert "sketch.absorb" in reg.stage_names()


def test_snapshot_maintainer_spans():
    from repro.graphstore.store import init_store
    from repro.query.snapshot import SnapshotMaintainer

    reg = TelemetryRegistry()
    m = SnapshotMaintainer()
    m.telemetry = reg
    m.snapshot(init_store(64, 64))
    assert "snapshot.rebuild" in reg.stage_names()
