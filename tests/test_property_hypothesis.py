"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.paper_ingest import IngestConfig
from repro.core import compression as C
from repro.core.buffer import BufferController
from repro.distributed.grad_compression import int8_roundtrip
from repro.kernels import ops, ref

_settings = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# compression invariants
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    data=st.lists(st.integers(min_value=1, max_value=40), min_size=1, max_size=120),
)
def test_dedup_partition_property(data):
    """Dedup is a partition: counts sum to n, uniques match set()."""
    n = len(data)
    cap = 128
    keys = jnp.asarray(np.pad(np.asarray(data, np.uint32), (0, cap - n)))
    valid = jnp.arange(cap) < n
    comp = C.dedup_with_counts(keys, valid)
    assert int(comp.counts.sum()) == n
    assert int(comp.n_unique) == len(set(data))
    uk = np.asarray(comp.keys[: int(comp.n_unique)])
    assert set(uk.tolist()) == set(data)
    assert (np.diff(uk.astype(np.int64)) > 0).all()  # sorted unique


@settings(**_settings)
@given(
    nsrc=st.integers(min_value=2, max_value=30),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_compression_ratio_bounds(nsrc, seed):
    """0 < ratio <= 1: compressed load never exceeds raw load."""
    rng = np.random.default_rng(seed)
    cap = 64
    n = 48
    src = jnp.asarray(rng.integers(1, nsrc, size=cap).astype(np.uint32))
    dst = jnp.asarray(rng.integers(1, nsrc, size=cap).astype(np.uint32))
    et = jnp.ones((cap,), jnp.int32)
    valid = jnp.arange(cap) < n
    from repro.core.edge_table import build_edge_table

    tbl = build_edge_table(src, dst, et, valid)
    r = float(tbl.compression_ratio())
    assert 0.0 < r <= 1.0


# ---------------------------------------------------------------------------
# bloom: no false negatives, ever
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    keys=st.lists(
        st.integers(min_value=1, max_value=2**31 - 1), min_size=1, max_size=64
    )
)
def test_bloom_never_false_negative(keys):
    k = jnp.asarray(np.asarray(keys, np.uint32))
    bm = ops.bloom_build(k, jnp.zeros((4, 1024), jnp.uint32))
    assert bool((np.asarray(ops.bloom_probe(k, bm)) == 1).all())


# ---------------------------------------------------------------------------
# controller invariants
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    mus=st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=4, max_size=30),
    sizes=st.lists(st.floats(min_value=1.0, max_value=1e5), min_size=4, max_size=30),
)
def test_controller_always_in_bounds_and_total(mus, sizes):
    cfg = IngestConfig(beta_min=100, beta_max=10_000)
    ctl = BufferController(cfg, spill_dir="/tmp/repro_spill_hyp")
    for i, (mu, sz) in enumerate(zip(mus, sizes)):
        ctl.perfmon.observe_mu(mu)
        ctl.perfmon.observe_rate(float(i), sz)
        dec = ctl.decide(sz, density=mu)
        assert cfg.beta_min <= ctl.beta <= cfg.beta_max
        assert dec.action in ("push", "hold", "throttle", "drain+push")
        assert 0.0 <= dec.mu_exp <= 1.0  # predictions clipped to [0,1]


# ---------------------------------------------------------------------------
# quantisation error bound
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    scale=st.floats(min_value=1e-3, max_value=1e3),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_int8_error_bound_property(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=512) * scale).astype(np.float32))
    y = int8_roundtrip(x)
    blocks = np.abs(np.asarray(x)).reshape(-1, 256).max(axis=1)
    bound = np.repeat(blocks, 256) / 127.0 * 0.5 + 1e-9
    assert (np.abs(np.asarray(y - x)) <= bound + 1e-6).all()


# ---------------------------------------------------------------------------
# tokenizer determinism
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(text=st.text(alphabet=st.characters(codec="ascii"), max_size=200))
def test_tokenizer_deterministic_and_in_range(text):
    from repro.data.tokenizer import HashTokenizer

    tok = HashTokenizer(1024)
    a = tok.encode(text)
    b = tok.encode(text)
    assert a == b
    assert all(0 <= t < 1024 for t in a)
