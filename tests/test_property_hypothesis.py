"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.paper_ingest import IngestConfig
from repro.core import compression as C
from repro.core.buffer import BufferController
from repro.distributed.grad_compression import int8_roundtrip
from repro.kernels import ops, ref

_settings = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# compression invariants
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    data=st.lists(st.integers(min_value=1, max_value=40), min_size=1, max_size=120),
)
def test_dedup_partition_property(data):
    """Dedup is a partition: counts sum to n, uniques match set()."""
    n = len(data)
    cap = 128
    keys = jnp.asarray(np.pad(np.asarray(data, np.uint32), (0, cap - n)))
    valid = jnp.arange(cap) < n
    comp = C.dedup_with_counts(keys, valid)
    assert int(comp.counts.sum()) == n
    assert int(comp.n_unique) == len(set(data))
    uk = np.asarray(comp.keys[: int(comp.n_unique)])
    assert set(uk.tolist()) == set(data)
    assert (np.diff(uk.astype(np.int64)) > 0).all()  # sorted unique


@settings(**_settings)
@given(
    nsrc=st.integers(min_value=2, max_value=30),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_compression_ratio_bounds(nsrc, seed):
    """0 < ratio <= 1: compressed load never exceeds raw load."""
    rng = np.random.default_rng(seed)
    cap = 64
    n = 48
    src = jnp.asarray(rng.integers(1, nsrc, size=cap).astype(np.uint32))
    dst = jnp.asarray(rng.integers(1, nsrc, size=cap).astype(np.uint32))
    et = jnp.ones((cap,), jnp.int32)
    valid = jnp.arange(cap) < n
    from repro.core.edge_table import build_edge_table

    tbl = build_edge_table(src, dst, et, valid)
    r = float(tbl.compression_ratio())
    assert 0.0 < r <= 1.0


# ---------------------------------------------------------------------------
# bloom: no false negatives, ever
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    keys=st.lists(
        st.integers(min_value=1, max_value=2**31 - 1), min_size=1, max_size=64
    )
)
def test_bloom_never_false_negative(keys):
    k = jnp.asarray(np.asarray(keys, np.uint32))
    bm = ops.bloom_build(k, jnp.zeros((4, 1024), jnp.uint32))
    assert bool((np.asarray(ops.bloom_probe(k, bm)) == 1).all())


# ---------------------------------------------------------------------------
# controller invariants
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    mus=st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=4, max_size=30),
    sizes=st.lists(st.floats(min_value=1.0, max_value=1e5), min_size=4, max_size=30),
)
def test_controller_always_in_bounds_and_total(mus, sizes):
    cfg = IngestConfig(beta_min=100, beta_max=10_000)
    ctl = BufferController(cfg, spill_dir="/tmp/repro_spill_hyp")
    for i, (mu, sz) in enumerate(zip(mus, sizes)):
        ctl.perfmon.observe_mu(mu)
        ctl.perfmon.observe_rate(float(i), sz)
        dec = ctl.decide(sz, density=mu)
        assert cfg.beta_min <= ctl.beta <= cfg.beta_max
        assert dec.action in ("push", "hold", "throttle", "drain+push")
        assert 0.0 <= dec.mu_exp <= 1.0  # predictions clipped to [0,1]


# ---------------------------------------------------------------------------
# workload sampler invariants (repro.workloads)
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    alpha=st.floats(min_value=0.0, max_value=0.9),
    amp=st.floats(min_value=0.0, max_value=0.9),
    flash_mult=st.floats(min_value=1.0, max_value=10.0),
    noise=st.floats(min_value=0.0, max_value=0.45),
)
def test_workload_rates_nonnegative_and_deterministic(
        seed, alpha, amp, flash_mult, noise):
    """Trajectory invariants: rates finite and >= 0, counts in
    [0, cap], and the whole chunk a pure function of the seed."""
    from repro.workloads import rate_trajectory

    args = (64, 0, 0.0, 60.0, noise, alpha, 0.5, amp, 120.0, 20.0,
            flash_mult, 30.0, 3000.0)
    ch = rate_trajectory(np.uint32(seed), *args)
    rates, counts = np.asarray(ch.rates), np.asarray(ch.counts)
    assert np.isfinite(rates).all() and (rates >= 0).all()
    assert (counts >= 0).all() and (counts <= 3000).all()
    again = rate_trajectory(np.uint32(seed), *args)
    np.testing.assert_array_equal(np.asarray(again.counts), counts)


@settings(**_settings)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    a=st.floats(min_value=1.2, max_value=2.5),
    n=st.integers(min_value=100, max_value=5000),
)
def test_workload_zipf_skew_bounds(seed, a, n):
    """Zipf ranks stay in [0, n) and the top decile holds at least
    ~70% of its bounded-Pareto mass (heavy-hitter skew)."""
    from repro.kernels.sampler import counter_mix, uniform01, zipf_rank

    ctr = np.arange(4096, dtype=np.uint32)
    u = uniform01(counter_mix(np.uint32(seed), ctr))
    r = np.asarray(zipf_rank(u, n, a))
    assert r.min() >= 0 and r.max() < n
    top = max(n // 10, 1)
    share = float((r < top).mean())
    expect = ((top + 1) ** (1 - a) - 1) / ((n + 1) ** (1 - a) - 1)
    assert share >= 0.7 * expect
    assert share > 0.3


@settings(**_settings)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_workload_hawkes_burstier_than_poisson(seed):
    """Self-excitation must raise the Fano factor above the alpha=0
    Poisson-like baseline at matched parameters."""
    from repro.workloads import rate_trajectory

    def fano(alpha):
        ch = rate_trajectory(np.uint32(seed), 256, 0, 0.0, 60.0, 0.0,
                             alpha, 0.4, 0.0, 240.0, 1e9, 1.0, 40.0, 6000.0)
        c = np.asarray(ch.counts, np.float64)
        return c.var() / max(c.mean(), 1e-9)

    assert fano(0.85) > fano(0.0)


# ---------------------------------------------------------------------------
# quantisation error bound
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    scale=st.floats(min_value=1e-3, max_value=1e3),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_int8_error_bound_property(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=512) * scale).astype(np.float32))
    y = int8_roundtrip(x)
    blocks = np.abs(np.asarray(x)).reshape(-1, 256).max(axis=1)
    bound = np.repeat(blocks, 256) / 127.0 * 0.5 + 1e-9
    assert (np.abs(np.asarray(y - x)) <= bound + 1e-6).all()


# ---------------------------------------------------------------------------
# tokenizer determinism
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(text=st.text(alphabet=st.characters(codec="ascii"), max_size=200))
def test_tokenizer_deterministic_and_in_range(text):
    from repro.data.tokenizer import HashTokenizer

    tok = HashTokenizer(1024)
    a = tok.encode(text)
    b = tok.encode(text)
    assert a == b
    assert all(0 <= t < 1024 for t in a)


# ---------------------------------------------------------------------------
# retry backoff invariants (repro.resilience)
# ---------------------------------------------------------------------------


@settings(**_settings)
@given(
    base_s=st.floats(min_value=1e-3, max_value=10.0),
    factor=st.floats(min_value=1.0, max_value=8.0),
    cap_mult=st.floats(min_value=1.0, max_value=100.0),
    jitter=st.floats(min_value=0.0, max_value=0.99),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_retry_backoff_property(base_s, factor, cap_mult, jitter, seed):
    """Capped, monotone (until the cap), jitter-bounded, deterministic:
    the RetryPolicy contract for every parameterisation it accepts."""
    from repro.resilience import RetryPolicy

    p = RetryPolicy(base_s=base_s, factor=factor, cap_s=base_s * cap_mult,
                    jitter=jitter, seed=seed)
    raws = [p.raw_delay(k) for k in range(24)]
    # monotone non-decreasing and capped (incl. huge attempt counts)
    assert all(b >= a for a, b in zip(raws, raws[1:]))
    assert all(r <= p.cap_s for r in raws)
    # the schedule saturates: at the cap when it grows, flat otherwise
    assert p.raw_delay(10**9) == (p.cap_s if factor > 1.0
                                  else min(base_s, p.cap_s))
    for k in range(24):
        d = p.delay(k)
        # jitter stays a +/- fraction of the raw schedule...
        assert raws[k] * (1 - jitter) - 1e-12 <= d
        assert d <= raws[k] * (1 + jitter) + 1e-12
        # ...and is a pure function of (policy params, attempt)
        assert d == RetryPolicy(base_s=base_s, factor=factor,
                                cap_s=base_s * cap_mult, jitter=jitter,
                                seed=seed).delay(k)
