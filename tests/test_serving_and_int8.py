"""Serving loop (BatchServer), KV-cache utils, and 8-bit Adam."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeSpec, get_config, smoke_config
from repro.distributed.sharding import init_params
from repro.models import model as M
from repro.serving.decode import BatchServer
from repro.serving.kvcache import alloc_cache, cache_bytes, pad_cache_to
from repro.train.optimizer import dequant_rowwise, quant_rowwise
from repro.train.trainstep import init_state, make_train_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-780m", "mixtral-8x7b"])
def test_batch_server_generates(arch):
    cfg = smoke_config(get_config(arch))
    params = init_params(M.param_specs(cfg), jax.random.key(0), dtype_override=cfg.dtype)
    srv = BatchServer(cfg, params)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    gen = srv.generate({"tokens": tokens}, max_new=6)
    assert gen.shape == (2, 6)
    assert (gen >= 0).all() and (gen < cfg.padded_vocab).all()
    assert srv.tokens_per_s > 0


def test_cache_bytes_scales_with_horizon():
    cfg = smoke_config(get_config("qwen2.5-3b"))
    b1 = cache_bytes(cfg, 2, 64)
    b2 = cache_bytes(cfg, 2, 128)
    assert b2 == 2 * b1  # KV caches scale linearly in horizon


def test_ssm_cache_horizon_free():
    cfg = smoke_config(get_config("mamba2-780m"))
    assert cache_bytes(cfg, 2, 64) == cache_bytes(cfg, 2, 4096)  # O(1) state


def test_pad_cache_roundtrip():
    cfg = smoke_config(get_config("qwen2.5-3b"))
    cache = alloc_cache(cfg, 2, 16)
    padded = pad_cache_to(cache, 32)
    assert padded["k"].shape[2] == 32
    np.testing.assert_array_equal(np.asarray(padded["k"][:, :, :16]), np.asarray(cache["k"]))


# ---------------------------------------------------------------------------
# 8-bit Adam
# ---------------------------------------------------------------------------


def test_quant_rowwise_error_bound(rng):
    x = jnp.asarray(rng.normal(0, 2.0, size=(16, 64)).astype(np.float32))
    q, s = quant_rowwise(x)
    y = dequant_rowwise(q, s)
    bound = np.abs(np.asarray(x)).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-6
    assert (np.abs(np.asarray(y - x)) <= bound + 1e-6).all()


def test_int8_adam_trains():
    cfg = dataclasses.replace(
        smoke_config(get_config("qwen2.5-3b")), opt_state_dtype="int8"
    )
    shape = ShapeSpec("t", 32, 4, "train")
    state = init_state(cfg, jax.random.key(0))
    # state structure: quantised moments + row scales
    qleaf = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "s"}
    assert qleaf(jax.tree.leaves(state["opt"]["m"], is_leaf=qleaf)[0])
    step, _ = make_train_step(cfg, shape, dp=1)
    tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    jstep = jax.jit(step, donate_argnums=0)
    losses = []
    for _ in range(6):
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert not any(np.isnan(losses))


def test_int8_adam_state_smaller():
    from repro.distributed.sharding import spec_avals
    from repro.train.trainstep import make_state_specs

    cfg = smoke_config(get_config("qwen2.5-3b"))
    cfg8 = dataclasses.replace(cfg, opt_state_dtype="int8")
    size = lambda c: sum(
        a.size * a.dtype.itemsize
        for a in jax.tree.leaves(spec_avals(make_state_specs(c)["opt"]))
    )
    assert size(cfg8) < 0.35 * size(cfg)  # ~int8+scales vs fp32
