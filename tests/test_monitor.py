"""repro.monitor tests (ISSUE 9): detector math on known sequences,
SLO burn-rate window arithmetic, controller decision-quality scoring,
the SeriesTap delta math, the end-to-end flash_crowd acceptance run,
the perf-regression gate's exit semantics, and the exporter edge
cases (empty registry, unresolved audit records, dropped-span
warnings)."""
import json
import math
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.monitor import (
    DetectorBank,
    EwmaDetector,
    HealthMonitor,
    MetricSpec,
    PageHinkley,
    SLOSpec,
    SLOTracker,
    compare_runs,
    default_slos,
    extract_metrics,
    format_verdict,
    gate,
    per_action_scores,
    prometheus_text,
    render_dashboard,
    score_record,
    score_trail,
    text_report,
)
from repro.telemetry import TelemetryRegistry, summary_tsv, text_summary
from repro.telemetry.audit import AuditRecord
from repro.telemetry.export import chrome_trace, write_jsonl
from repro.telemetry.spans import SeriesTap
from repro.workloads import run_scenario


def _noise(i: int, amp: float = 3.0) -> float:
    # deterministic pseudo-noise: same sequence on every run
    return amp * math.sin(1.7 * i) + 0.5 * amp * math.cos(3.1 * i)


def _steady(n: int, level: float = 100.0):
    return [level + _noise(i) for i in range(n)]


# ---------------------------------------------------------------------------
# EWMA detector on known sequences
# ---------------------------------------------------------------------------


def test_ewma_step_detected_within_k_ticks():
    det = EwmaDetector(alpha=0.15, z_on=4.0, warmup=8, direction=1)
    seq = _steady(40) + [300.0 + _noise(i) for i in range(40, 60)]
    onset_at = -1
    for i, x in enumerate(seq):
        if det.update(x) == "onset":
            onset_at = i
            break
    # the step is at index 40; a 4-sigma step must fire immediately
    assert onset_at == 40


def test_ewma_no_alert_on_steady_noise():
    det = EwmaDetector(alpha=0.15, z_on=4.0, warmup=8, direction=0)
    events = [det.update(x) for x in _steady(200)]
    assert all(e is None for e in events)


def test_ewma_clears_after_burst_decays():
    det = EwmaDetector(alpha=0.3, z_on=4.0, z_off=1.5, warmup=8,
                       k_off=3, direction=1)
    seq = _steady(30) + [400.0 + _noise(i) for i in range(30, 60)]
    phases = [det.update(x) for x in seq]
    assert "onset" in phases
    # the EWMA adapts to the new level, so the alert clears on its own
    assert "clear" in phases
    assert phases.index("clear") > phases.index("onset")
    assert not det.active


def test_ewma_direction_gates_the_sign():
    down = EwmaDetector(z_on=4.0, warmup=8, direction=-1)
    seq = _steady(30, level=100.0) + [5.0 + 0.1 * _noise(i)
                                      for i in range(30, 40)]
    assert any(down.update(x) == "onset" for x in seq)
    up = EwmaDetector(z_on=4.0, warmup=8, direction=1)
    assert all(up.update(x) != "onset" for x in seq)


def test_ewma_warmup_suppresses_early_alarms():
    det = EwmaDetector(z_on=1.0, warmup=10, direction=0)
    # wild swings inside the warmup window must not alarm
    for i, x in enumerate([0.0, 100.0, -50.0, 80.0, 0.0, 60.0, -10.0, 30.0]):
        assert det.update(x) is None, f"alarmed during warmup at {i}"


# ---------------------------------------------------------------------------
# Page-Hinkley on known sequences
# ---------------------------------------------------------------------------


def test_page_hinkley_detects_sustained_shift():
    ph = PageHinkley(delta=0.5, lam=6.0, warmup=8, direction=1)
    # a ~2-sigma sustained shift: too small for a 4-sigma EWMA alarm,
    # but PH accumulates it
    seq = _steady(40) + [108.0 + _noise(i) for i in range(40, 80)]
    onset_at = -1
    for i, x in enumerate(seq):
        if ph.update(x) == "onset":
            onset_at = i
            break
    assert 40 <= onset_at <= 55, f"onset at {onset_at}"


def test_page_hinkley_no_alert_on_steady_noise():
    ph = PageHinkley(delta=0.5, lam=6.0, warmup=8, direction=1)
    assert all(ph.update(x) != "onset" for x in _steady(300))


def test_page_hinkley_keeps_stat_readable_at_onset():
    ph = PageHinkley(delta=0.5, lam=6.0, warmup=8, direction=1)
    seq = _steady(30) + [400.0 + _noise(i) for i in range(30, 40)]
    for x in seq:
        if ph.update(x) == "onset":
            break
    assert ph.active and ph.stat > ph.lam


def test_detector_bank_determinism_across_reruns():
    seq = _steady(35) + [420.0 + _noise(i) for i in range(35, 70)]

    def run():
        bank = DetectorBank()
        for i, x in enumerate(seq):
            bank.observe(i, float(i), {"rate": x, "commit_ms": x / 10.0})
        return [(e.series, e.detector, e.phase, e.tick, e.value,
                 e.score) for e in bank.events]

    a, b = run(), run()
    assert a == b and len(a) > 0


def test_detector_bank_skips_absent_series():
    bank = DetectorBank()
    for i in range(50):
        bank.observe(i, float(i), {"rate": 100.0 + _noise(i),
                                   "commit_ms": None})
    assert bank.first_onset_tick("commit_ms") == -1
    assert bank.first_onset_tick("rate") == -1
    assert bank.active_alerts() == []


# ---------------------------------------------------------------------------
# SLO burn-rate window arithmetic
# ---------------------------------------------------------------------------


def test_slo_burn_rate_window_arithmetic():
    spec = SLOSpec("lat", "ms", "<=", 10.0, budget=0.25,
                   short_window=4, long_window=8, burn_alert=2.0)
    tr = SLOTracker([spec])
    # 4 good ticks, then sustained breach
    fired = []
    for i in range(12):
        v = 5.0 if i < 4 else 50.0
        fired += tr.observe(i, float(i), {"ms": v})
    s = tr.summary()["lat"]
    # short window saturates at 4/4 breaches -> burn = 1.0/0.25 = 4.0
    assert s["max_burn_short"] == pytest.approx(4.0)
    # long window peaks at 8/8 once the deque fills with breaches
    assert s["max_burn_long"] == pytest.approx(4.0)
    assert s["breaches"] == 8 and s["ticks"] == 12
    assert s["budget_consumed"] == pytest.approx((8 / 12) / 0.25, abs=1e-3)
    assert s["met"] is False
    # the alert fires only once BOTH windows burn >= 2.0 with history:
    # short hits 2.0 at tick 6 (2/4 bad), long needs 4/8 -> tick 7
    onsets = [f for f in fired if f["phase"] == "onset"]
    assert len(onsets) == 1 and onsets[0]["tick"] == 7
    assert s["first_breach_tick"] == 4 and s["first_alert_tick"] == 7


def test_slo_alert_clears_when_burn_cools():
    spec = SLOSpec("lat", "ms", "<=", 10.0, budget=0.5,
                   short_window=3, long_window=6, burn_alert=1.5)
    tr = SLOTracker([spec])
    seq = [50.0] * 8 + [5.0] * 8
    phases = []
    for i, v in enumerate(seq):
        phases += [f["phase"] for f in tr.observe(i, float(i), {"ms": v})]
    assert phases == ["onset", "clear"]
    assert tr.active_alerts() == []


def test_slo_none_values_not_evaluated():
    tr = SLOTracker([SLOSpec("x", "m", "<=", 1.0, budget=0.1)])
    for i in range(10):
        tr.observe(i, float(i), {"m": None})
    s = tr.summary()["x"]
    assert s["ticks"] == 0 and s["breaches"] == 0 and s["met"] is True


def test_default_slos_checkpoint_cadence_gated():
    names = {s.name for s in default_slos()}
    assert "checkpoint_cadence" not in names
    withc = {s.name: s for s in default_slos(checkpoint_every=5)}
    assert withc["checkpoint_cadence"].target == 10.0
    # mu bound tracks the Algorithm-2 escalation threshold
    mu = {s.name: s for s in default_slos(cpu_max=0.55, theta2=0.25)}
    assert mu["mu_bounded"].target == pytest.approx(0.55 * 1.25)


# ---------------------------------------------------------------------------
# decision-quality scoring
# ---------------------------------------------------------------------------


def _rec(action, mu_pred, mu_real, seq=0):
    return AuditRecord(seq=seq, t=float(seq), ts_ns=0, shard=0,
                       action=action, reason="", beta=1500,
                       beta_e_pred=1400.0, mu_pred=mu_pred, slope=0.01,
                       inputs={}, mu_real=mu_real,
                       beta_e_real=None if mu_real is None else 1400.0)


def test_quality_perfect_push_scores_one():
    q = score_record(_rec("push", 0.40, 0.40), cpu_max=0.55)
    assert q["score"] == 1.0 and q["resolved"] and not q["overload"]
    assert q["regret"] == 0.0


def test_quality_unresolved_is_neutral():
    r = _rec("push", 0.40, None)
    q = score_record(r, cpu_max=0.55)
    assert q == {"resolved": False, "score": 1.0, "mu_abs_err": None,
                 "cost": None, "baseline_cost": None, "regret": None,
                 "overload": False, "overcautious": False}
    assert r.quality is q


def test_quality_overload_and_overcaution_flags():
    over = score_record(_rec("push", 0.50, 0.80), cpu_max=0.55)
    assert over["overload"] and over["score"] < 1.0
    # held while the consumer demonstrably had headroom: overcautious,
    # and the do-nothing baseline (mu_pred under cpu_max) prices regret
    cautious = score_record(_rec("hold", 0.30, 0.10), cpu_max=0.55)
    assert cautious["overcautious"] and cautious["regret"] > 0.0
    assert cautious["score"] < 1.0
    # a hold that dodged a predicted overload beats do-nothing
    wise = score_record(_rec("throttle", 0.90, 0.50), cpu_max=0.55)
    assert wise["regret"] < 0.0 and not wise["overcautious"]


def test_score_trail_aggregates_and_attaches():
    audit = [_rec("push", 0.4, 0.4, 0), _rec("hold", 0.3, 0.1, 1),
             _rec("push", 0.5, 0.8, 2), _rec("push", 0.4, None, 3)]
    agg = score_trail(audit, cpu_max=0.55)
    assert agg["decisions"] == 4 and agg["resolved"] == 3
    assert agg["overload_decisions"] == 1
    assert agg["overcautious_decisions"] == 1
    assert 0.0 < agg["controller_score"] < 1.0
    assert all(r.quality is not None for r in audit)
    by_action = per_action_scores(audit)
    assert by_action["push"]["n"] == 3 and by_action["hold"]["n"] == 1


def test_score_trail_empty_is_perfect():
    agg = score_trail([], cpu_max=0.55)
    assert agg["controller_score"] == 1.0 and agg["decisions"] == 0


# ---------------------------------------------------------------------------
# SeriesTap delta math
# ---------------------------------------------------------------------------


def test_series_tap_hist_and_counter_deltas():
    reg = TelemetryRegistry()
    tap = SeriesTap(reg)
    with reg.span("commit.upsert"):
        pass
    h1 = tap.hist_delta("commit.upsert")
    assert h1.count == 1
    reg.counters["drop"] += 7
    assert tap.counter_delta("drop") == 7
    # second poll sees only what happened since the first
    with reg.span("commit.upsert"):
        pass
    with reg.span("commit.upsert"):
        pass
    h2 = tap.hist_delta("commit.upsert")
    assert h2.count == 2
    assert tap.counter_delta("drop") == 0
    # an idle interval yields an empty delta, not a crash
    assert tap.hist_delta("commit.upsert").count == 0


# ---------------------------------------------------------------------------
# HealthMonitor on a synthetic event stream (no pipeline)
# ---------------------------------------------------------------------------


class _Ev:
    def __init__(self, kind, t, **payload):
        self.kind, self.t, self.payload = kind, t, payload


def _drive(mon, n=50, burst_at=30):
    for i in range(n):
        kept = 100.0 + _noise(i) + (400.0 if i >= burst_at else 0.0)
        mon.on_event(_Ev("tick", float(i), kept=int(kept), raw=int(kept)))
        mon.on_event(_Ev("push", float(i), records=int(kept)))
        mon.on_event(_Ev("sample", float(i), mu=0.4, spill_depth=0))
    mon.on_event(_Ev("report", float(n)))


def test_monitor_detects_synthetic_burst_and_is_deterministic():
    def run():
        from repro.api import MetricsHub
        hub = MetricsHub(telemetry=TelemetryRegistry())
        mon = HealthMonitor(slos=default_slos())
        mon.bind(hub)
        _drive(mon)
        return mon

    a, b = run(), run()
    assert 30 <= a.burst_onset_tick("rate") <= 33
    ra, rb = a.report(), b.report()
    assert ra["health_events"] == rb["health_events"]
    assert ra["slo"] == rb["slo"]
    assert json.dumps(ra, sort_keys=True)  # JSON-safe


def test_monitor_finish_is_idempotent():
    from repro.api import MetricsHub
    hub = MetricsHub(telemetry=TelemetryRegistry())
    mon = HealthMonitor()
    mon.bind(hub)
    _drive(mon, n=20, burst_at=99)
    mon.finish()
    first = mon.report()
    mon.finish()
    assert mon.report() == first


# ---------------------------------------------------------------------------
# end-to-end acceptance: flash_crowd under the monitor
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def flash_run(tmp_path_factory):
    reg = TelemetryRegistry()
    rep = run_scenario(
        "flash_crowd", ticks=60, seed=7, speed=0.5,
        node_cap=1 << 12, edge_cap=1 << 14,
        spill_dir=str(tmp_path_factory.mktemp("monitor_spill")),
        telemetry=reg, monitor=True)
    return rep, reg


def test_flash_crowd_burst_onset_bounded(flash_run):
    rep, _ = flash_run
    assert rep.monitor_enabled
    # the scenario's rate step is at t=30.0 (tick 29/30); the monitor
    # must timestamp the onset within a few ticks of it
    assert 28 <= rep.burst_onset_tick <= 36
    assert any(e["series"] == "rate" and e["phase"] == "onset"
               for e in rep.health_events)


def test_flash_crowd_breaches_an_slo_with_burn_rate(flash_run):
    rep, _ = flash_run
    missed = {n: s for n, s in rep.slo_summary.items() if not s["met"]}
    assert missed, "flash_crowd at half-capacity must breach an SLO"
    assert any(s["max_burn_short"] > 1.0 for s in missed.values())
    assert rep.slo_breaches > 0


def test_flash_crowd_every_decision_scored(flash_run):
    rep, reg = flash_run
    assert len(reg.audit) > 0
    assert all(r.quality is not None for r in reg.audit)
    assert rep.decision_quality["decisions"] == len(reg.audit)
    assert 0.0 <= rep.controller_score <= 1.0
    assert "controller_score=" in rep.summary()
    assert json.dumps(rep.to_dict())


def test_flash_crowd_prometheus_and_dashboard(flash_run):
    rep, reg = flash_run
    # the harness-owned monitor is reachable for exposition through
    # the registry-independent surface: rebuild text from the report
    text = prometheus_text(registry=reg)
    assert "repro_events_total" in text
    assert 'repro_stage_latency_seconds_bucket{stage="commit.upsert"' in text
    assert text.endswith("\n")


def test_monitor_exposition_with_live_monitor():
    from repro.api import MetricsHub
    hub = MetricsHub(telemetry=TelemetryRegistry())
    mon = HealthMonitor(slos=default_slos())
    mon.bind(hub)
    _drive(mon)
    text = prometheus_text(monitor=mon, registry=hub.telemetry)
    assert "repro_controller_score" in text
    assert 'repro_monitor_series{series="rate"}' in text
    assert "repro_slo_budget_consumed" in text
    dash = render_dashboard(mon)
    assert "repro.monitor" in dash and "SLO" in dash
    verdict = text_report(mon)
    assert "monitor verdict" in verdict and "controller score" in verdict


# ---------------------------------------------------------------------------
# perf-regression gate
# ---------------------------------------------------------------------------


def _fake_run(run_idx, commit_ms=50.0, score=0.85):
    return {"run": run_idx, "benches": {
        "ingest_trajectory": {"derived": {"commit_ms_mean": commit_ms,
                                          "dropped_total": 1000.0,
                                          "probe_rounds_max": 64.0}},
        "monitor_overhead": {"derived": {"overhead_pct": 1.0,
                                         "controller_score": score}},
    }}


def test_gate_passes_on_identical_runs(tmp_path):
    path = str(tmp_path / "BENCH_ingest.json")
    with open(path, "w") as f:
        json.dump({"runs": [_fake_run(0), _fake_run(1)]}, f)
    v = gate(path, baseline=0, candidate=-1)
    assert v["ok"] and not v["regressions"]
    assert "OK" in format_verdict(v)


def test_gate_trips_on_2x_commit_latency(tmp_path):
    path = str(tmp_path / "BENCH_ingest.json")
    with open(path, "w") as f:
        json.dump({"runs": [_fake_run(0), _fake_run(1, commit_ms=100.0)]}, f)
    v = gate(path, baseline=0, candidate=-1)
    assert not v["ok"] and v["regressions"] == ["commit_ms_mean"]
    assert "REGRESSED" in format_verdict(v)


def test_gate_noise_tolerance_and_floor():
    # +30% is inside the 35% tolerance: stable
    v = compare_runs(_fake_run(0), _fake_run(1, commit_ms=65.0))
    assert v["ok"]
    # a big relative move under the absolute floor is also stable
    spec = (MetricSpec("commit_ms_mean",
                       ("ingest_trajectory", "derived", "commit_ms_mean"),
                       floor=2.0),)
    v = compare_runs(_fake_run(0, commit_ms=0.5),
                     _fake_run(1, commit_ms=1.5), metrics=spec)
    assert v["ok"]


def test_gate_controller_score_is_higher_better():
    v = compare_runs(_fake_run(0, score=0.9), _fake_run(1, score=0.5))
    assert "controller_score" in v["regressions"]
    v = compare_runs(_fake_run(0, score=0.5), _fake_run(1, score=0.9))
    assert v["ok"]


def test_gate_inject_mutation_path():
    v = compare_runs(_fake_run(0), _fake_run(1),
                     mutate=lambda m: m.__setitem__(
                         "commit_ms_mean", m["commit_ms_mean"] * 2.0))
    assert v["regressions"] == ["commit_ms_mean"]


def test_gate_legacy_single_run_file(tmp_path):
    path = str(tmp_path / "BENCH_ingest.json")
    with open(path, "w") as f:
        json.dump(_fake_run(0)["benches"], f)
    v = gate(path, baseline=0, candidate=0)
    assert v["ok"] and v["runs_in_trajectory"] == 1


def test_gate_skips_metrics_missing_from_either_run(tmp_path):
    old = {"run": 0, "benches": {"ingest_trajectory": {
        "derived": {"commit_ms_mean": 50.0}}}}
    v = compare_runs(old, _fake_run(1))
    assert v["compared"] == 1 and "controller_score" in v["skipped"]


def test_gate_cli_exit_codes(tmp_path):
    from repro.launch.monitor import main as monitor_main
    path = str(tmp_path / "BENCH_ingest.json")
    with open(path, "w") as f:
        json.dump({"runs": [_fake_run(0), _fake_run(1)]}, f)
    assert monitor_main(["regression", "--bench", path]) == 0
    assert monitor_main(["regression", "--bench", path,
                         "--inject", "commit_ms_mean",
                         "--inject-factor", "2.0"]) == 1
    assert monitor_main(["regression", "--bench",
                         str(tmp_path / "missing.json")]) == 2


def test_merge_bench_ingest_preserves_corrupt_file(tmp_path):
    from benchmarks.run import merge_bench_ingest
    path = str(tmp_path / "BENCH_ingest.json")
    with open(path, "w") as f:
        f.write("{ not json !!")
    n = merge_bench_ingest(path, {"ingest_trajectory": {"derived": {}}})
    assert n == 1
    assert os.path.exists(path + ".bak-0")
    with open(path + ".bak-0") as f:
        assert f.read().startswith("{ not json")
    with open(path) as f:
        assert len(json.load(f)["runs"]) == 1
    # a second corruption gets the next bak index
    with open(path, "w") as f:
        f.write("also not json")
    merge_bench_ingest(path, {"ingest_trajectory": {"derived": {}}})
    assert os.path.exists(path + ".bak-1")


# ---------------------------------------------------------------------------
# exporter edge cases (satellite: telemetry hardening)
# ---------------------------------------------------------------------------


def test_empty_registry_exports_cleanly(tmp_path):
    reg = TelemetryRegistry()
    trace = chrome_trace(reg)
    assert trace["traceEvents"] == []
    assert json.dumps(trace)
    p = write_jsonl(reg, str(tmp_path / "spans.jsonl"))
    lines = [json.loads(l) for l in open(p)]
    assert lines[0]["type"] == "meta" and lines[0]["events_dropped"] == 0
    assert summary_tsv(reg).startswith("stage\t")
    assert "no spans recorded" in text_summary(reg)


def test_unresolved_audit_record_exports_cleanly(tmp_path):
    reg = TelemetryRegistry()
    reg.audit.append(_rec("hold", 0.3, None))      # never resolved
    reg.audit.append(AuditRecord(                   # sparse inputs
        seq=1, t=1.0, ts_ns=0, shard=0, action="push", reason="",
        beta=1500, beta_e_pred=1400.0, mu_pred=0.4, slope=0.0,
        inputs={"rate": 10.0}, mu_real=0.41, beta_e_real=1400.0))
    trace = chrome_trace(reg)
    assert json.dumps(trace)
    p = write_jsonl(reg, str(tmp_path / "audit.jsonl"))
    lines = [json.loads(l) for l in open(p)]
    audits = [l for l in lines if l["type"] == "audit"]
    assert audits[0]["realized"] is None
    assert audits[1]["realized"] == {"mu": 0.41, "beta_e": 1400.0}
    # the text timeline tolerates missing PerfMon keys (no KeyError)
    assert "push" in text_summary(reg)


def test_dropped_span_warning_in_tsv_and_jsonl(tmp_path):
    reg = TelemetryRegistry(max_events=1)
    for _ in range(3):
        with reg.span("tick"):
            pass
    assert reg.events_dropped == 2
    assert "# WARNING: 2 span events dropped" in summary_tsv(reg)
    assert "2 span events dropped" in text_summary(reg)
    p = write_jsonl(reg, str(tmp_path / "x.jsonl"))
    meta = json.loads(open(p).readline())
    assert meta["events_dropped"] == 2 and meta["spans"] == 1
    assert chrome_trace(reg)["otherData"]["events_dropped"] == 2
