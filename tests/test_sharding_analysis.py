"""Sharding-rule resolution + HLO loop-expansion analyzer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    ParamSpec,
    ShardingRules,
    logical_to_spec,
    spec_avals,
)
from repro.launch.hlo_analysis import analyze, shape_bytes, split_computations
from repro.launch.jaxpr_flops import traced_flops


def _mesh(shape=(2, 2), axes=("data", "model")):
    # AbstractMesh: rule resolution is shape-only, no devices needed
    return jax.sharding.AbstractMesh(shape, axes)


def test_logical_rules_basic():
    mesh = _mesh((1, 1))
    rules = ShardingRules.default()
    spec = logical_to_spec(("fsdp", "mlp"), mesh, rules, dims=(64, 128))
    assert spec == P("data", "model")


def test_divisibility_fallback():
    """kv=2 heads cannot shard over model=16: falls back to replicated."""
    mesh = _mesh((1, 4))
    rules = ShardingRules.default()
    spec = logical_to_spec(("fsdp", "kv_heads", None), mesh, rules, dims=(64, 2, 16))
    assert spec == P("data", None, None)


def test_axis_used_once():
    """Two logical dims mapping to the same mesh axis: first wins."""
    mesh = _mesh((2, 2))
    rules = ShardingRules.default()
    spec = logical_to_spec(("kv_len", "kv_heads"), mesh, rules, dims=(64, 8))
    assert spec == P("model", None)


def test_spec_avals_shapes():
    s = {"w": ParamSpec((4, 8), ("fsdp", "mlp"))}
    av = spec_avals(s)
    assert av["w"].shape == (4, 8) and av["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# HLO analyzer: loop expansion must match the jaxpr-level count
# ---------------------------------------------------------------------------


def test_analyzer_matches_jaxpr_on_scan():
    L, B, D = 7, 64, 256

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    g = jax.grad(f)
    compiled = jax.jit(g).lower(ws, x).compile()
    st = analyze(compiled.as_text())
    want = traced_flops(g, ws, x)
    assert abs(st.flops - want) / want < 0.05
    # and the XLA raw count must be an under-count (bodies once)
    xla = compiled.cost_analysis().get("flops", 0)
    assert xla < want / 2


def test_analyzer_matches_xla_on_loop_free():
    def f(w1, w2, x):
        return jnp.tanh(jnp.maximum(x @ w1, 0) @ w2).sum()

    a = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)
    compiled = jax.jit(jax.grad(f, argnums=(0, 1))).lower(
        a((128, 256)), a((256, 128)), a((32, 128))
    ).compile()
    st = analyze(compiled.as_text())
    ca = compiled.cost_analysis()
    assert abs(st.bytes - ca["bytes accessed"]) / ca["bytes accessed"] < 0.05
    assert abs(st.flops - ca["flops"]) / ca["flops"] < 0.05


def test_shape_bytes_parses_tuples():
    s = "(f32[16,128]{1,0}, s32[], bf16[7,64]{1,0})"
    assert shape_bytes(s) == 16 * 128 * 4 + 4 + 7 * 64 * 2


def test_split_computations_nested_parens():
    txt = (
        "ENTRY %main.7 (a: (s32[], f32[2,2])) -> f32[2,2] {\n"
        "  %p = (s32[], f32[2,2]) parameter(0)\n"
        "}\n"
        "%helper (b: f32[2]) -> f32[2] {\n"
        "  %q = f32[2] parameter(0)\n"
        "}\n"
    )
    comps = split_computations(txt)
    assert "main.7" in comps and "helper" in comps


def test_mesh_construction():
    from repro.launch.mesh import dp_size, make_dev_mesh

    m = make_dev_mesh()
    assert dp_size(m) >= 1
    assert set(m.axis_names) == {"data", "model"}
