"""Per-kernel shape/dtype sweeps, assert_allclose against ref.py oracles
(kernels run in interpret mode on CPU; same call sites compile on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.edge_dedup import sort_dedup
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

# ---------------------------------------------------------------------------
# edge_dedup
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 256, 1024, 4096])
@pytest.mark.parametrize("dup_range", [5, 1000, 2**31])
def test_sort_dedup_sweep(n, dup_range, rng):
    keys = jnp.asarray(rng.integers(0, dup_range, size=n).astype(np.uint32))
    sk, order, head = sort_dedup(keys, interpret=True)
    sk_r, _, head_r = ref.sort_dedup_ref(keys)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sk_r))
    np.testing.assert_array_equal(np.asarray(head), np.asarray(head_r))
    # order is a valid permutation that sorts keys
    assert sorted(np.asarray(order).tolist()) == list(range(n))
    np.testing.assert_array_equal(np.asarray(keys)[np.asarray(order)], np.asarray(sk))


def test_dedup_counts_match_numpy(rng):
    keys = jnp.asarray(rng.integers(0, 37, size=512).astype(np.uint32))
    sk, order, head = ops.sort_dedup(keys)
    counts, nu = ops.dedup_sorted_counts(sk, head)
    vals, cts = np.unique(np.asarray(keys), return_counts=True)
    assert int(nu) == len(vals)
    np.testing.assert_array_equal(np.asarray(counts[: len(vals)]), cts)


# ---------------------------------------------------------------------------
# bloom
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,rows", [(64, 2), (256, 4), (1024, 16)])
def test_bloom_build_matches_ref(n, rows, rng):
    keys = jnp.asarray(rng.integers(1, 2**31, size=n).astype(np.uint32))
    bm = jnp.zeros((rows, 1024), jnp.uint32)
    out = ops.bloom_build(keys, bm)
    want = ref.bloom_build_ref(np.asarray(keys), np.asarray(bm))
    np.testing.assert_array_equal(np.asarray(out), want)


def test_bloom_no_false_negatives(rng):
    keys = jnp.asarray(rng.integers(1, 2**31, size=500).astype(np.uint32))
    bm = ops.bloom_build(keys, jnp.zeros((16, 1024), jnp.uint32))
    hit = ops.bloom_probe(keys, bm)
    assert bool((np.asarray(hit) == 1).all())


def test_bloom_low_false_positive_rate(rng):
    seen = jnp.asarray(rng.integers(1, 2**30, size=1000).astype(np.uint32))
    bm = ops.bloom_build(seen, jnp.zeros((16, 1024), jnp.uint32))
    fresh = jnp.asarray((rng.integers(1, 2**30, size=2000) + 2**30).astype(np.uint32))
    fp = float(np.asarray(ops.bloom_probe(fresh, bm)).mean())
    assert fp < 0.05, fp


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,d,bq,bk", [(128, 32, 32, 32), (256, 64, 64, 128), (512, 128, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(S, d, bq, bk, dtype, causal):
    BH = 3
    q = jax.random.normal(jax.random.key(0), (BH, S, d), dtype)
    k = jax.random.normal(jax.random.key(1), (BH, S, d), dtype)
    v = jax.random.normal(jax.random.key(2), (BH, S, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_sliding_window():
    BH, S, d = 2, 256, 64
    q = jax.random.normal(jax.random.key(0), (BH, S, d))
    k = jax.random.normal(jax.random.key(1), (BH, S, d))
    v = jax.random.normal(jax.random.key(2), (BH, S, d))
    out = flash_attention(q, k, v, causal=True, window=64, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,p,N,chunk", [(64, 16, 8, 16), (128, 32, 16, 32), (256, 64, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(S, p, N, chunk, dtype):
    BH = 2
    x = jax.random.normal(jax.random.key(0), (BH, S, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (BH, S))).astype(dtype)
    A = -jnp.abs(jax.random.normal(jax.random.key(2), (BH,)))
    B = jax.random.normal(jax.random.key(3), (BH, S, N), dtype)
    C = jax.random.normal(jax.random.key(4), (BH, S, N), dtype)
    y, hT = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_r, hT_r = ref.ssd_scan_ref(x, dt, A, B, C)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_r, np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_r), atol=tol, rtol=tol)


def test_ssd_model_chunked_matches_bruteforce():
    """The model's chunked SSD (used in training) == sequential recurrence."""
    from repro.models.mamba2 import ssd_chunked

    B_, S, nh, p, N = 2, 96, 3, 8, 4
    xh = jax.random.normal(jax.random.key(0), (B_, S, nh, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (B_, S, nh)))
    A = -jnp.abs(jax.random.normal(jax.random.key(2), (nh,)))
    Bs = jax.random.normal(jax.random.key(3), (B_, S, N))
    Cs = jax.random.normal(jax.random.key(4), (B_, S, N))
    y, hT = ssd_chunked(xh, dt, A, Bs, Cs, chunk=32)
    # brute force via the kernel oracle, vmapped over heads (B,C shared)
    x_f = xh.transpose(0, 2, 1, 3).reshape(B_ * nh, S, p)
    dt_f = dt.transpose(0, 2, 1).reshape(B_ * nh, S)
    A_f = jnp.tile(A, (B_,))
    B_f = jnp.repeat(Bs, nh, axis=0)
    C_f = jnp.repeat(Cs, nh, axis=0)
    y_r, hT_r = ref.ssd_scan_ref(x_f, dt_f, A_f, B_f, C_f)
    y_r = y_r.reshape(B_, nh, S, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), atol=2e-4, rtol=2e-4)
