"""Fused GRAPHPUSH commit kernel + incremental CSR snapshots.

Covers the PR-3 hot-path rewrite: Pallas-vs-jnp-oracle parity of the
fused upsert, the 6 -> 2 probe-loop contract of `ingest_step`, the
adaptive probe budget under table pressure (hypothesis property: a key
is only dropped when its escalated probe window is genuinely
exhausted), the table-pressure -> controller back-pressure, and
bit-exact equivalence of `apply_delta` / `SnapshotMaintainer` against
full `build_snapshot` recompaction after N random commits.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_table import from_raw_batch
from repro.core.transform import RawEdgeBatch
from repro.graphstore.store import (
    MAX_PROBES,
    count_probe_loops,
    ingest_step,
    init_store,
    probe_budget,
)
from repro.kernels import ops
from repro.kernels.upsert import fused_upsert, fused_upsert_ref, probe_hash
from repro.query.snapshot import (
    SnapshotMaintainer,
    apply_delta,
    build_snapshot,
)


def _raw(src, dst, etype):
    n = len(src)
    return RawEdgeBatch(
        src=np.asarray(src, np.uint64), dst=np.asarray(dst, np.uint64),
        etype=np.asarray(etype, np.int32),
        src_type=np.zeros(n, np.int32), dst_type=np.zeros(n, np.int32),
        n_records=n,
    )


def _table(rng, n=256, n_keys=60, cap=512, n_types=3):
    src = rng.integers(1, n_keys, size=n)
    dst = rng.integers(1, n_keys, size=n)
    et = rng.integers(0, n_types, size=n)
    return from_raw_batch(_raw(src, dst, et), cap)


def _assert_snapshots_equal(got, want, msg=""):
    for f in dataclasses.fields(want):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f.name)), np.asarray(getattr(want, f.name)),
            err_msg=f"{msg}{f.name}")


# ---------------------------------------------------------------------------
# fused upsert: kernel parity + invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cap,n,probes", [(128, 64, 32), (512, 256, 64),
                                          (1024, 128, 128)])
def test_fused_upsert_kernel_matches_oracle(cap, n, probes, rng):
    keys = jnp.asarray(
        rng.choice(np.arange(1, 1 << 30, dtype=np.uint32), size=n,
                   replace=False))
    valid = jnp.asarray(rng.random(n) < 0.9)
    # pre-populate some slots so hits, claims and races all occur
    table = jnp.zeros((cap,), jnp.uint32)
    table, _, _ = fused_upsert_ref(table, keys[: n // 2], valid[: n // 2],
                                   jnp.int32(probes))
    got = fused_upsert(table, keys, valid, jnp.int32(probes), interpret=True)
    want = fused_upsert_ref(table, keys, valid, jnp.int32(probes))
    for g, w, name in zip(got, want, ("table", "slot", "is_new")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_fused_upsert_idempotent_and_consistent(rng):
    cap, n = 512, 256
    keys = jnp.asarray(
        rng.choice(np.arange(1, 1 << 30, dtype=np.uint32), size=n,
                   replace=False))
    valid = jnp.ones((n,), bool)
    table0 = jnp.zeros((cap,), jnp.uint32)
    table1, slot1, new1 = ops.fused_upsert(table0, keys, valid, MAX_PROBES)
    s1 = np.asarray(slot1)
    placed = s1 >= 0
    assert np.asarray(new1)[placed].all()  # empty table: every placed is new
    # placed keys occupy distinct slots holding exactly their key
    assert len(set(s1[placed])) == placed.sum()
    assert (np.asarray(table1)[s1[placed]] == np.asarray(keys)[placed]).all()
    # re-upsert: pure lookup — same slots, nothing new, table unchanged
    table2, slot2, new2 = ops.fused_upsert(table1, keys, valid, MAX_PROBES)
    np.testing.assert_array_equal(np.asarray(table2), np.asarray(table1))
    np.testing.assert_array_equal(np.asarray(slot2)[placed], s1[placed])
    assert not np.asarray(new2).any()


def test_probe_budget_escalates_with_load():
    cap = 1000
    assert int(probe_budget(jnp.int32(100), cap)) == MAX_PROBES
    assert int(probe_budget(jnp.int32(599), cap)) == MAX_PROBES
    assert int(probe_budget(jnp.int32(600), cap)) == 2 * MAX_PROBES
    assert int(probe_budget(jnp.int32(799), cap)) == 2 * MAX_PROBES
    assert int(probe_budget(jnp.int32(800), cap)) == 4 * MAX_PROBES


def test_high_load_drops_only_when_probing_exhausted():
    """Hypothesis property: fill a table to >= 0.8 load; the fused
    upsert must not drop a key while an empty slot remains inside its
    (adaptively escalated) probe window, placed keys stay retrievable,
    and escalation never drops more than the fixed seed budget."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cap, chunk = 256, 64

    def fill(keys, adaptive: bool):
        table = jnp.zeros((cap,), jnp.uint32)
        placed_mask = np.zeros(len(keys), bool)
        placed, dropped = 0, []
        for lo in range(0, len(keys), chunk):
            part = keys[lo: lo + chunk]
            batch = np.zeros(chunk, np.uint32)
            batch[: len(part)] = part
            valid = jnp.arange(chunk) < len(part)
            bud = (probe_budget(jnp.int32(placed), cap) if adaptive
                   else jnp.int32(MAX_PROBES))
            table, slot, _ = ops.fused_upsert(
                table, jnp.asarray(batch), valid, bud)
            slot = np.asarray(slot)[: len(part)]
            placed_mask[lo: lo + len(part)] = slot >= 0
            placed += int((slot >= 0).sum())
            dropped += [(k, int(bud)) for k, s in zip(part, slot) if s < 0]
        return np.asarray(table), dropped, placed, placed_mask

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10_000), load=st.floats(0.8, 0.92))
    def check(seed, load):
        rng = np.random.default_rng(seed)
        keys = rng.choice(np.arange(1, 1 << 30, dtype=np.uint32),
                          size=int(cap * load), replace=False)
        table, dropped, placed, placed_mask = fill(keys, adaptive=True)
        # every drop is a genuine exhaustion: all probe-window slots
        # are occupied by OTHER keys (slots never free up, so checking
        # the final table is sound)
        for key, bud in dropped:
            cand = np.asarray(probe_hash(
                jnp.full((bud,), key, jnp.uint32), cap,
                jnp.arange(bud, dtype=jnp.int32)))
            window = table[cand]
            assert (window != 0).all() and (window != key).all(), \
                f"key {key} dropped with a free/own slot in its window"
        # placed keys are retrievable (upsert of them is a pure lookup)
        _, slot2, new2 = ops.fused_upsert(
            jnp.asarray(table), jnp.asarray(keys), jnp.asarray(placed_mask),
            probe_budget(jnp.int32(placed), cap))
        s2 = np.asarray(slot2)
        assert (s2 >= 0).sum() == placed
        assert not np.asarray(new2).any()
        # adaptive probing dominates the fixed seed budget
        _, dropped_fixed, _, _ = fill(keys, adaptive=False)
        assert len(dropped) <= len(dropped_fixed)

    check()


# ---------------------------------------------------------------------------
# ingest_step: structural + stats contracts
# ---------------------------------------------------------------------------


def test_commit_runs_exactly_two_probe_loops(rng):
    # the acceptance criterion of the fused rewrite: 6 -> 2 probe loops
    assert count_probe_loops(_table(rng)) == 2


def test_ingest_step_reports_pressure_stats(rng):
    store = init_store(1 << 10, 1 << 12)
    store, stats = ingest_step(store, _table(rng))
    assert int(stats["probe_rounds"]) == MAX_PROBES  # near-empty tables
    assert int(stats["dropped_inserts"]) == 0
    assert 0.0 < float(stats["node_load"]) < 0.1
    # re-ingesting the same batch creates nothing new but counts up
    before = int(np.asarray(store.edge_count).sum())
    store2, stats2 = ingest_step(store, _table(np.random.default_rng(0)))
    assert int(stats2["new_nodes"]) == 0 and int(stats2["new_edges"]) == 0
    assert int(np.asarray(store2.edge_count).sum()) == 2 * before
    # degree invariant survives the fused/slot-reuse path
    assert int(np.asarray(store2.node_degree).sum()) == 2 * int(store2.n_edges)


def test_ingest_step_escalates_probes_under_load(rng):
    # the budget is computed from the PRE-commit load factor
    store = init_store(256, 1 << 12)
    store, stats = ingest_step(store, _table(rng))
    assert int(stats["probe_rounds"]) == MAX_PROBES
    pressured = dataclasses.replace(store, n_nodes=jnp.int32(170))  # 0.66
    _, stats = ingest_step(pressured, _table(rng))
    assert int(stats["probe_rounds"]) == 2 * MAX_PROBES
    saturated = dataclasses.replace(store, n_nodes=jnp.int32(210))  # 0.82
    _, stats = ingest_step(saturated, _table(rng))
    assert int(stats["probe_rounds"]) == 4 * MAX_PROBES


def test_saturated_store_reports_drops(rng):
    store = init_store(64, 1 << 10)
    total_dropped = 0
    for _ in range(8):
        store, stats = ingest_step(
            store, _table(rng, n=256, n_keys=4000, cap=256))
        total_dropped += int(stats["dropped_inserts"])
    assert int(store.n_nodes) <= 64
    assert total_dropped > 0  # pressure signal fires when truly full


def test_controller_throttles_on_dropped_inserts():
    from repro.configs.paper_ingest import IngestConfig
    from repro.core.buffer import BufferController

    ctl = BufferController(IngestConfig(), spill_dir="/tmp/repro_test_pressure")
    assert ctl.decide(64.0, 0.0).action in ("push", "drain+push")
    ctl.perfmon.observe_pressure(0.97, 12)
    assert ctl.decide(64.0, 0.0).action == "throttle"
    # one-shot: the signal is consumed, the next tick retries the push
    assert ctl.decide(64.0, 0.0).action in ("push", "drain+push")


# ---------------------------------------------------------------------------
# incremental snapshots: apply_delta == build_snapshot, bit-exact
# ---------------------------------------------------------------------------


def test_apply_delta_matches_full_rebuild(rng):
    store = init_store(1 << 10, 1 << 12)
    snap = build_snapshot(store)
    for i in range(6):
        store, stats = ingest_step(store, _table(rng, n_keys=80))
        snap, unplaced = apply_delta(snap, stats["delta"])
        assert int(unplaced) == 0
        _assert_snapshots_equal(snap, build_snapshot(store),
                                msg=f"commit {i}: ")


def test_snapshot_maintainer_serves_exact_views(rng):
    store = init_store(1 << 10, 1 << 12)
    m = SnapshotMaintainer(max_pending=4)
    for i in range(9):
        store, stats = ingest_step(store, _table(rng, n_keys=70))
        m.absorb(None, stats)
        if i % 2 == 1:
            _assert_snapshots_equal(m.snapshot(store), build_snapshot(store),
                                    msg=f"query after commit {i}: ")
    assert m.delta_applies > 0
    assert m.full_builds >= 1  # the initial compaction


def test_snapshot_maintainer_rebuilds_on_overflow(rng):
    store = init_store(1 << 10, 1 << 12)
    m = SnapshotMaintainer(max_pending=2)
    m.snapshot(store)
    for _ in range(4):  # 4 pending > max_pending -> full rebuild
        store, stats = ingest_step(store, _table(rng))
        m.absorb(None, stats)
    _assert_snapshots_equal(m.snapshot(store), build_snapshot(store))
    assert m.full_builds == 2 and m.delta_applies == 0


def test_snapshot_maintainer_rebuilds_on_dangling(rng):
    # 16-node table saturates -> edges with unresolvable endpoints;
    # the maintainer must detect it and serve full rebuilds (exactness
    # beats incrementality)
    store = init_store(16, 1 << 10)
    m = SnapshotMaintainer()
    for i in range(4):
        store, stats = ingest_step(store, _table(rng, n=128, n_keys=500,
                                                 cap=128))
        m.absorb(None, stats)
        _assert_snapshots_equal(m.snapshot(store), build_snapshot(store),
                                msg=f"commit {i}: ")
    assert int(store.n_edges) > int(m.snapshot(store).n_edges)  # dangling


def test_query_sink_incremental_snapshot_end_to_end(tmp_path):
    from repro.api import GraphStoreSink, PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig
    from repro.ingest.sources import BurstyTweetSource

    cfg = IngestConfig(store_nodes=1 << 13, store_edges=1 << 15)
    pipe = (PipelineBuilder(cfg)
            .with_source(BurstyTweetSource(seed=3, mean_rate=40.0))
            .with_sink(GraphStoreSink(node_cap=1 << 13, edge_cap=1 << 15))
            .with_query_sink(depth=2, width=128, answer_every=5, top_k=3)
            .spill_dir(str(tmp_path / "spill"))
            .build())
    pipe.run(max_ticks=12)
    snap1 = pipe.sink.snapshot()
    _assert_snapshots_equal(snap1, build_snapshot(pipe.store))
    pipe.run(max_ticks=8)
    snap2 = pipe.sink.snapshot()  # second query: delta path
    _assert_snapshots_equal(snap2, build_snapshot(pipe.store))
    m = pipe.sink.maintainer
    assert m.delta_applies > 0, "live query must not recompact every time"
