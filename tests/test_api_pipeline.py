"""Composable-API tests: protocol round-trips, builder facade,
wrapper parity, metrics hooks, and the sharded scale-out scenario."""
import numpy as np
import pytest

from repro.api import (
    Consumer,
    FilterStage,
    GraphStoreSink,
    MetricsHub,
    PipelineBuilder,
    ShardedPipeline,
    SimulatedConsumer,
    Sink,
    Source,
    Stage,
    StreamPipeline,
    TransformStage,
)
from repro.api.consumers import MeasuredConsumer
from repro.configs.paper_ingest import IngestConfig
from repro.core.ingestor import GraphIngestor
from repro.core.pipeline import IngestionPipeline
from repro.graphstore.store import init_store
from repro.ingest.sources import BurstyTweetSource, FileReplaySource, StreamTick


# ---------------------------------------------------------------------------
# protocol round-trips
# ---------------------------------------------------------------------------


def test_builtin_parts_satisfy_protocols(tmp_path):
    p = tmp_path / "x.jsonl"
    p.write_text('{"id": "t1", "user": "u1"}\n')
    assert isinstance(BurstyTweetSource(), Source)
    assert isinstance(FileReplaySource(str(p)), Source)
    assert isinstance(FilterStage(), Stage)
    assert isinstance(SimulatedConsumer(), Consumer)
    assert isinstance(MeasuredConsumer(GraphIngestor(init_store(64, 64))), Consumer)
    assert isinstance(GraphStoreSink(node_cap=64, edge_cap=64), Sink)


class ListSource:
    """Custom Source: replays a fixed list of ticks."""

    def __init__(self, ticks_):
        self._ticks = ticks_

    def ticks(self):
        return iter(self._ticks)


class CountingSink:
    """Custom Sink: counts commits, never touches a store."""

    def __init__(self):
        self.commits = 0

    def commit(self, et, now=None):
        self.commits += 1
        return {"committed": True, "rho": 1.0}


class FlatConsumer:
    """Custom Consumer: constant occupancy."""

    def __init__(self, mu=0.2):
        self.mu = mu
        self.calls = 0

    def consume(self, instructions, dt, now=None):
        self.calls += 1
        return self.mu

    @property
    def delay_s(self):
        return 0.0


def _toy_ticks(n=8, per=6):
    return [
        StreamTick(float(t + 1), [
            {"id": f"t{t}_{j}", "user": f"u{j % 3}",
             "hashtags": [f"h{j % 2}"], "mentions": []}
            for j in range(per)
        ])
        for t in range(n)
    ]


def test_custom_source_sink_consumer_roundtrip():
    src = ListSource(_toy_ticks())
    sink = CountingSink()
    consumer = FlatConsumer()
    assert isinstance(src, Source) and isinstance(sink, Sink)
    assert isinstance(consumer, Consumer)
    pipe = StreamPipeline(IngestConfig(), source=src, sink=sink,
                          consumer=consumer, uncontrolled=True,
                          spill_dir="/tmp/repro_spill_api_rt")
    rep = pipe.run(max_ticks=8)
    assert sink.commits == 8
    assert consumer.calls == 8
    assert rep.total_records == 8 * 6
    assert (rep.samples["mu"] == 0.2).all()


# ---------------------------------------------------------------------------
# wrapper parity: the compat IngestionPipeline == builder-built pipeline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("uncontrolled", [False, True])
def test_wrapper_matches_builder_pipeline(uncontrolled):
    kw = dict(seed=9, mean_rate=60, burst_multiplier=5.0)
    old = IngestionPipeline(IngestConfig(), uncontrolled=uncontrolled,
                            spill_dir=f"/tmp/repro_spill_par_a{uncontrolled}")
    r_old = old.run(BurstyTweetSource(**kw).ticks(), max_ticks=50)
    new = (PipelineBuilder(IngestConfig())
           .with_source(BurstyTweetSource(**kw))
           .uncontrolled(uncontrolled)
           .spill_dir(f"/tmp/repro_spill_par_b{uncontrolled}")
           .build())
    r_new = new.run(max_ticks=50)
    assert r_old.total_records == r_new.total_records
    assert r_old.total_instructions == r_new.total_instructions
    assert r_old.actions == r_new.actions
    np.testing.assert_array_equal(r_old.samples["mu"], r_new.samples["mu"])
    np.testing.assert_array_equal(r_old.samples["delay_s"],
                                  r_new.samples["delay_s"])


# ---------------------------------------------------------------------------
# metrics / event hooks
# ---------------------------------------------------------------------------


def test_metrics_hub_hooks_see_loop_events():
    events = []
    pipe = (PipelineBuilder(IngestConfig())
            .with_source(BurstyTweetSource(seed=1))
            .on_event(events.append)
            .spill_dir("/tmp/repro_spill_api_hooks")
            .build())
    rep = pipe.run(max_ticks=30)
    kinds = {e.kind for e in events}
    assert "tick" in kinds and "sample" in kinds
    assert sum(e.kind == "tick" for e in events) == 30
    assert sum(e.kind == "sample" for e in events) == len(rep.actions)
    assert pipe.metrics.counters["push"] == rep.actions.count("push") + \
        rep.actions.count("drain+push")


# ---------------------------------------------------------------------------
# sharded scale-out
# ---------------------------------------------------------------------------


def test_sharded_pipeline_quickstart_scenario():
    """The quickstart scenario end-to-end on >= 2 shards: every shard
    buffer stays bounded by its controller and the shared store fills."""
    cfg = IngestConfig(cpu_max=0.55)
    pipe = (PipelineBuilder(cfg)
            .with_source(BurstyTweetSource(seed=42, mean_rate=60,
                                           burst_multiplier=5.0))
            .sharded(2)
            .spill_dir("/tmp/repro_spill_api_shard2")
            .build())
    assert isinstance(pipe, ShardedPipeline)
    rep = pipe.run(max_ticks=80)
    assert len(rep.shards) == 2
    # every record landed in exactly one shard
    assert sum(r.total_records for r in rep.shards) == rep.total_records
    assert rep.total_records > 0
    # all shard buffers bounded by the controller
    for hwm in rep.max_buffered:
        assert hwm <= cfg.beta_max
    for sr in rep.shards:
        assert set(sr.actions) <= {"push", "hold", "throttle", "drain+push"}
        assert (sr.samples["mu"] <= 1.0).all()
    # shared store got the union of shard commits
    assert int(pipe.store.n_nodes) > 0
    assert int(pipe.store.n_edges) > 0


def test_sharded_partition_is_deterministic_by_user():
    """Same user always routes to the same shard (graph locality)."""
    pipe = ShardedPipeline(IngestConfig(), n_shards=4,
                           spill_dir="/tmp/repro_spill_api_shard4")
    recs = [{"id": f"t{i}", "user": f"u{i % 7}"} for i in range(70)]
    parts_a = pipe._partition(recs)
    parts_b = pipe._partition(recs)
    for a, b in zip(parts_a, parts_b):
        assert a == b
    for part in parts_a:
        assert len({r["user"] for r in part} & {
            r["user"] for other in parts_a for r in other if other is not part
        }) == 0


# ---------------------------------------------------------------------------
# replay source: fractional-rate carry
# ---------------------------------------------------------------------------


def test_file_replay_fractional_rate_no_drift(tmp_path):
    """rate*dt = 4.9 must deliver ~4.9 records/tick on average, not 4."""
    path = tmp_path / "replay.jsonl"
    path.write_text("".join(f'{{"id": "t{i}", "user": "u{i}"}}\n'
                            for i in range(490)))
    src = FileReplaySource(str(path), rate_multiplier=1.0, natural_rate=4.9)
    counts = [len(t.records) for t in src.ticks()]
    # every record delivered, and per-tick counts hit both floor and ceil
    assert sum(counts) == 490
    mean = sum(counts[:-1]) / max(len(counts) - 1, 1)
    assert abs(mean - 4.9) < 0.2
    assert 5 in counts  # the carry must produce ceil ticks sometimes


def test_file_replay_sub_unit_rate(tmp_path):
    """rate*dt < 1 used to floor to zero records forever (and then
    dump the whole file as one EOF burst)."""
    path = tmp_path / "slow.jsonl"
    path.write_text("".join(f'{{"id": "t{i}", "user": "u{i}"}}\n'
                            for i in range(10)))
    src = FileReplaySource(str(path), rate_multiplier=1.0, natural_rate=0.5)
    counts = [len(t.records) for t in src.ticks()]
    assert sum(counts) == 10
    assert max(counts) == 1  # never more than ceil(0.5) per tick
    assert len(counts) == 20  # tail drains at the programmed rate


def test_sharded_consumer_capacity_is_shared_not_multiplied():
    """N shards draining one consumer must split each tick's capacity
    (dt/N each), not each take a full dt — otherwise the shared
    consumer silently becomes N consumers and never saturates."""

    class ProbeConsumer:
        def __init__(self):
            self.dts = []

        def consume(self, instructions, dt, now=None):
            self.dts.append(dt)
            return 0.1

        @property
        def delay_s(self):
            return 0.0

    probe = ProbeConsumer()
    pipe = ShardedPipeline(IngestConfig(), n_shards=2, consumer=probe,
                           sink=CountingSink(),
                           spill_dir="/tmp/repro_spill_api_dt")
    pipe.run(iter(_toy_ticks(n=6, per=8)), max_ticks=6)
    assert probe.dts  # every shard consumed every tick
    assert all(dt == 0.5 for dt in probe.dts)
    assert len(probe.dts) == 6 * 2


def test_sharded_events_forward_to_subscribers_with_shard_tag():
    events = []
    pipe = (PipelineBuilder(IngestConfig())
            .with_source(BurstyTweetSource(seed=2))
            .sharded(2)
            .on_event(events.append)
            .spill_dir("/tmp/repro_spill_api_shard_ev")
            .build())
    pipe.run(max_ticks=20)
    kinds = {e.kind for e in events}
    assert "sample" in kinds and "push" in kinds  # shard loop events arrive
    shard_tags = {e.payload.get("shard") for e in events if e.kind == "sample"}
    assert shard_tags == {0, 1}
