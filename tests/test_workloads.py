"""Workload subsystem tests: samplers (kernel parity, determinism,
skew/burstiness bounds), scenario registry, ScenarioSource, the
closed-loop harness (e2e controller transitions), sketch-guided
control, and the BENCH_ingest.json merge-append format.

Hypothesis-driven parameter sweeps over the same invariants live in
tests/test_property_hypothesis.py (guarded on the hypothesis import);
the checks here are deterministic so they run everywhere."""
import json

import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.sampler import (
    counter_mix,
    traffic_ids,
    traffic_ids_ref,
    uniform01,
    zipf_rank,
)
from repro.workloads import (
    Scenario,
    ScenarioSource,
    get_scenario,
    list_scenarios,
    rate_trajectory,
    register,
    run_scenario,
)

# ---------------------------------------------------------------------------
# sampler kernel: oracle parity + counter-PRNG determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario,burst", [("flash_crowd", 0.0),
                                            ("spam_storm", 0.9)])
def test_traffic_kernel_bit_exact(scenario, burst):
    scn = get_scenario(scenario)
    ip, fp = scn.iparams(), scn.fparams(burst)
    ref = traffic_ids_ref(np.uint32(11), np.uint32(640), 256, ip, fp)
    ker = traffic_ids(np.uint32(11), np.uint32(640), 256, ip, fp,
                      interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_counter_prng_deterministic_and_stream_disjoint():
    ctr = np.arange(512, dtype=np.uint32)
    a = np.asarray(counter_mix(np.uint32(5), ctr))
    b = np.asarray(counter_mix(np.uint32(5), ctr))
    c = np.asarray(counter_mix(np.uint32(6), ctr))
    np.testing.assert_array_equal(a, b)
    assert (a != c).mean() > 0.99  # different seeds decorrelate
    u = np.asarray(uniform01(counter_mix(np.uint32(5), ctr)))
    assert (u >= 0).all() and (u < 1).all()
    assert 0.3 < u.mean() < 0.7


# ---------------------------------------------------------------------------
# sampler invariants (deterministic spot checks; hypothesis sweeps of
# the same properties live in test_property_hypothesis.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,a,n", [(0, 1.2, 100), (7, 1.3, 1000),
                                      (42, 2.5, 5000)])
def test_zipf_skew_bounds(seed, a, n):
    """Ranks stay in [0, n); the top decile holds at least ~70% of the
    bounded-Pareto mass it should (heavy-hitter skew), far above the
    uniform 10%."""
    ctr = np.arange(4096, dtype=np.uint32)
    u = uniform01(counter_mix(np.uint32(seed), ctr))
    r = np.asarray(zipf_rank(u, n, a))
    assert r.min() >= 0 and r.max() < n
    top = max(n // 10, 1)
    share = float((r < top).mean())
    # theoretical bounded-Pareto mass below rank n/10
    expect = ((top + 1) ** (1 - a) - 1) / ((n + 1) ** (1 - a) - 1)
    assert share >= 0.7 * expect
    assert share > 0.3  # always much more skewed than uniform


@pytest.mark.parametrize("scenario", ["flash_crowd", "diurnal"])
def test_rates_nonnegative_and_chunks_compose(scenario):
    scn = get_scenario(scenario)
    args = (scn.base_rate, scn.noise_frac, scn.hawkes_alpha, scn.hawkes_beta,
            scn.diurnal_amp, scn.diurnal_period, scn.flash_t, scn.flash_mult,
            scn.flash_decay, scn.rate_cap_mult * scn.base_rate)
    full = rate_trajectory(np.uint32(5), 128, 0, 0.0, *args)
    rates, counts = np.asarray(full.rates), np.asarray(full.counts)
    assert np.isfinite(rates).all() and (rates >= 0).all()
    assert (counts >= 0).all()
    # two 64-tick chunks with carried Hawkes state == one 128-tick chunk
    c1 = rate_trajectory(np.uint32(5), 64, 0, 0.0, *args)
    c2 = rate_trajectory(np.uint32(5), 64, 64, c1.excite, *args)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(c1.counts), np.asarray(c2.counts)]), counts)


def test_hawkes_burstier_than_poisson_baseline():
    """Fano factor (var/mean of per-tick counts) under strong
    self-excitation far exceeds the Poisson-like alpha=0 baseline."""
    def fano(alpha, seed):
        ch = rate_trajectory(np.uint32(seed), 512, 0, 0.0, 60.0, 0.0,
                             alpha, 0.4, 0.0, 240.0, 1e9, 1.0, 40.0, 6000.0)
        c = np.asarray(ch.counts, np.float64)
        return c.var() / max(c.mean(), 1e-9)

    f_hawkes = np.mean([fano(0.85, s) for s in (0, 1, 2)])
    f_poisson = np.mean([fano(0.0, s) for s in (0, 1, 2)])
    assert f_poisson < 1.5  # near-Poisson dispersion
    assert f_hawkes > 1.7 * f_poisson
    assert f_hawkes > 1.5  # clearly overdispersed


def test_burst_level_concentrates_topics():
    """At burst level 1 the hot-topic share dwarfs the calm share —
    content diversity collapses exactly when volume spikes."""
    scn = get_scenario("flash_crowd")
    ip = scn.iparams()

    def hot_share(burst):
        _, tag, _, _, _ = ops.traffic_sample(
            np.uint32(3), np.uint32(0), 4096, ip, scn.fparams(burst))
        t = np.asarray(tag)
        hot = (t >= scn.topic_base) & (t < scn.topic_base + scn.burst_ntags)
        return float(hot.mean())

    assert hot_share(1.0) > hot_share(0.0) + 0.3
    assert hot_share(1.0) > 0.6


# ---------------------------------------------------------------------------
# registry + source
# ---------------------------------------------------------------------------


def test_registry_ships_named_scenarios():
    names = [s.name for s in list_scenarios()]
    for required in ("steady_state", "flash_crowd", "celebrity_cascade",
                     "diurnal", "spam_storm"):
        assert required in names
    assert len(names) >= 5
    with pytest.raises(KeyError):
        get_scenario("no_such_scenario")
    custom = Scenario(name="test_custom", description="x", base_rate=10.0)
    register(custom)
    try:
        assert get_scenario("test_custom") is custom
        with pytest.raises(ValueError):
            register(Scenario(name="test_custom", description="dup"))
    finally:
        from repro.workloads.scenarios import _REGISTRY

        _REGISTRY.pop("test_custom", None)


def test_scenario_source_satisfies_source_protocol():
    from repro.api.protocols import Source
    from repro.ingest.sources import StreamTick

    src = ScenarioSource("steady_state", seed=1)
    assert isinstance(src, Source)
    tick = next(src.ticks())
    assert isinstance(tick, StreamTick)
    assert tick.records, "steady_state must emit records on tick 1"
    rec = tick.records[0]
    for key in ("id", "user", "hashtags", "mentions", "ts"):
        assert key in rec


def test_scenario_source_seed_deterministic():
    def first_ticks(seed):
        src = ScenarioSource("celebrity_cascade", seed=seed)
        it = src.ticks()
        return [next(it).records for _ in range(5)]

    assert first_ticks(9) == first_ticks(9)
    a = [r["id"] for t in first_ticks(9) for r in t]
    b = [r["id"] for t in first_ticks(10) for r in t]
    assert a != b or len(a) != len(b)


def test_spam_storm_duplicates():
    src = ScenarioSource("spam_storm", seed=2)
    it = src.ticks()
    recs = [r for _ in range(8) for r in next(it).records]
    ids = [r["id"] for r in recs]
    dup_frac = 1.0 - len(set(ids)) / max(len(ids), 1)
    assert dup_frac > 0.25  # scenario asks for ~50% duplicates


# ---------------------------------------------------------------------------
# closed-loop harness (e2e) + sketch-guided control
# ---------------------------------------------------------------------------


def test_harness_flash_crowd_forces_mode_transitions(tmp_path):
    rep = run_scenario("flash_crowd", ticks=50, seed=3, speed=0.5,
                       node_cap=1 << 12, edge_cap=1 << 14,
                       spill_dir=str(tmp_path / "spill"))
    assert rep.total_records > 0
    assert rep.n_transitions >= 1, "flash crowd must force >=1 buffer-mode transition"
    moved = {tr["to"] for tr in rep.transitions} | {tr["from"] for tr in rep.transitions}
    assert moved - {"push"}, "controller must leave push mode"
    # a timeline of K transitions needs at least K+1 recorded actions
    assert sum(rep.action_counts.values()) >= rep.n_transitions + 1
    d = rep.to_dict()
    json.dumps(d)  # report must be JSON-serialisable
    assert d["n_transitions"] == rep.n_transitions


def test_harness_steady_state_stays_calm(tmp_path):
    rep = run_scenario("steady_state", ticks=40, seed=3, speed=1.0,
                       node_cap=1 << 13, edge_cap=1 << 15,
                       spill_dir=str(tmp_path / "spill"))
    assert rep.total_records > 0
    assert rep.action_counts.get("push", 0) >= 0.8 * sum(rep.action_counts.values())
    assert rep.spill_events == 0


def test_sketch_guided_control_feeds_controller(tmp_path):
    from repro.api import PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig

    cfg = IngestConfig(store_nodes=1 << 12, store_edges=1 << 14)
    pipe = (PipelineBuilder(cfg)
            .with_source(ScenarioSource("steady_state", seed=5))
            .simulated_consumer(speed=1.0)
            .sketch_guided()
            .spill_dir(str(tmp_path / "spill"))
            .build())
    pipe.run(max_ticks=30)
    pm = pipe.buffer_stage.controller.perfmon
    assert pm.sketch_rho is not None, "sketch events must reach the controller"
    assert 0.0 <= pm.sketch_rho <= 1.0


def test_controller_observability_counters(tmp_path):
    rep = run_scenario("flash_crowd", ticks=50, seed=3, speed=0.5,
                       node_cap=1 << 11, edge_cap=1 << 12,
                       spill_dir=str(tmp_path / "spill"))
    # the tiny store saturates under the flash: the table-pressure
    # one-shot must fire and be observable
    assert rep.pressure_throttles >= 1
    assert rep.dropped_inserts > 0


# ---------------------------------------------------------------------------
# BENCH_ingest.json merge-append
# ---------------------------------------------------------------------------


def test_merge_bench_ingest_appends_runs(tmp_path):
    from benchmarks.run import merge_bench_ingest

    path = str(tmp_path / "BENCH_ingest.json")
    assert merge_bench_ingest(path, {"store_ingest": {"x": 1}}) == 1
    assert merge_bench_ingest(path, {"store_ingest": {"x": 2}}) == 2
    data = json.load(open(path))
    assert [r["run"] for r in data["runs"]] == [0, 1]
    assert data["runs"][1]["benches"]["store_ingest"]["x"] == 2


def test_merge_bench_ingest_wraps_legacy(tmp_path):
    from benchmarks.run import merge_bench_ingest

    path = str(tmp_path / "BENCH_ingest.json")
    with open(path, "w") as f:
        json.dump({"ingest_trajectory": {"rows": []}}, f)
    assert merge_bench_ingest(path, {"store_ingest": {}}) == 2
    data = json.load(open(path))
    assert data["runs"][0]["note"] == "legacy single-run format"
    assert "ingest_trajectory" in data["runs"][0]["benches"]
