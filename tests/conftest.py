import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)

# NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here —
# smoke tests and benches must see the real single device; only
# launch/dryrun.py forces 512 host devices (in its own process).


@pytest.fixture
def rng():
    return np.random.default_rng(0)
