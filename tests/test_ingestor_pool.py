"""GraphIngestor (Algorithm 3 GRAPHPUSH) pool admission + retry paths."""
import pytest

from repro.core.edge_table import from_raw_batch
from repro.core.ingestor import GraphIngestor
from repro.core.transform import create_edges, tweet_mapping
from repro.graphstore.store import init_store


def _et(tag: str, n: int = 5):
    recs = [{"id": f"{tag}{i}", "user": f"u{tag}{i}", "hashtags": ["x"],
             "mentions": []} for i in range(n)]
    return from_raw_batch(create_edges(recs, tweet_mapping()), 64)


def test_pool_full_holds_batch_without_commit():
    """Pool at capacity: the batch is held in local memory (paper
    §III-B), nothing is committed, and the caller learns the depth."""
    ing = GraphIngestor(init_store(512, 1024), max_pool_size=2)
    ing.pool.append(_et("a"))
    ing.pool.append(_et("b"))
    out = ing.push(_et("c"))
    assert out == {"committed": False, "pooled": 3}
    assert len(ing.pool) == 3
    assert int(ing.store.n_nodes) == 0  # nothing reached the store
    assert ing.commits == []


def test_pool_drains_fully_once_below_capacity():
    """A push with pool headroom drains every pooled batch in order."""
    ing = GraphIngestor(init_store(512, 1024), max_pool_size=4)
    ing.pool.append(_et("a"))
    ing.pool.append(_et("b"))
    out = ing.push(_et("c"))
    assert out["committed"]
    assert len(ing.pool) == 0
    assert len(ing.commits) == 3
    # 3 batches x 5 records x 2 unique nodes (user+tweet) + hashtag "x"
    assert int(ing.store.n_nodes) == 3 * 5 * 2 + 1


def test_pool_drain_stops_at_first_failure():
    """A mid-drain commit failure archives that batch and leaves the
    rest pooled (bounded retry surface)."""
    fails = {"n": 0}

    def hook():
        fails["n"] += 1
        return fails["n"] == 2  # second commit attempt fails

    ing = GraphIngestor(init_store(512, 1024), max_pool_size=4, fail_hook=hook)
    ing.pool.append(_et("a"))
    ing.pool.append(_et("b"))
    out = ing.push(_et("c"))
    assert not out["committed"] and out["archived"] == 1
    assert len(ing.archive) == 1  # batch "b" archived
    assert len(ing.pool) == 1  # batch "c" still pooled
    assert [c.ok for c in ing.commits] == [True, False]


def test_retry_archive_after_injected_failures():
    """Algorithm 3 line 18: archived batches replay once the
    connection recovers; a failure during retry stops the replay."""
    fail = {"on": True}
    ing = GraphIngestor(init_store(512, 1024),
                        fail_hook=lambda: fail["on"])
    for tag in ("a", "b", "c"):
        out = ing.push(_et(tag))
        assert not out["committed"]
    assert len(ing.archive) == 3
    assert int(ing.store.n_nodes) == 0

    # connection still down: retry commits nothing, archive intact
    # (the failed head re-archives, so depth is conserved)
    assert ing.retry_archive() == 0
    assert len(ing.archive) == 3

    # connection restored: full replay
    fail["on"] = False
    assert ing.retry_archive() == 3
    assert len(ing.archive) == 0
    assert int(ing.store.n_nodes) == 3 * 5 * 2 + 1
    assert [c.ok for c in ing.commits] == [False] * 4 + [True] * 3


def test_retry_archive_partial_failure_preserves_order():
    fails = {"seq": [False, True]}  # first retry ok, second fails

    def hook():
        return fails["seq"].pop(0) if fails["seq"] else False

    ing = GraphIngestor(init_store(512, 1024), fail_hook=lambda: True)
    ing.push(_et("a"))
    ing.push(_et("b"))
    assert len(ing.archive) == 2
    ing.fail_hook = hook
    assert ing.retry_archive() == 1  # "a" lands, "b" fails and re-archives
    assert len(ing.archive) == 1
    assert int(ing.store.n_nodes) == 5 * 2 + 1
