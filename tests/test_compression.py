"""Graph compression + edge table + store semantics (Algorithms 1 & 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core.edge_table import build_edge_table, from_raw_batch
from repro.core.transform import create_edges, reddit_mapping, tweet_mapping
from repro.graphstore.store import init_store, ingest_step


def _rand_edges(rng, n, cap, n_nodes=20):
    src = jnp.asarray(rng.integers(1, n_nodes, size=cap).astype(np.uint32))
    dst = jnp.asarray(rng.integers(1, n_nodes, size=cap).astype(np.uint32))
    et = jnp.asarray(rng.integers(1, 4, size=cap).astype(np.int32))
    valid = jnp.arange(cap) < n
    return src, dst, et, valid


def test_dedup_counts_sum_to_input(rng):
    src, dst, et, valid = _rand_edges(rng, 100, 128)
    comp, density = C.compress_edges(src, dst, et, valid)
    assert int(comp.counts.sum()) == 100
    assert int(comp.n_input) == 100
    assert int(comp.n_unique) <= 100
    assert 0.0 <= float(density)


def test_dedup_exact_vs_numpy(rng):
    src, dst, et, valid = _rand_edges(rng, 96, 128, n_nodes=8)
    comp, _ = C.compress_edges(src, dst, et, valid)
    triples = set()
    for i in range(96):
        triples.add((int(src[i]), int(dst[i]), int(et[i])))
    assert int(comp.n_unique) == len(triples)


def test_edge_table_counts_duplicates(rng):
    # one edge repeated 5 times + 3 singletons
    src = jnp.asarray([1, 1, 1, 1, 1, 2, 3, 4] + [0] * 8, dtype=jnp.uint32)
    dst = jnp.asarray([9, 9, 9, 9, 9, 9, 9, 9] + [0] * 8, dtype=jnp.uint32)
    et = jnp.ones((16,), jnp.int32)
    valid = jnp.arange(16) < 8
    tbl = build_edge_table(src, dst, et, valid)
    assert int(tbl.n_edges) == 4
    counts = sorted(np.asarray(tbl.count[:4]).tolist())
    assert counts == [1, 1, 1, 5]
    assert int(tbl.n_raw) == 8


def test_mapping_portability():
    """Paper §III-B: swapping the map file retargets the transformation."""
    tweets = [{"id": "t1", "user": "u1", "hashtags": ["a"], "mentions": ["u2"]}]
    reddit = [{"id": "p1", "author": "u1", "subreddit": "s1", "parent": "p0"}]
    rt = create_edges(tweets, tweet_mapping())
    rr = create_edges(reddit, reddit_mapping())
    assert rt.n_edges == 4  # owner, mention, ht-used, ht-mention
    assert rr.n_edges == 3  # authored, posted-in, replied-to


def test_store_merge_semantics(rng):
    src, dst, et, valid = _rand_edges(rng, 60, 64, n_nodes=12)
    tbl = build_edge_table(src, dst, et, valid)
    store = init_store(512, 1024)
    store, s1 = ingest_step(store, tbl)
    assert int(s1["new_nodes"]) == int(tbl.n_nodes)
    assert int(s1["new_edges"]) == int(tbl.n_edges)
    # MERGE: re-ingesting the same batch creates nothing new
    store, s2 = ingest_step(store, tbl)
    assert int(s2["new_nodes"]) == 0
    assert int(s2["new_edges"]) == 0
    assert int(store.n_nodes) == int(tbl.n_nodes)


def test_store_edge_counts_accumulate(rng):
    src, dst, et, valid = _rand_edges(rng, 40, 64, n_nodes=6)
    tbl = build_edge_table(src, dst, et, valid)
    store = init_store(256, 512)
    store, _ = ingest_step(store, tbl)
    store, _ = ingest_step(store, tbl)
    total_count = int(store.edge_count.sum())
    assert total_count == 2 * 40  # every raw edge instruction counted


def test_diversity_signal_decreases_on_repeat(rng):
    """rho = new/batch nodes: 1.0 first time, 0.0 on exact repeat."""
    src, dst, et, valid = _rand_edges(rng, 50, 64, n_nodes=15)
    tbl = build_edge_table(src, dst, et, valid)
    store = init_store(512, 1024)
    store, s1 = ingest_step(store, tbl)
    rho1 = int(s1["new_nodes"]) / max(int(s1["batch_nodes"]), 1)
    store, s2 = ingest_step(store, tbl)
    rho2 = int(s2["new_nodes"]) / max(int(s2["batch_nodes"]), 1)
    assert rho1 == 1.0 and rho2 == 0.0


def test_compression_improves_with_density():
    """Paper Fig. 13: denser (more redundant) batches compress better."""
    rng = np.random.default_rng(7)
    # high redundancy: few nodes -> many duplicate edges
    s1 = _rand_edges(rng, 120, 128, n_nodes=6)
    # low redundancy: many nodes
    s2 = _rand_edges(rng, 120, 128, n_nodes=10_000)
    t_dense = build_edge_table(*s1)
    t_sparse = build_edge_table(*s2)
    assert float(t_dense.compression_ratio()) < float(t_sparse.compression_ratio())
