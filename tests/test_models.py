"""Model-zoo correctness: prefill/decode equivalence (fp32), attention
variants, MoE dispatch properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.distributed.sharding import init_params
from repro.models import model as M

ARCHS = ["qwen2.5-3b", "qwen3-4b", "stablelm-1.6b", "mixtral-8x7b",
         "qwen2-moe-a2.7b", "mamba2-780m", "zamba2-7b", "whisper-medium",
         "phi-3-vision-4.2b"]


def _pad_kv(cache, to_len):
    def f(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 5:
            pad = to_len - x.shape[2]
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return x
    return jax.tree_util.tree_map_with_path(f, cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward_fp32(arch):
    cfg = dataclasses.replace(smoke_config(get_config(arch)), dtype="float32")
    params = init_params(M.param_specs(cfg), jax.random.key(0))
    B, S = 2, 33 if cfg.family in ("ssm", "hybrid") else 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(jax.random.key(2), (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(jax.random.key(2), (B, cfg.num_patches, cfg.d_model))
    logits_full, _ = M.forward(params, cfg, batch)
    pb = dict(batch)
    pb["tokens"] = tokens[:, : S - 1]
    lp, cache = M.prefill(params, cfg, pb)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(logits_full[:, -2]), atol=2e-3, rtol=2e-3
    )
    if cfg.family not in ("ssm",):
        cache = _pad_kv(cache, S + (cfg.num_patches or 0))
    pos = jnp.int32(S - 1 + (cfg.num_patches or 0))
    ld, _ = M.decode_step(params, cfg, cache, tokens[:, S - 1], pos)
    np.testing.assert_allclose(
        np.asarray(ld), np.asarray(logits_full[:, -1]), atol=5e-3, rtol=5e-3
    )


def test_sliding_window_masks_long_history():
    """SWA: tokens beyond the window cannot influence the output."""
    from repro.models import layers as L
    from repro.models.transformer import attn_specs

    cfg = dataclasses.replace(
        smoke_config(get_config("mixtral-8x7b")), dtype="float32", sliding_window=8
    )
    p = init_params(attn_specs(cfg), jax.random.key(0))
    B, S = 1, 64
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    y1 = L.attention(x, p, cfg)
    # perturb history far outside the window of the last query
    x2 = x.at[:, : S - 16].set(jax.random.normal(jax.random.key(2), (B, S - 16, cfg.d_model)))
    y2 = L.attention(x2, p, cfg)
    np.testing.assert_allclose(
        np.asarray(y1[:, -1]), np.asarray(y2[:, -1]), atol=1e-5, rtol=1e-5
    )


def test_chunked_attention_equals_full():
    from repro.models import layers as L

    cfg = dataclasses.replace(smoke_config(get_config("qwen2.5-3b")), dtype="float32")
    B, S, n, h = 2, 128, 4, 16
    q = jax.random.normal(jax.random.key(0), (B, S, n, h))
    k = jax.random.normal(jax.random.key(1), (B, S, 2, h))
    v = jax.random.normal(jax.random.key(2), (B, S, 2, h))
    full = L._sdpa_full(q, k, v, causal=True, window=None)
    chunked = L._sdpa_chunked(q, k, v, causal=True, window=None, chunk=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), atol=1e-5, rtol=1e-5)


def test_moe_dropless_conservation():
    """With capacity >= E/K, every token is processed by exactly K experts."""
    from repro.models.moe import moe_block
    from repro.models.transformer import moe_specs

    cfg = dataclasses.replace(smoke_config(get_config("mixtral-8x7b")), dtype="float32")
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = moe_block(x, p, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-5  # Switch aux lower bound at balance
    # permutation invariance across the batch dim
    y2, _ = moe_block(x[::-1], p, cfg)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y[::-1]), atol=1e-5, rtol=1e-5)


def test_moe_capacity_drops_tokens():
    """With tiny capacity, overflow tokens fall back to (shared/zero) path."""
    from repro.models.moe import moe_block
    from repro.models.transformer import moe_specs

    cfg = dataclasses.replace(
        smoke_config(get_config("mixtral-8x7b")), dtype="float32", capacity_factor=0.25
    )
    p = init_params(moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, _ = moe_block(x, p, cfg)
    assert not bool(jnp.isnan(y).any())


def test_rope_position_shift_property():
    """RoPE: attention logits depend only on relative positions."""
    from repro.models.layers import apply_rope

    h = 16
    q = jax.random.normal(jax.random.key(0), (1, 4, 1, h))
    k = jax.random.normal(jax.random.key(1), (1, 4, 1, h))
    p0 = jnp.arange(4)[None, :]
    q0, k0 = apply_rope(q, p0, 10000.0), apply_rope(k, p0, 10000.0)
    s0 = jnp.einsum("bqnh,bknh->bqk", q0, k0)
    p1 = p0 + 17
    q1, k1 = apply_rope(q, p1, 10000.0), apply_rope(k, p1, 10000.0)
    s1 = jnp.einsum("bqnh,bknh->bqk", q1, k1)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-4, rtol=1e-4)


def test_hybrid_group_structure():
    cfg = smoke_config(get_config("zamba2-7b"))
    from repro.models.hybrid import hybrid_groups

    ng, rem, g = hybrid_groups(cfg)
    assert ng * g + rem == cfg.num_layers
    # full config: 81 layers, period 6 -> 13 groups + 3 tail
    full = get_config("zamba2-7b")
    ng2, rem2, g2 = hybrid_groups(full)
    assert (ng2, rem2, g2) == (13, 3, 6)
