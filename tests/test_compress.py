"""repro.compress: pattern mining, dictionary, pattern-aware commits.

The load-bearing assertion is bit-exactness: committing the SAME
edge-table sequence through the raw path (`ingest_step`) and through
rewrite + `commit_compressed` must leave byte-identical stores (and
therefore byte-identical snapshots).  See the lemma in
repro/compress/stage.py's module docstring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import DictionaryStage, dict_admit, dict_lookup, init_dictionary
from repro.compress.stage import CompressedCommit
from repro.core import compression as C
from repro.core.edge_table import EdgeTable, build_edge_table
from repro.graphstore.store import commit_compressed, ingest_step, init_store
from repro.kernels import ops
from repro.kernels import pattern_mine as PM


def _rand_edges(rng, n, cap, n_nodes=20):
    src = jnp.asarray(rng.integers(1, n_nodes, size=cap).astype(np.uint32))
    dst = jnp.asarray(rng.integers(1, n_nodes, size=cap).astype(np.uint32))
    et = jnp.asarray(rng.integers(1, 4, size=cap).astype(np.int32))
    valid = jnp.arange(cap) < n
    return src, dst, et, valid


# ---------------------------------------------------------------------------
# pattern mining: kernel parity + brute-force semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cap,n,pool", [(128, 100, 10), (256, 200, 40),
                                        (512, 512, 6)])
def test_pattern_mine_kernel_matches_oracle(rng, cap, n, pool):
    src, dst, et, valid = _rand_edges(rng, n, cap, n_nodes=pool)
    count = jnp.asarray(rng.integers(1, 4, size=cap).astype(np.int32))
    a = ops.pattern_mine(src, dst, et, count, valid, 3, 2, use_kernel=True)
    b = ops.pattern_mine(src, dst, et, count, valid, 3, 2, use_kernel=False)
    for ka, kb, name in zip(a, b, ("fan_out", "fan_in", "flags", "psig")):
        assert jnp.array_equal(ka, kb), f"{name} differs kernel vs oracle"


def test_pattern_mine_matches_numpy_bruteforce(rng):
    cap = 128
    src, dst, et, valid = _rand_edges(rng, 100, cap, n_nodes=12)
    count = jnp.asarray(rng.integers(1, 4, size=cap).astype(np.int32))
    star_min, hot_min = 3, 2
    fo, fi, flags, psig = ops.pattern_mine(
        src, dst, et, count, valid, star_min, hot_min)
    s, d, e, c, v = map(np.asarray, (src, dst, et, count, valid))
    fo, fi, flags, psig = map(np.asarray, (fo, fi, flags, psig))
    srcs = set(s[v].tolist())
    for i in range(cap):
        if not v[i]:
            assert fo[i] == 0 and fi[i] == 0 and flags[i] == 0
            continue
        exp_fo = int(np.sum(v & (s == s[i]) & (e == e[i])))
        exp_fi = int(np.sum(v & (d == d[i]) & (e == e[i])))
        assert fo[i] == exp_fo
        assert fi[i] == exp_fi
        chain = int(d[i]) in srcs and d[i] != s[i]
        exp_flags = ((exp_fo >= star_min) * PM.FLAG_STAR_OUT
                     + (exp_fi >= star_min) * PM.FLAG_STAR_IN
                     + chain * PM.FLAG_CHAIN
                     + (c[i] >= hot_min) * PM.FLAG_HOT)
        assert flags[i] == exp_flags
        assert (psig[i] != 0) == (exp_flags != 0)


def test_pattern_mine_star_burst():
    cap = 64
    # hub 7 fans out to 5 targets under one etype + 2 unrelated edges
    src = jnp.asarray([7, 7, 7, 7, 7, 1, 2] + [0] * 57, dtype=jnp.uint32)
    dst = jnp.asarray([10, 11, 12, 13, 14, 3, 4] + [0] * 57, dtype=jnp.uint32)
    et = jnp.ones((cap,), jnp.int32)
    count = jnp.ones((cap,), jnp.int32)
    valid = jnp.arange(cap) < 7
    fo, fi, flags, psig = ops.pattern_mine(src, dst, et, count, valid, 4, 99)
    fo, flags, psig = map(np.asarray, (fo, flags, psig))
    assert (fo[:5] == 5).all()
    assert all(flags[i] & PM.FLAG_STAR_OUT for i in range(5))
    assert flags[5] == 0 and flags[6] == 0
    # all five star members share one pattern signature (the hub's)
    assert len(set(psig[:5].tolist())) == 1 and psig[0] != 0


def test_pattern_mine_cascade_chain():
    cap = 64
    # relay chain 1 -> 2 -> 3 -> 4: edges whose dst re-appears as a src
    src = jnp.asarray([1, 2, 3] + [0] * 61, dtype=jnp.uint32)
    dst = jnp.asarray([2, 3, 4] + [0] * 61, dtype=jnp.uint32)
    et = jnp.ones((cap,), jnp.int32)
    count = jnp.ones((cap,), jnp.int32)
    valid = jnp.arange(cap) < 3
    _, _, flags, _ = ops.pattern_mine(src, dst, et, count, valid, 99, 99)
    flags = np.asarray(flags)
    assert flags[0] & PM.FLAG_CHAIN  # dst=2 is a source
    assert flags[1] & PM.FLAG_CHAIN  # dst=3 is a source
    assert flags[2] == 0  # dst=4 is terminal


# ---------------------------------------------------------------------------
# satellite: tree_flatten regression (astuple recursion bug)
# ---------------------------------------------------------------------------


def test_compressed_batch_tree_flatten_roundtrip(rng):
    src, dst, et, valid = _rand_edges(rng, 50, 64)
    comp, _ = C.compress_edges(src, dst, et, valid)
    leaves, treedef = jax.tree_util.tree_flatten(comp)
    assert len(leaves) == 6
    # the flatten must hand back the field objects THEMSELVES
    assert leaves[0] is comp.keys
    comp2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(comp2, C.CompressedBatch)
    for f in dataclasses.fields(comp):
        assert jnp.array_equal(getattr(comp, f.name), getattr(comp2, f.name))


@pytest.mark.parametrize("cls", [C.CompressedBatch, EdgeTable])
def test_tree_flatten_preserves_partition_spec_leaves(cls):
    """The astuple() bug: a PartitionSpec (a tuple subclass) leaf came
    back a plain tuple, so sharding-spec pytrees shaped like the batch
    silently lost their spec-ness."""
    from jax.sharding import PartitionSpec as P

    spec = cls(*[P("x") for _ in range(len(dataclasses.fields(cls)))])
    leaves, treedef = jax.tree_util.tree_flatten(
        spec, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(l, P) for l in leaves)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert all(isinstance(getattr(rebuilt, f.name), P)
               for f in dataclasses.fields(cls))


# ---------------------------------------------------------------------------
# satellite: bijective uint64 key packing
# ---------------------------------------------------------------------------


def test_mix_keys_uint64_bijective_when_ids_fit():
    from jax.experimental import enable_x64

    with enable_x64():
        rng = np.random.default_rng(7)
        n = 4096
        src = rng.integers(0, 1 << C.PACK_SRC_BITS, n, dtype=np.uint64)
        dst = rng.integers(0, 1 << C.PACK_DST_BITS, n, dtype=np.uint64)
        et = rng.integers(0, 1 << C.PACK_ETYPE_BITS, n, dtype=np.int64)
        keys = np.asarray(C.mix_keys(jnp.asarray(src), jnp.asarray(dst),
                                     jnp.asarray(et, jnp.int32)))
        triples = set(zip(src.tolist(), dst.tolist(), et.tolist()))
        # bijective: exactly one key per distinct triple, and the
        # packing is exact (decodable)
        assert len(set(keys.tolist())) == len(triples)
        assert ((keys >> np.uint64(62)) == 1).all()  # pack tag, not hash
        back_src = (keys >> np.uint64(C.PACK_DST_BITS + C.PACK_ETYPE_BITS)) \
            & np.uint64((1 << C.PACK_SRC_BITS) - 1)
        back_dst = (keys >> np.uint64(C.PACK_ETYPE_BITS)) \
            & np.uint64((1 << C.PACK_DST_BITS) - 1)
        back_et = keys & np.uint64((1 << C.PACK_ETYPE_BITS) - 1)
        assert (back_src == src).all()
        assert (back_dst == dst).all()
        assert (back_et == et.astype(np.uint64)).all()


def test_mix_keys_uint64_wide_ids_fall_back_to_hash():
    from jax.experimental import enable_x64

    with enable_x64():
        wide = jnp.asarray(np.asarray([1 << 40, 5], np.uint64))
        dst = jnp.asarray(np.asarray([3, 1 << 50], np.uint64))
        et = jnp.zeros((2,), jnp.int32)
        keys = np.asarray(C.mix_keys(wide, dst, et))
        # hash domain is tagged with bit 63: can never alias a packed key
        assert ((keys >> np.uint64(63)) == 1).all()


def test_mix_keys_uint32_unchanged_by_pack_path(rng):
    src, dst, et, _ = _rand_edges(rng, 64, 64)
    keys = C.mix_keys(src, dst, et)
    assert keys.dtype == jnp.uint32
    assert (np.asarray(keys) != 0).all()  # 0 is the empty-slot marker


# ---------------------------------------------------------------------------
# dictionary lifecycle
# ---------------------------------------------------------------------------


def test_dictionary_miss_admit_hit_cycle(rng):
    src, dst, et, valid = _rand_edges(rng, 40, 64, n_nodes=50)
    keys = C.mix_keys(src, dst, et)
    d = init_dictionary(256, keys.dtype)
    d, hit, es, ss, ds, slot = dict_lookup(d, keys, valid)
    assert int(hit.sum()) == 0  # cold dictionary: all misses
    eslot = jnp.where(valid, jnp.arange(64, dtype=jnp.int32), -1)
    d = dict_admit(d, keys, valid, eslot, eslot + 100, eslot + 200,
                   jnp.where(valid, keys, 0))
    d, hit, es, ss, ds, slot = dict_lookup(d, keys, valid)
    n_unique = int(C.dedup_with_counts(keys, valid).n_unique)
    assert int(hit.sum()) == 40  # every valid lane hits now
    assert int(d.n_entries) == n_unique
    # bindings come back exactly as cached
    hv = np.asarray(hit)
    assert (np.asarray(es)[hv] == np.asarray(eslot)[hv]).all()
    assert (np.asarray(ss)[hv] == np.asarray(eslot)[hv] + 100).all()
    assert (np.asarray(ds)[hv] == np.asarray(eslot)[hv] + 200).all()


def test_dictionary_hit_rate_monotone_on_cascade_replay(rng):
    """Replaying the same cascade makes the hit rate non-decreasing:
    round 1 is all misses, later rounds reference what was admitted."""
    cap = 128
    # star-heavy batch: two hubs + chain, so mining admits everything
    hub = np.concatenate([np.full(20, 3), np.full(20, 5)])
    src = jnp.asarray(np.pad(hub, (0, cap - 40)).astype(np.uint32))
    dst = jnp.asarray(np.pad(np.arange(10, 50), (0, cap - 40)).astype(np.uint32))
    et = jnp.ones((cap,), jnp.int32)
    valid = jnp.arange(cap) < 40
    table = build_edge_table(src, dst, et, valid)
    stage = DictionaryStage(capacity=512, star_min=3, hot_min=1)
    store = init_store(1 << 10, 1 << 11)
    rates = []
    for _ in range(4):
        cc = stage.rewrite(table)
        store, s = commit_compressed(store, cc)
        stage.observe_commit(cc, s)
        rates.append(float(s["dict_hit_rate"]))
    assert rates[0] == 0.0
    assert all(b >= a for a, b in zip(rates, rates[1:]))
    assert rates[-1] > 0.5  # replayed batch is nearly all references


def test_dictionary_shared_across_batches(rng):
    """An edge admitted in batch 1 is a reference in batch 2 even when
    batch 2 is a different table (dictionary survives across batches)."""
    cap = 64
    src = jnp.asarray([9] * 6 + [0] * 58, dtype=jnp.uint32)
    dst = jnp.asarray(list(range(20, 26)) + [0] * 58, dtype=jnp.uint32)
    et = jnp.ones((cap,), jnp.int32)
    t1 = build_edge_table(src, dst, et, jnp.arange(cap) < 6)
    # batch 2 = three of those edges + three fresh ones
    src2 = jnp.asarray([9, 9, 9, 1, 2, 3] + [0] * 58, dtype=jnp.uint32)
    dst2 = jnp.asarray([20, 21, 22, 40, 41, 42] + [0] * 58, dtype=jnp.uint32)
    t2 = build_edge_table(src2, dst2, et, jnp.arange(cap) < 6)
    stage = DictionaryStage(capacity=256, star_min=3, hot_min=1)
    store = init_store(1 << 10, 1 << 11)
    cc1 = stage.rewrite(t1)
    store, s1 = commit_compressed(store, cc1)
    stage.observe_commit(cc1, s1)
    cc2 = stage.rewrite(t2)
    store, s2 = commit_compressed(store, cc2)
    assert int(s1["dict_refs"]) == 0
    assert int(s2["dict_refs"]) == 3


# ---------------------------------------------------------------------------
# bit-exactness: raw path vs pattern-aware path
# ---------------------------------------------------------------------------


def test_commit_compressed_bit_exact_store_and_snapshot(rng):
    from repro.query.snapshot import build_snapshot

    node_cap, edge_cap = 1 << 11, 1 << 12
    batches = []
    for _ in range(8):
        src, dst, et, valid = _rand_edges(rng, 110, 128, n_nodes=60)
        batches.append(build_edge_table(src, dst, et, valid))
    batches = batches + batches  # replay -> dictionary hits in round 2

    store_a = init_store(node_cap, edge_cap)
    store_b = init_store(node_cap, edge_cap)
    stage = DictionaryStage(capacity=512, star_min=3, hot_min=1)
    total_refs = 0
    for et in batches:
        store_a, _ = ingest_step(store_a, et)
        cc = stage.rewrite(et)
        store_b, s = commit_compressed(store_b, cc)
        stage.observe_commit(cc, s)
        total_refs += int(s["dict_refs"])
    assert total_refs > 0  # the compressed path actually referenced
    for f in dataclasses.fields(store_a):
        a, b = getattr(store_a, f.name), getattr(store_b, f.name)
        assert jnp.array_equal(a, b), f"store field {f.name} diverged"
    snap_a, snap_b = build_snapshot(store_a), build_snapshot(store_b)
    for f in dataclasses.fields(snap_a):
        a, b = getattr(snap_a, f.name), getattr(snap_b, f.name)
        assert jnp.array_equal(a, b), f"snapshot field {f.name} diverged"


def test_commit_compressed_accounting(rng):
    """Stats keep full-batch semantics: batch_edges counts references
    too (rho comparable to the raw path), instructions do not."""
    src, dst, et, valid = _rand_edges(rng, 60, 64, n_nodes=30)
    table = build_edge_table(src, dst, et, valid)
    stage = DictionaryStage(capacity=256, star_min=3, hot_min=1)
    store = init_store(1 << 10, 1 << 11)
    cc1 = stage.rewrite(table)
    store, s1 = commit_compressed(store, cc1)
    stage.observe_commit(cc1, s1)
    cc2 = stage.rewrite(table)
    store, s2 = commit_compressed(store, cc2)
    assert int(s1["batch_edges"]) == int(s2["batch_edges"]) == int(table.n_edges)
    assert int(s2["dict_refs"]) > 0
    # a reference costs 1 instruction < the 1 edge + <=2 nodes it replaces
    assert int(s2["instructions"]) < int(s1["instructions"])
    assert float(cc2.compression_ratio()) < float(cc1.compression_ratio()) < 1.0


# ---------------------------------------------------------------------------
# property tests (hypothesis)
# ---------------------------------------------------------------------------


try:  # hypothesis when available; deterministic fallback otherwise
    from hypothesis import given, settings, strategies as st

    _settings = dict(max_examples=25, deadline=None)
except ImportError:  # pragma: no cover - environment without hypothesis
    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _TupleStrategy:
        def __init__(self, parts):
            self.parts = parts

        def sample(self, rng):
            return tuple(p.sample(rng) for p in self.parts)

    class _ListStrategy:
        def __init__(self, elem, lo, hi):
            self.elem, self.lo, self.hi = elem, lo, hi

        def sample(self, rng):
            n = int(rng.integers(self.lo, self.hi + 1))
            return [self.elem.sample(rng) for _ in range(n)]

    class st:  # noqa: N801 - mimic the hypothesis surface used above
        @staticmethod
        def integers(min_value, max_value):
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def tuples(*parts):
            return _TupleStrategy(parts)

        @staticmethod
        def lists(elem, min_size, max_size):
            return _ListStrategy(elem, min_size, max_size)

    def settings(**kw):
        return lambda fn: fn

    def given(**strategies):
        def deco(fn):
            def run():
                rng = np.random.default_rng(0)
                for _ in range(25):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})

            run.__name__ = fn.__name__
            return run

        return deco

    _settings = {}


@settings(**_settings)
@given(data=st.lists(st.integers(min_value=1, max_value=60),
                     min_size=1, max_size=100))
def test_dedup_idempotent(data):
    """Dedup of a dedup's unique keys is a fixed point: same uniques,
    every count 1."""
    cap = 128
    keys = jnp.asarray(np.pad(np.asarray(data, np.uint32), (0, cap - len(data))))
    valid = jnp.arange(cap) < len(data)
    once = C.dedup_with_counts(keys, valid)
    twice = C.dedup_with_counts(once.keys, once.valid)
    assert int(twice.n_unique) == int(once.n_unique)
    assert jnp.array_equal(twice.keys, once.keys)
    n = int(once.n_unique)
    assert (np.asarray(twice.counts)[:n] == 1).all()


@settings(**_settings)
@given(
    pairs=st.lists(st.tuples(st.integers(1, 30), st.integers(1, 30)),
                   min_size=1, max_size=100),
)
def test_compression_ratio_in_unit_interval(pairs):
    """Fig. 13 ratio is always in (0, 1] — dedup can only help."""
    cap = 128
    n = len(pairs)
    src = jnp.asarray(np.pad([a for a, _ in pairs], (0, cap - n)).astype(np.uint32))
    dst = jnp.asarray(np.pad([b for _, b in pairs], (0, cap - n)).astype(np.uint32))
    table = build_edge_table(src, dst, jnp.ones((cap,), jnp.int32),
                             jnp.arange(cap) < n)
    ratio = float(table.compression_ratio())
    assert 0.0 < ratio <= 1.0
    # the rewrite's ratio (references cost 1 instruction) never exceeds it
    stage = DictionaryStage(capacity=128, star_min=3, hot_min=1)
    cc = stage.rewrite(table)
    assert 0.0 < float(cc.compression_ratio()) <= ratio + 1e-6
