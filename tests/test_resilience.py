"""repro.resilience: backoff policy, fault injection, bounded archive,
checkpoint/restore, and the kill/resume bit-exactness contract.

The e2e chaos tests mirror `python -m repro.launch.chaos`: a run killed
mid-scenario and resumed from its latest checkpoint must land on a
byte-identical GraphStore and CSR snapshot vs the same run left alone —
everything downstream of (scenario, seed) is counter-deterministic,
including the injected failure schedule."""
import math
import os
import pickle

import numpy as np
import pytest

from repro.core.edge_table import from_raw_batch
from repro.core.ingestor import GraphIngestor
from repro.core.transform import create_edges, tweet_mapping
from repro.graphstore.store import init_store
from repro.resilience import (
    FaultInjector,
    FaultPlan,
    PipelineCheckpointer,
    PipelineKilled,
    RetryPolicy,
    pytree_digest,
)
from repro.workloads import run_scenario


def _et(tag: str, n: int = 5):
    recs = [{"id": f"{tag}{i}", "user": f"u{tag}{i}", "hashtags": ["x"],
             "mentions": []} for i in range(n)]
    return from_raw_batch(create_edges(recs, tweet_mapping()), 64)


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------

def test_retry_policy_capped_and_monotone():
    p = RetryPolicy(base_s=0.5, factor=2.0, cap_s=30.0, jitter=0.0)
    raws = [p.raw_delay(k) for k in range(20)]
    assert raws[0] == 0.5 and raws[1] == 1.0 and raws[2] == 2.0
    assert all(b >= a for a, b in zip(raws, raws[1:]))  # monotone
    assert raws[-1] == 30.0  # capped
    assert p.raw_delay(10_000) == 30.0  # no float overflow


def test_retry_policy_jitter_bounded_and_deterministic():
    p = RetryPolicy(jitter=0.1, seed=7)
    for k in range(12):
        raw = p.raw_delay(k)
        d = p.delay(k)
        assert raw * 0.9 <= d <= raw * 1.1
        assert d == RetryPolicy(jitter=0.1, seed=7).delay(k)  # pure
    # different seeds decorrelate the jitter stream
    assert any(RetryPolicy(seed=1).delay(k) != RetryPolicy(seed=2).delay(k)
               for k in range(8))


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(base_s=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(factor=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(cap_s=0.1, base_s=1.0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.0)
    with pytest.raises(ValueError):
        RetryPolicy().raw_delay(-1)


# ---------------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------------

def test_fault_injector_windows_and_state():
    inj = FaultInjector(FaultPlan(fail_attempts=((2, 4),),
                                  fail_times=((10.0, 12.0),)))
    assert inj.wants_now
    hits = [inj(now=0.0) for _ in range(5)]
    assert hits == [False, False, True, True, False]
    assert inj(now=11.0) is True  # inside the outage window
    assert inj(now=12.0) is False  # half-open
    s = inj.state()
    inj2 = FaultInjector(inj.plan)
    inj2.restore_state(s)
    assert inj2.attempts == inj.attempts  # sequence continues exactly


def test_fault_plan_without_crash():
    p = FaultPlan(fail_attempts=((0, 1),), crash_at_tick=9)
    q = p.without_crash()
    assert q.crash_at_tick is None and q.fail_attempts == p.fail_attempts


# ---------------------------------------------------------------------------
# GraphIngestor resilience paths
# ---------------------------------------------------------------------------

def test_commit_record_keeps_simulated_zero_time():
    """now=0.0 is falsy: the failure record must still carry t=0.0
    (the old `now or time.time()` stamped it with wall clock)."""
    ing = GraphIngestor(init_store(512, 1024), fail_hook=lambda: True)
    out = ing.push(_et("a"), now=0.0)
    assert not out["committed"]
    assert ing.commits[-1].t == 0.0


def test_pool_hard_cap_diverts_to_archive():
    ing = GraphIngestor(init_store(512, 1024), max_pool_size=2, pool_cap=3)
    for tag in "abc":
        ing.pool.append(_et(tag))
    out = ing.push(_et("d"))
    assert out == {"committed": False, "pooled": 3, "pool_overflow": 1}
    assert ing.pool_overflows == 1
    assert ing.archive_depth == 1 and ing.archived_total == 1
    assert len(ing.pool) == 3  # pool did not grow past the cap


def test_backoff_gate_blocks_then_allows_retry():
    state = {"down": True}
    ing = GraphIngestor(init_store(512, 1024),
                        fail_hook=lambda: state["down"],
                        retry_policy=RetryPolicy(jitter=0.0))
    out = ing.push(_et("a"), now=0.0)
    assert not out["committed"] and out["retry_in_s"] == 0.5
    assert ing.next_retry_t == 0.5
    assert ing.retry_archive(now=0.4) == 0  # gate closed: no attempt
    assert ing.retry_archive(now=0.4) == 0  # ...and it stays cheap
    state["down"] = False
    assert ing.retry_archive(now=0.6) == 1  # gate open: replayed
    assert ing.archive_depth == 0 and ing.replayed == 1


def test_backoff_delay_doubles_per_consecutive_failure():
    ing = GraphIngestor(init_store(512, 1024), fail_hook=lambda: True,
                        retry_policy=RetryPolicy(jitter=0.0), degrade_after=99)
    delays = []
    t = 0.0
    for _ in range(5):
        t = ing.next_retry_t if ing.next_retry_t > t else t
        out = ing.push(_et("x"), now=t)
        delays.append(out["retry_in_s"])
    assert delays == [0.5, 1.0, 2.0, 4.0, 8.0]


def test_degraded_mode_archives_without_probing():
    ing = GraphIngestor(init_store(512, 1024), fail_hook=lambda: True,
                        retry_policy=RetryPolicy(jitter=0.0), degrade_after=2)
    ing.push(_et("a"), now=0.0)
    ing.push(_et("b"), now=1.0)
    assert ing.degraded
    n_attempts = ing.attempts
    out = ing.push(_et("c"), now=1.1)  # gate closed: no commit attempt
    assert out == {"committed": False, "archived": 3, "degraded": True}
    assert ing.attempts == n_attempts
    assert ing.archived_total == 3


def test_archive_spills_to_disk_and_replays_fifo(tmp_path):
    """Past max_archive the archive spills to disk; replay preserves
    FIFO order across the memory/disk boundary and the accounting
    invariant archived_total == replayed + archive_depth holds."""
    state = {"down": True}
    ing = GraphIngestor(init_store(2048, 4096),
                        fail_hook=lambda: state["down"],
                        retry_policy=RetryPolicy(jitter=0.0),
                        max_archive=2, archive_dir=str(tmp_path / "arch"),
                        degrade_after=1)
    for i in range(5):
        # gate always open at these times -> every push probes + fails
        ing.push(_et(f"t{i}"), now=100.0 * i)
    assert len(ing.archive) <= 2 and ing.archive_depth == 5
    assert len(ing._archive_spill) == 3
    assert ing.archived_total == ing.replayed + ing.archive_depth
    state["down"] = False
    assert ing.retry_archive(now=1e9) == 5
    assert ing.archive_depth == 0 and ing._archive_spill == []
    assert ing.archived_total == ing.replayed + ing.archive_depth
    # FIFO: first-archived batch committed first
    assert [c.ok for c in ing.commits].count(True) == 5


def test_legacy_no_policy_behavior_unchanged():
    """No RetryPolicy: no gate, no degraded mode — retry_archive always
    probes (pinned by test_ingestor_pool; re-pinned here)."""
    state = {"down": True}
    ing = GraphIngestor(init_store(512, 1024),
                        fail_hook=lambda: state["down"])
    ing.push(_et("a"), now=0.0)
    assert not ing.degraded
    state["down"] = False
    assert ing.retry_archive() == 1  # no gate to wait out


def test_ingestor_state_roundtrip(tmp_path):
    state = {"down": True}
    ing = GraphIngestor(init_store(2048, 4096),
                        fail_hook=lambda: state["down"],
                        retry_policy=RetryPolicy(jitter=0.0),
                        max_archive=1, archive_dir=str(tmp_path / "a"),
                        degrade_after=1)
    for i in range(3):
        ing.push(_et(f"t{i}"), now=100.0 * i)
    snap = pickle.loads(pickle.dumps(ing.state()))
    ing2 = GraphIngestor(ing.store, fail_hook=lambda: state["down"],
                         retry_policy=RetryPolicy(jitter=0.0),
                         max_archive=1, archive_dir=str(tmp_path / "b"),
                         degrade_after=1)
    ing2.restore_state(snap)
    assert ing2.archive_depth == ing.archive_depth
    assert ing2.archived_total == ing.archived_total
    assert ing2.next_retry_t == ing.next_retry_t
    assert ing2.consecutive_failures == ing.consecutive_failures
    state["down"] = False  # connection restored: replay everything
    assert ing2.retry_archive(now=1e9) == 3
    assert ing2.archived_total == ing2.replayed + ing2.archive_depth


# ---------------------------------------------------------------------------
# PipelineCheckpointer
# ---------------------------------------------------------------------------

def _tiny_pipe(tmp_path, tag="a"):
    from repro.api import PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig
    from repro.workloads.source import ScenarioSource

    src = ScenarioSource("steady_state", seed=5)
    pipe = (PipelineBuilder(IngestConfig(store_nodes=1 << 11,
                                         store_edges=1 << 12))
            .with_source(src)
            .simulated_consumer(speed=1.0)
            .spill_dir(str(tmp_path / f"spill_{tag}"))
            .build())
    return pipe, src


def test_checkpoint_save_restore_roundtrip(tmp_path):
    pipe, src = _tiny_pipe(tmp_path, "save")
    pipe.run(max_ticks=12)
    ck = PipelineCheckpointer(str(tmp_path / "ck"), every=4)
    ck.save(12, pipe, src, blocking=True, extra={"seed": 5})
    assert ck.list_steps() == [12]

    pipe2, src2 = _tiny_pipe(tmp_path, "load")
    man = ck.restore(pipe2, src2, expect={"seed": 5})
    assert man["step"] == 12
    assert pytree_digest(pipe2.store) == pytree_digest(pipe.store)
    assert src2.state() == src.state()
    assert pipe2.loop_state["records"] == pipe.loop_state["records"]
    # both continue identically
    pipe.run(max_ticks=6)
    pipe2.run(max_ticks=6)
    assert pytree_digest(pipe2.store) == pytree_digest(pipe.store)


def test_checkpoint_expect_mismatch_is_hard_error(tmp_path):
    pipe, src = _tiny_pipe(tmp_path, "exp")
    pipe.run(max_ticks=4)
    ck = PipelineCheckpointer(str(tmp_path / "ck"))
    ck.save(4, pipe, src, blocking=True, extra={"seed": 5})
    pipe2, src2 = _tiny_pipe(tmp_path, "exp2")
    with pytest.raises(ValueError, match="mismatch"):
        ck.restore(pipe2, src2, expect={"seed": 6})


def test_torn_checkpoint_ignored_and_gc_keeps_n(tmp_path):
    pipe, src = _tiny_pipe(tmp_path, "gc")
    pipe.run(max_ticks=4)
    ck = PipelineCheckpointer(str(tmp_path / "ck"), keep=2)
    for step in (4, 8, 12, 16):
        ck.save(step, pipe, src, blocking=True)
    assert ck.list_steps() == [12, 16]  # keep-N GC
    # a torn checkpoint (no _COMMITTED) is invisible to discovery
    os.remove(str(tmp_path / "ck" / "step_00000016" / "_COMMITTED"))
    assert ck.list_steps() == [12]
    assert ck.latest_step() == 12


def test_restore_without_checkpoint_raises(tmp_path):
    pipe, src = _tiny_pipe(tmp_path, "none")
    ck = PipelineCheckpointer(str(tmp_path / "ck"))
    with pytest.raises(FileNotFoundError):
        ck.restore(pipe, src)


# ---------------------------------------------------------------------------
# e2e: kill/resume bit-exactness + chaos invariants (the tentpole)
# ---------------------------------------------------------------------------

_CHAOS_KW = dict(ticks=40, seed=3, node_cap=1 << 12, edge_cap=1 << 14,
                 retry=RetryPolicy(jitter=0.0), checkpoint_every=8)


@pytest.mark.parametrize("scenario", ["flash_crowd", "celebrity_cascade"])
def test_kill_resume_bit_exact(scenario, tmp_path):
    """Kill mid-scenario, resume from the latest checkpoint: store AND
    CSR snapshot digests match an uninterrupted run executing the same
    fault schedule."""
    plan = FaultPlan(fail_times=((10.0, 16.0),), crash_at_tick=20)

    ref = run_scenario(scenario, fault_plan=plan.without_crash(),
                       spill_dir=str(tmp_path / "ref"), **_CHAOS_KW)
    assert ref.commit_failures > 0  # the outage actually bit

    with pytest.raises(PipelineKilled):
        run_scenario(scenario, fault_plan=plan,
                     checkpoint_dir=str(tmp_path / "ck"),
                     spill_dir=str(tmp_path / "chaos"), **_CHAOS_KW)

    res = run_scenario(scenario, fault_plan=plan.without_crash(),
                       checkpoint_dir=str(tmp_path / "ck"), resume=True,
                       spill_dir=str(tmp_path / "chaos"), **_CHAOS_KW)
    assert 0 < res.resumed_from_tick <= 20
    assert res.total_records == ref.total_records
    assert res.store_digest == ref.store_digest
    assert res.snapshot_digest == ref.snapshot_digest
    # no batch lost across kill/resume: archive accounting balances
    assert res.archived_total == res.retries_replayed + res.archive_remaining


def test_outage_backoff_does_not_hot_loop(tmp_path):
    """During a store outage the commit-failure count stays logarithmic
    in the outage length — the backoff gate holds (a gateless retry
    fails about once per tick)."""
    outage = 14.0
    plan = FaultPlan(fail_times=((8.0, 8.0 + outage),))
    rep = run_scenario("flash_crowd", fault_plan=plan,
                       spill_dir=str(tmp_path / "sp"), **_CHAOS_KW)
    assert rep.commit_failures > 0
    allowed = 3 + 2 * (math.log2(outage / 0.5) + 2)
    assert rep.commit_failures <= allowed
    # service recovered: everything archived during the outage replayed
    assert rep.retries_replayed > 0
    assert rep.archive_remaining == 0
    assert rep.archived_total == rep.retries_replayed
    assert rep.degraded_events > 0  # degraded mode engaged mid-outage


def test_faults_off_keeps_report_inert(tmp_path):
    rep = run_scenario("steady_state", ticks=10, node_cap=1 << 10,
                       edge_cap=1 << 11, spill_dir=str(tmp_path / "sp"))
    assert rep.commit_failures == 0 and rep.retries_replayed == 0
    assert rep.store_digest == "" and rep.snapshot_digest == ""
    assert rep.resumed_from_tick == -1
    assert "commit_failures" in rep.to_dict()  # JSON-safe


def test_sharded_kill_resume_bit_exact(tmp_path):
    """The contract holds across shards too: per-shard buffers,
    controllers, and hub counters all ride in the checkpoint."""
    kw = dict(_CHAOS_KW, shards=2, ticks=32)
    plan = FaultPlan(fail_times=((8.0, 12.0),), crash_at_tick=16)
    ref = run_scenario("flash_crowd", fault_plan=plan.without_crash(),
                       spill_dir=str(tmp_path / "ref"), **kw)
    with pytest.raises(PipelineKilled):
        run_scenario("flash_crowd", fault_plan=plan,
                     checkpoint_dir=str(tmp_path / "ck"),
                     spill_dir=str(tmp_path / "chaos"), **kw)
    res = run_scenario("flash_crowd", fault_plan=plan.without_crash(),
                       checkpoint_dir=str(tmp_path / "ck"), resume=True,
                       spill_dir=str(tmp_path / "chaos"), **kw)
    assert res.store_digest == ref.store_digest
    assert res.snapshot_digest == ref.snapshot_digest
    assert res.total_records == ref.total_records


def test_pool_overflow_surfaces_in_metrics_and_report(tmp_path):
    """A wedged commit path (pool admits nothing) holds batches up to
    the hard cap, then diverts to the archive — and the overflow
    surfaces through the MetricsHub as a `pool_overflow` event."""
    from repro.api import PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig
    from repro.workloads.source import ScenarioSource

    events = []
    src = ScenarioSource("flash_crowd", seed=1)
    pipe = (PipelineBuilder(IngestConfig(store_nodes=1 << 12,
                                         store_edges=1 << 14))
            .with_source(src)
            .simulated_consumer(speed=0.5)
            .spill_dir(str(tmp_path / "sp"))
            .on_event(lambda ev: events.append(ev.kind))
            .build())
    ing = pipe.sink.ingestor
    ing.max_pool_size = 0  # wedge the pool: nothing ever commits
    ing.pool_cap = 2
    pipe.run(max_ticks=20)
    assert len(ing.pool) == 2  # held up to the hard cap, no further
    assert ing.pool_overflows > 0
    assert "pool_overflow" in events
    assert ing.archived_total == ing.replayed + ing.archive_depth
    assert ing.archived_total == ing.pool_overflows
