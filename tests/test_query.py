"""Query subsystem: sketch bounds, kernel parity, CSR snapshot
round-trip, engine-vs-brute-force, and the end-to-end pipeline demo."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_table import from_raw_batch
from repro.core.transform import RawEdgeBatch
from repro.graphstore.store import init_store, ingest_step
from repro.query import (
    build_snapshot,
    degree_distribution,
    edge_lookup,
    init_sketch,
    k_hop,
    sketch_degree,
    sketch_edge_weight,
    sketch_heavy_hitters,
    sketch_update,
    top_k_degree,
    triangle_count,
)
from repro.query.sketch import _merge_top_k, sketch_scatter_ref


def _raw(src, dst, etype):
    n = len(src)
    return RawEdgeBatch(
        src=np.asarray(src, np.uint64), dst=np.asarray(dst, np.uint64),
        etype=np.asarray(etype, np.int32),
        src_type=np.zeros(n, np.int32), dst_type=np.zeros(n, np.int32),
        n_records=n,
    )


def _table(rng, n=256, n_keys=50, cap=512, n_types=3):
    src = rng.integers(1, n_keys, size=n)
    dst = rng.integers(1, n_keys, size=n)
    et = rng.integers(0, n_types, size=n)
    return src, dst, et, from_raw_batch(_raw(src, dst, et), cap)


def _ingest_batches(rng, store, batches=5, n=256, n_keys=80):
    """Ingest several batches; returns (store, exact edge-count dict)."""
    want = {}
    for _ in range(batches):
        src, dst, et, tbl = _table(rng, n=n, n_keys=n_keys)
        for s, d, t in zip(src, dst, et):
            want[(int(s), int(d), int(t))] = want.get((int(s), int(d), int(t)), 0) + 1
        store, _ = ingest_step(store, tbl)
    return store, want


def _snapshot_edges(snap):
    """Brute-force extraction: {(src_key, dst_key, etype): count}."""
    ncap = snap.node_cap
    er, ec = np.asarray(snap.edge_row), np.asarray(snap.edge_col)
    live = er < ncap
    nk = np.asarray(snap.node_key)
    tt, cc = np.asarray(snap.edge_type), np.asarray(snap.edge_count)
    out = {}
    for r, c, t, cnt in zip(er[live], ec[live], tt[live], cc[live]):
        key = (int(nk[r]), int(nk[c]), int(t))
        assert key not in out, f"edge {key} appears twice in the snapshot"
        out[key] = int(cnt)
    return out


# ---------------------------------------------------------------------------
# sketch: Pallas kernel parity + CMS guarantees
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth,width,n", [(2, 128, 64), (4, 128, 256), (3, 256, 512)])
def test_sketch_kernel_matches_oracle(depth, width, n, rng):
    from repro.kernels import ops

    ew = jnp.asarray(rng.integers(0, 50, size=(depth, width, width)).astype(np.int32))
    od = jnp.asarray(rng.integers(0, 50, size=(depth, width)).astype(np.int32))
    idg = jnp.asarray(rng.integers(0, 50, size=(depth, width)).astype(np.int32))
    r = jnp.asarray(rng.integers(0, width, size=(depth, n)).astype(np.int32))
    c = jnp.asarray(rng.integers(0, width, size=(depth, n)).astype(np.int32))
    cnt = jnp.asarray(rng.integers(0, 5, size=n).astype(np.int32))
    got = ops.sketch_scatter(ew, od, idg, r, c, cnt)
    want = sketch_scatter_ref(ew, od, idg, r, c, cnt)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_sketch_update_kernel_path_bit_exact(rng):
    _, _, _, tbl = _table(rng)
    sk0 = init_sketch(depth=4, width=128)
    a = sketch_update(sk0, tbl, use_kernel=False)
    b = sketch_update(sk0, tbl, use_kernel=True)
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name)


def test_sketch_upper_bounds_and_tracks_exact(rng):
    src, dst, et, tbl = _table(rng, n=512, n_keys=60, cap=1024)
    sk = sketch_update(init_sketch(depth=4, width=256), tbl)
    keys = np.unique(np.concatenate([src, dst]))
    est = np.asarray(sketch_degree(sk, jnp.asarray(keys, sk.hh_keys.dtype)))
    exact = np.asarray([(src == u).sum() + (dst == u).sum() for u in keys])
    assert (est >= exact).all()
    n_total = int(sk.n_updates)
    assert (est - exact).mean() <= max(2.0, 8.0 * n_total / 256)

    q = rng.integers(0, 512, size=64)
    ew = np.asarray(sketch_edge_weight(
        sk, jnp.asarray(src[q], sk.hh_keys.dtype), jnp.asarray(dst[q], sk.hh_keys.dtype)))
    exact_ew = np.asarray([((src == s) & (dst == d)).sum()
                           for s, d in zip(src[q], dst[q])])
    assert (ew >= exact_ew).all()
    assert (ew - exact_ew).mean() <= max(2.0, 8.0 * n_total / 256)


def test_sketch_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        edges=st.lists(
            st.tuples(st.integers(1, 30), st.integers(1, 30), st.integers(0, 2)),
            min_size=1, max_size=100),
    )
    def check(edges):
        src = [e[0] for e in edges]
        dst = [e[1] for e in edges]
        et = [e[2] for e in edges]
        tbl = from_raw_batch(_raw(src, dst, et), 128)
        sk = sketch_update(init_sketch(depth=4, width=256), tbl)
        keys = sorted({*src, *dst})
        est = np.asarray(sketch_degree(sk, jnp.asarray(keys, sk.hh_keys.dtype)))
        exact = np.asarray([sum(s == u for s in src) + sum(d == u for d in dst)
                            for u in keys])
        assert (est >= exact).all()
        # degree sketch of distinct keys tracks exact closely at this load
        assert (est - exact).mean() <= max(2.0, 8.0 * len(edges) / 256)

    check()


def test_merge_top_k_keeps_heaviest():
    hk = jnp.asarray([10, 11, 0, 0], jnp.uint32)
    hc = jnp.asarray([5, 3, 0, 0], jnp.int32)
    ck = jnp.asarray([11, 12, 13, 0], jnp.uint32)
    cc = jnp.asarray([7, 9, 1, -1], jnp.int32)
    keys, counts = _merge_top_k(hk, hc, ck, cc)
    got = dict(zip(np.asarray(keys).tolist(), np.asarray(counts).tolist()))
    got.pop(0, None)
    # 11 deduplicates to its max estimate; top-4 of {10:5, 11:7, 12:9, 13:1}
    assert got == {12: 9, 11: 7, 10: 5, 13: 1}


def test_sketch_heavy_hitters_find_hot_nodes(rng):
    # one node participates in half of all edges
    n = 512
    src = rng.integers(2, 40, size=n)
    src[: n // 2] = 1
    dst = rng.integers(2, 40, size=n)
    tbl = from_raw_batch(_raw(src, dst, np.zeros(n, np.int32)), 1024)
    sk = sketch_update(init_sketch(depth=4, width=256, hh_slots=32), tbl)
    hk, hc = sketch_heavy_hitters(sk, 3)
    assert int(np.asarray(hk)[0]) == 1
    assert int(np.asarray(hc)[0]) >= n // 2


# ---------------------------------------------------------------------------
# store invariants (regression: -1 scatter targets used to WRAP to the
# last slot under mode="drop", corrupting counts/degrees/last edge)
# ---------------------------------------------------------------------------


def test_store_degree_and_count_invariants(rng):
    store, want = _ingest_batches(rng, init_store(1 << 10, 1 << 12), batches=6)
    nd = np.asarray(store.node_degree)
    assert nd.sum() == 2 * int(store.n_edges)
    ud = {}
    for (s, d, _t) in want:
        ud[s] = ud.get(s, 0) + 1
        ud[d] = ud.get(d, 0) + 1
    assert nd.max() == max(ud.values())
    assert int(np.asarray(store.edge_count).sum()) == sum(want.values())


# ---------------------------------------------------------------------------
# snapshot: CSR round-trip
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip(rng):
    store, want = _ingest_batches(rng, init_store(1 << 10, 1 << 12), batches=5)
    snap = build_snapshot(store)
    assert int(snap.n_nodes) == int(store.n_nodes)
    assert int(snap.n_edges) == int(store.n_edges)
    got = _snapshot_edges(snap)  # asserts each edge appears exactly once
    assert got == want
    # CSR structure: indptr row sums == per-row edge counts, cols sorted
    indptr = np.asarray(snap.indptr)
    er, ec = np.asarray(snap.edge_row), np.asarray(snap.edge_col)
    live = er < snap.node_cap
    assert indptr[-1] == live.sum()
    for r in range(int(snap.n_nodes)):
        lo, hi = indptr[r], indptr[r + 1]
        assert (er[lo:hi] == r).all()
        assert (np.diff(ec[lo:hi]) >= 0).all()
    # node metadata preserved under the sort
    ud = {}
    for (s, d, _t) in want:
        ud[s] = ud.get(s, 0) + 1
        ud[d] = ud.get(d, 0) + 1
    nk = np.asarray(snap.node_key)[: int(snap.n_nodes)]
    deg = np.asarray(snap.node_degree)[: int(snap.n_nodes)]
    assert {int(k): int(v) for k, v in zip(nk, deg)} == ud


def test_snapshot_empty_store():
    snap = build_snapshot(init_store(1 << 8, 1 << 9))
    assert int(snap.n_nodes) == 0 and int(snap.n_edges) == 0
    assert not np.asarray(snap.edge_valid).any()


# ---------------------------------------------------------------------------
# engine vs brute force
# ---------------------------------------------------------------------------


@pytest.fixture
def graph(rng):
    store, want = _ingest_batches(rng, init_store(1 << 10, 1 << 12), batches=4)
    snap = build_snapshot(store)
    adj = {}
    ud = {}
    for (s, d, _t) in want:
        adj.setdefault(s, set()).add(d)
        adj.setdefault(d, set()).add(s)
        ud[s] = ud.get(s, 0) + 1
        ud[d] = ud.get(d, 0) + 1
    return snap, want, adj, ud


def test_degree_distribution_matches_bincount(graph):
    snap, _want, _adj, _ud = graph
    hist = np.asarray(degree_distribution(snap, num_bins=32))
    deg = np.asarray(snap.node_degree)[: int(snap.n_nodes)]
    np.testing.assert_array_equal(hist, np.bincount(np.clip(deg, 0, 31),
                                                    minlength=32))
    assert hist.sum() == int(snap.n_nodes)


def test_top_k_matches_sorted_degrees(graph):
    snap, _want, _adj, ud = graph
    keys, degs = top_k_degree(snap, 8)
    assert np.asarray(degs).tolist() == sorted(ud.values(), reverse=True)[:8]
    for k, d in zip(np.asarray(keys), np.asarray(degs)):
        assert ud[int(k)] == int(d)


@pytest.mark.parametrize("hops", [1, 2, 3])
@pytest.mark.parametrize("directed", [False, True])
def test_k_hop_matches_bfs(graph, hops, directed):
    snap, want, adj, _ud = graph
    if directed:
        adj = {}
        for (s, d, _t) in want:
            adj.setdefault(s, set()).add(d)
    seed = next(iter(sorted(adj)))
    mask = np.asarray(k_hop(snap, jnp.asarray([seed], snap.node_key.dtype),
                            hops=hops, directed=directed))
    frontier = {seed}
    for _ in range(hops):
        frontier |= {v for u in frontier for v in adj.get(u, ())}
    nk = np.asarray(snap.node_key)
    assert {int(k) for k, m in zip(nk, mask) if m} == frontier


def test_k_hop_absent_seed_is_empty(graph):
    snap, _w, _a, _u = graph
    mask = np.asarray(k_hop(snap, jnp.asarray([999_999_937],
                                              snap.node_key.dtype), hops=2))
    assert not mask.any()


def test_triangle_count_matches_bruteforce(graph):
    snap, want, _adj, ud = graph
    nodes = sorted(ud)
    idx = {u: i for i, u in enumerate(nodes)}
    a = np.zeros((len(nodes), len(nodes)), np.int64)
    for (s, d, _t) in want:
        if s != d:
            a[idx[s], idx[d]] = a[idx[d], idx[s]] = 1
    assert int(triangle_count(snap)) == int(np.trace(a @ a @ a)) // 6


def test_triangle_count_guards_dense_capacity():
    snap = build_snapshot(init_store(1 << 8, 1 << 9))
    with pytest.raises(ValueError):
        triangle_count(snap, max_dense_nodes=64)


def test_edge_lookup_matches_dict(graph, rng):
    snap, want, _adj, _ud = graph
    pair_w = {}
    for (s, d, _t), c in want.items():
        pair_w[(s, d)] = pair_w.get((s, d), 0) + c
    pairs = list(pair_w) + [(1, 999_999), (999_999, 1)]
    srcs = jnp.asarray([p[0] for p in pairs], snap.node_key.dtype)
    dsts = jnp.asarray([p[1] for p in pairs], snap.node_key.dtype)
    got = np.asarray(edge_lookup(snap, srcs, dsts))
    wantv = np.asarray([pair_w.get(p, 0) for p in pairs])
    np.testing.assert_array_equal(got, wantv)


# ---------------------------------------------------------------------------
# end-to-end: pipeline with SketchStage + QuerySink
# ---------------------------------------------------------------------------


def test_end_to_end_pipeline_sketch_and_snapshot(tmp_path):
    from repro.api import GraphStoreSink, PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig
    from repro.ingest.sources import BurstyTweetSource
    from repro.query import SketchStage

    # caps sized for low load factor: open addressing degrades near full
    cfg = IngestConfig(store_nodes=1 << 13, store_edges=1 << 15)
    stage = SketchStage(depth=4, width=256)
    events = []
    pipe = (PipelineBuilder(cfg)
            .with_source(BurstyTweetSource(seed=3, mean_rate=40.0))
            .with_sink(GraphStoreSink(node_cap=1 << 13, edge_cap=1 << 15))
            .with_sketch(stage)
            .with_query_sink(depth=4, width=256, answer_every=2, top_k=3)
            .spill_dir(str(tmp_path / "spill"))
            .on_event(lambda ev: events.append(ev) if ev.kind == "sketch" else None)
            .build())
    rep = pipe.run(max_ticks=40)
    assert rep.total_records > 0
    store = pipe.store
    assert int(store.n_edges) > 0

    # live sketch events flowed during ingestion
    assert events and events[-1].payload["commits"] >= 2
    assert events[-1].payload["hh_keys"][0] != 0

    snap = build_snapshot(store)
    assert int(snap.n_nodes) == int(store.n_nodes)
    assert int(snap.n_edges) == int(store.n_edges)

    # exact top-k vs both sketches: sketch answers upper-bound exact
    keys, degs = top_k_degree(snap, 5)
    keys, degs = np.asarray(keys), np.asarray(degs)
    live = keys != 0
    filter_est = stage.degree(keys[live])
    commit_est = pipe.sink.degree(keys[live])
    assert (filter_est >= degs[live]).all()
    assert (commit_est >= degs[live]).all()
    # filter-time sketch saw everything the commit-time sketch saw
    assert int(stage.sketch.n_updates) >= int(pipe.sink.sketch.n_updates)

    # committed-edge weights: sketch upper-bounds the exact lookup
    er = np.asarray(snap.edge_row)
    take = np.flatnonzero(er < snap.node_cap)[:16]
    nk = np.asarray(snap.node_key)
    s_keys = nk[er[take]]
    d_keys = nk[np.asarray(snap.edge_col)[take]]
    exact_w = np.asarray(edge_lookup(snap, jnp.asarray(s_keys, snap.node_key.dtype),
                                     jnp.asarray(d_keys, snap.node_key.dtype)))
    est_w = pipe.sink.edge_weight(s_keys, d_keys)
    assert (est_w >= exact_w).all()


def test_query_sink_absorbs_retried_and_pooled_commits(rng):
    """Commit-consistency under failures: batches that reach the store
    via archive replay (and pool drains) must also reach the sketch."""
    from repro.api import GraphStoreSink
    from repro.query import QuerySink

    fails = iter([False, True, False])  # 2nd commit raises -> archived
    sink = QuerySink(GraphStoreSink(node_cap=1 << 10, edge_cap=1 << 11,
                                    fail_hook=lambda: next(fails, False)),
                     depth=2, width=128)
    tables = [_table(rng, n=128, n_keys=40)[3] for _ in range(3)]
    outs = [sink.commit(t, now=float(i)) for i, t in enumerate(tables)]
    assert outs[1]["committed"] is False  # archived
    committed_total = sum(int(t.count.sum()) for i, t in enumerate(tables)
                          if outs[i]["committed"])
    assert int(sink.sketch.n_updates) == committed_total
    assert sink.retry_archive(now=3.0) == 1  # replay reaches the sketch too
    assert int(sink.sketch.n_updates) == sum(int(t.count.sum()) for t in tables)
    assert int(np.asarray(sink.store.edge_count).sum()) == int(sink.sketch.n_updates)


def test_with_sketch_inherits_builder_mapping(tmp_path):
    """with_sketch() without an explicit stage must observe the same
    edges the transform commits (builder mapping + batch cap)."""
    from repro.api import PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig
    from repro.core.transform import tweet_mapping

    mapping = tweet_mapping()
    b = (PipelineBuilder(IngestConfig(max_edges_per_batch=2048))
         .with_mapping(mapping)
         .with_sketch(width=128))
    pipe = b.build()
    stage = b.sketch_stage
    assert stage is pipe.stages[0]
    assert stage.mapping is mapping
    assert stage.max_edges_per_batch == 2048


def test_sharded_pipeline_accepts_stages(tmp_path):
    from repro.api import PipelineBuilder
    from repro.configs.paper_ingest import IngestConfig
    from repro.ingest.sources import BurstyTweetSource
    from repro.query import SketchStage

    stage = SketchStage(depth=2, width=128)
    pipe = (PipelineBuilder(IngestConfig(store_nodes=1 << 12, store_edges=1 << 14))
            .with_source(BurstyTweetSource(seed=1, mean_rate=30.0))
            .with_sketch(stage)
            .sharded(2)
            .spill_dir(str(tmp_path / "spill"))
            .build())
    rep = pipe.run(max_ticks=15)
    assert rep.total_records > 0
    assert int(stage.sketch.n_updates) > 0
