"""Checkpointing, fault tolerance, gradient compression, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeSpec, get_config, smoke_config
from repro.distributed.fault import FaultTolerantRunner
from repro.distributed.grad_compression import (
    int8_roundtrip,
    make_compressor,
    topk_roundtrip,
    wire_bytes,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, schedule
from repro.train.trainstep import init_state, make_train_step


def _mk(arch="qwen2.5-3b"):
    cfg = smoke_config(get_config(arch))
    shape = ShapeSpec("t", 32, 4, "train")
    state = init_state(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    return cfg, shape, state, {"tokens": tokens, "labels": tokens}


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    cfg, shape, state, batch = _mk()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, blocking=True)
    state2 = mgr.restore(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.latest_step() == 7


def test_checkpoint_atomicity(tmp_path):
    """Torn checkpoints (no _COMMITTED) are invisible to restore."""
    cfg, shape, state, batch = _mk()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=True)
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_checkpoint_gc_keeps_latest(tmp_path):
    cfg, shape, state, batch = _mk()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.list_steps() == [3, 4]


def test_training_resumes_identically(tmp_path):
    """ckpt at step k, run 2 more, vs uninterrupted run: same loss."""
    cfg, shape, state, batch = _mk()
    step, _ = make_train_step(cfg, shape, dp=1)
    jstep = jax.jit(step)
    mgr = CheckpointManager(str(tmp_path))

    s = state
    for _ in range(2):
        s, m0 = jstep(s, batch)
    mgr.save(2, s, blocking=True)
    for _ in range(2):
        s, m_ref = jstep(s, batch)

    s2 = mgr.restore(state)
    for _ in range(2):
        s2, m_res = jstep(s2, batch)
    assert abs(float(m_ref["loss"]) - float(m_res["loss"])) < 1e-6


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_fault_runner_recovers(tmp_path):
    cfg, shape, state, batch = _mk()
    mgr = CheckpointManager(str(tmp_path))

    def make_step(dp):
        f, _ = make_train_step(cfg, shape, dp=1)
        return jax.jit(f)

    def batches():
        while True:
            yield batch

    runner = FaultTolerantRunner(
        mgr, make_step, lambda: init_state(cfg, jax.random.key(0)),
        dp_size=2, ckpt_every=5, fail_schedule={8: "crash"},
    )
    state2, hist = runner.run(state, batches(), max_steps=12)
    kinds = [e.kind for e in runner.events]
    assert "failure" in kinds and "recovered" in kinds
    assert runner.dp == 1  # elastic shrink
    assert len(hist) >= 12
    assert not np.isnan(hist[-1]["loss"])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.normal(0, 3.0, size=(1000,)).astype(np.float32))
    y = int8_roundtrip(x)
    block_max = np.abs(np.asarray(x)).reshape(-1, 250 if False else 8 * 25)  # noqa
    err = np.abs(np.asarray(y - x))
    # per-block quantisation error <= scale/2 = blockmax/254
    assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 + 1e-6


def test_topk_keeps_largest(rng):
    x = jnp.asarray(rng.normal(size=(100,)).astype(np.float32))
    y = topk_roundtrip(x, frac=0.1)
    nz = np.nonzero(np.asarray(y))[0]
    assert len(nz) == 10
    thresh = np.sort(np.abs(np.asarray(x)))[-10]
    assert (np.abs(np.asarray(x)[nz]) >= thresh - 1e-6).all()


def test_error_feedback_is_unbiased_over_time(rng):
    """sum(sent_t) -> sum(g_t): residuals don't leak signal."""
    init, compress = make_compressor("int8")
    g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    err = init(g)
    total_sent = jnp.zeros((64,))
    for _ in range(50):
        sent, err = compress(g, err)
        total_sent = total_sent + sent["w"]
    avg_sent = np.asarray(total_sent) / 50
    np.testing.assert_allclose(avg_sent, np.asarray(g["w"]), atol=2e-2)


def test_wire_bytes_model():
    assert wire_bytes(1_000_000, "int8") < 0.3 * wire_bytes(1_000_000, "none")
    assert wire_bytes(1_000_000, "topk", 0.05) < 0.5 * wire_bytes(1_000_000, "none")


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_moves_params_and_clips():
    oc = OptConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0)
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}  # huge grad -> clipped
    st = init_opt_state(p)
    p2, st2, m = adamw_update(oc, p, g, st)
    assert float(m["grad_norm"]) > 1.0
    assert not bool(jnp.isnan(p2["w"]).any())
    assert float(jnp.abs(p2["w"] - p["w"]).max()) < 0.1  # clip bounded the step


def test_schedule_warmup_and_decay():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(oc, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(oc, jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
