"""Deliverable (f): per-architecture smoke tests.

Every assigned arch instantiates a REDUCED same-family config and runs
one forward + one train step on CPU, asserting output shapes and no
NaNs.  The FULL configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, ShapeSpec, all_arch_ids, get_config, smoke_config
from repro.distributed.sharding import init_params
from repro.models import model as M
from repro.train.trainstep import init_state, make_train_step

ARCHS = [
    "zamba2-7b", "mamba2-780m", "mixtral-8x7b", "qwen2-moe-a2.7b",
    "llama3-405b", "qwen2.5-3b", "stablelm-1.6b", "qwen3-4b",
    "phi-3-vision-4.2b", "whisper-medium",
]


def _batch_for(cfg, B, S, key=1):
    tokens = jax.random.randint(jax.random.key(key), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (B, cfg.num_patches, cfg.d_model), jnp.bfloat16
        )
        batch["labels"] = jax.random.randint(
            jax.random.key(3), (B, S + cfg.num_patches), 0, cfg.vocab_size
        )
    return batch


def test_all_archs_registered():
    assert set(ARCHS) <= set(all_arch_ids())


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_config(get_config(arch))
    params = init_params(M.param_specs(cfg), jax.random.key(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    logits, aux = M.forward(params, cfg, batch)
    S_out = S + (cfg.num_patches or 0)
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = smoke_config(get_config(arch))
    shape = ShapeSpec("smoke", 32, 4, "train")
    state = init_state(cfg, jax.random.key(0))
    step, info = make_train_step(cfg, shape, dp=1)
    batch = _batch_for(cfg, 4, 32)
    jstep = jax.jit(step, donate_argnums=0)
    losses = []
    for _ in range(4):
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert not any(np.isnan(l) for l in losses)
    assert losses[-1] < losses[0], losses  # memorises the repeated batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_sane(arch):
    """Full (non-reduced) configs expose the advertised scale."""
    cfg = get_config(arch)
    total, active = cfg.param_count()
    expect = {
        "zamba2-7b": (6e9, 9e9), "mamba2-780m": (0.6e9, 1.0e9),
        "mixtral-8x7b": (40e9, 52e9), "qwen2-moe-a2.7b": (12e9, 16e9),
        "llama3-405b": (390e9, 420e9), "qwen2.5-3b": (2.6e9, 3.5e9),
        "stablelm-1.6b": (1.2e9, 2.0e9), "qwen3-4b": (3.5e9, 4.6e9),
        "phi-3-vision-4.2b": (3.5e9, 4.5e9), "whisper-medium": (0.6e9, 0.9e9),
    }[arch]
    assert expect[0] <= total <= expect[1], (arch, total)
    assert active <= total
