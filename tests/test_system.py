"""End-to-end behaviour tests for the paper's system (§IV claims)."""
import numpy as np
import pytest

from repro.configs.paper_ingest import IngestConfig
from repro.core.pipeline import IngestionPipeline
from repro.ingest.sources import BurstyTweetSource


def _run(uncontrolled, compress, ticks=150, seed=3, **cfg_kw):
    cfg = IngestConfig(**cfg_kw)
    src = BurstyTweetSource(seed=seed)
    pipe = IngestionPipeline(
        cfg, uncontrolled=uncontrolled, compress=compress,
        spill_dir=f"/tmp/repro_spill_sys_{uncontrolled}_{compress}",
    )
    return pipe.run(src.ticks(), max_ticks=ticks), pipe


def test_controlled_beats_uncontrolled_on_load():
    """Fig 7 vs Fig 12: the controller keeps consumer load bounded."""
    r_unc, _ = _run(uncontrolled=True, compress=False)
    r_ctl, _ = _run(uncontrolled=False, compress=True)
    mu_u, mu_c = r_unc.samples["mu"], r_ctl.samples["mu"]
    assert mu_c.max() <= mu_u.max() + 1e-9
    assert (mu_c > 0.95).mean() < (mu_u > 0.95).mean() + 1e-9
    # delay (Eq. 3) improves too
    assert r_ctl.samples["delay_s"].max() <= r_unc.samples["delay_s"].max() + 1e-9


def test_compression_reduces_instruction_load():
    """Compression cuts the effective insert-instruction stream."""
    r, _ = _run(uncontrolled=False, compress=True)
    assert r.total_instructions < r.raw_instructions
    assert 0.05 < r.mean_compression < 0.95


def test_compression_better_during_bursts():
    """Fig 13 narrative: a hashtag storm (few hot tags, heavy retweets)
    compresses better than a diverse calm day."""
    # uncontrolled+compress isolates the compression measurement from the
    # controller (which rightly throttles a *permanent* 5x storm)
    src = BurstyTweetSource(seed=5, p_burst_start=1.0, p_burst_end=0.0,
                            burst_hashtags=6, duplicate_frac=0.2)  # storm
    pipe = IngestionPipeline(IngestConfig(), uncontrolled=True, compress=True,
                             spill_dir="/tmp/repro_spill_b1")
    r_burst = pipe.run(src.ticks(), max_ticks=80)
    src2 = BurstyTweetSource(seed=5, p_burst_start=0.0, n_hashtags=20_000,
                             duplicate_frac=0.05)  # diverse calm day
    pipe2 = IngestionPipeline(IngestConfig(), uncontrolled=True, compress=True,
                              spill_dir="/tmp/repro_spill_b2")
    r_calm = pipe2.run(src2.ticks(), max_ticks=80)
    assert r_burst.mean_compression < r_calm.mean_compression


def test_store_consistent_with_stream():
    """Every unique node that entered the pipeline exists in the store."""
    r, pipe = _run(uncontrolled=False, compress=True, ticks=60)
    store = pipe.ingestor.store
    assert int(store.n_nodes) > 0
    assert int(store.n_edges) > 0
    # edge-count conservation: stored counts == committed raw edges
    assert int(store.edge_count.sum()) <= r.raw_instructions


def test_throttling_rare_under_normal_load():
    """Paper: 'only on rare occasions resort to spilling'."""
    r, _ = _run(uncontrolled=False, compress=True, ticks=200)
    assert r.spill_events <= 0.1 * len(r.actions)


def test_commit_failure_archives_and_retries():
    """Algorithm 3: failed commits archive, then replay."""
    from repro.core.edge_table import from_raw_batch
    from repro.core.transform import create_edges, tweet_mapping
    from repro.core.ingestor import GraphIngestor
    from repro.graphstore.store import init_store

    recs = [{"id": f"t{i}", "user": f"u{i}", "hashtags": ["x"], "mentions": []}
            for i in range(10)]
    et = from_raw_batch(create_edges(recs, tweet_mapping()), 64)
    fail = {"on": True}
    ing = GraphIngestor(init_store(512, 1024), fail_hook=lambda: fail["on"])
    out = ing.push(et)
    assert not out["committed"] and len(ing.archive) == 1
    fail["on"] = False
    assert ing.retry_archive() == 1
    assert int(ing.store.n_nodes) > 0
