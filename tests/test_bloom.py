"""Parity + property tests for the blocked Bloom filter kernel.

`kernels/bloom.py` powers the pre-commit bucket-diversity signal rho
(§III-A).  The build/probe pair is validated against a bit-for-bit
numpy re-implementation of the hash rounds, and the Bloom contract is
asserted directly: NO false negatives, ever (false positives allowed
and measured).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bloom as B
from repro.kernels import ops


def _np_hash_round(keys: np.ndarray, r: int) -> np.ndarray:
    c1 = np.uint32((0x9E3779B9 + 0x7F4A7C15 * r) & 0xFFFFFFFF)
    c2 = np.uint32(0x85EBCA6B)
    x = ((keys + c1) * c2).astype(np.uint32)
    x = x ^ (x >> np.uint32(13))
    x = (x * np.uint32(0xC2B2AE35)).astype(np.uint32)
    return x ^ (x >> np.uint32(16))


def _np_bit_coords(keys: np.ndarray, r: int, words: int):
    h = _np_hash_round(keys, r)
    return (h >> np.uint32(5)) % np.uint32(words), h % np.uint32(32)


def _np_build(keys: np.ndarray, bitmap: np.ndarray) -> np.ndarray:
    flat = bitmap.reshape(-1).copy()
    words = flat.shape[0]
    for r in range(B.HASHES):
        w, b = _np_bit_coords(keys, r, words)
        for wi, bi in zip(w.tolist(), b.tolist()):
            flat[wi] |= np.uint32(1 << bi)
    return flat.reshape(bitmap.shape)


def _np_probe(keys: np.ndarray, bitmap: np.ndarray) -> np.ndarray:
    flat = bitmap.reshape(-1)
    words = flat.shape[0]
    hit = np.ones(keys.shape, np.int32)
    for r in range(B.HASHES):
        w, b = _np_bit_coords(keys, r, words)
        hit &= ((flat[w] >> b) & np.uint32(1)).astype(np.int32)
    return hit


def _keys(rng, n, hi=10_000):
    return rng.integers(1, hi, size=n).astype(np.uint32)


@pytest.mark.parametrize("rows,n", [(4, 128), (16, 256)])
def test_build_matches_numpy_oracle(rng, rows, n):
    keys = _keys(rng, n)
    bitmap = B.init_bitmap(rows)
    built = ops.bloom_build(jnp.asarray(keys), bitmap)
    expect = _np_build(keys, np.asarray(bitmap))
    assert (np.asarray(built) == expect).all()


@pytest.mark.parametrize("rows", [4, 16])
def test_probe_matches_numpy_oracle(rng, rows):
    inserted = _keys(rng, 200)
    queries = np.concatenate([inserted[:100], _keys(rng, 100, hi=1 << 30)])
    bitmap = ops.bloom_build(jnp.asarray(inserted), B.init_bitmap(rows))
    hits = ops.bloom_probe(jnp.asarray(queries), bitmap)
    expect = _np_probe(queries, np.asarray(bitmap))
    assert (np.asarray(hits) == expect).all()


def test_no_false_negatives(rng):
    """The Bloom contract: every inserted key MUST probe as present."""
    for trial in range(5):
        keys = _keys(rng, 256, hi=1 << 31)
        bitmap = ops.bloom_build(jnp.asarray(keys), B.init_bitmap(8))
        hits = np.asarray(ops.bloom_probe(jnp.asarray(keys), bitmap))
        assert (hits == 1).all(), f"false negative in trial {trial}"


def test_false_positive_rate_bounded(rng):
    """At ~1.6% fill (512 keys x 4 hashes in 64x32768 bits) the false-
    positive rate must be far under 1% — a sanity bound, not the exact
    (1-e^{-kn/m})^k formula."""
    inserted = _keys(rng, 512, hi=1 << 20)
    bitmap = ops.bloom_build(jnp.asarray(inserted), B.init_bitmap(64))
    fresh = (rng.integers(1 << 20, 1 << 30, size=4096)).astype(np.uint32)
    hits = np.asarray(ops.bloom_probe(jnp.asarray(fresh), bitmap))
    assert hits.mean() < 0.01


def test_empty_bitmap_probe_all_misses(rng):
    keys = _keys(rng, 128)
    hits = np.asarray(ops.bloom_probe(jnp.asarray(keys), B.init_bitmap(4)))
    assert (hits == 0).all()


def test_build_idempotent(rng):
    """Re-inserting the same keys cannot change the bitmap."""
    keys = jnp.asarray(_keys(rng, 256))
    once = ops.bloom_build(keys, B.init_bitmap(8))
    twice = ops.bloom_build(keys, once)
    assert jnp.array_equal(once, twice)


def test_build_monotone(rng):
    """Building only SETS bits: the old bitmap is a subset of the new."""
    a = ops.bloom_build(jnp.asarray(_keys(rng, 128)), B.init_bitmap(8))
    b = ops.bloom_build(jnp.asarray(_keys(rng, 128, hi=1 << 29)), a)
    assert jnp.array_equal(jnp.bitwise_and(a, b), a)


def test_bloom_diversity_signal(rng):
    """rho = 1 on an all-fresh bucket, 0 on an exact replay."""
    keys = jnp.asarray(_keys(rng, 256, hi=1 << 28))
    rho_fresh, bitmap = ops.bloom_diversity(keys, B.init_bitmap(32))
    assert float(rho_fresh) == 1.0
    rho_replay, _ = ops.bloom_diversity(keys, bitmap)
    assert float(rho_replay) == 0.0
