"""repro.lineage tests (ISSUE 10): watermark-set arithmetic, tag path
classification, record conservation, the monotone-watermark property
(hypothesis when available), the e2e freshness report, the flash_crowd
+ store-outage acceptance run (archive-path attribution + freshness
burn alert onset/clear), kill/resume watermark determinism, and the
flow-event / JSONL / Prometheus exporters."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.lineage import (
    BatchTag,
    LineageTracker,
    flow_events,
    freshness_table,
    prometheus_lines,
    sample_tags,
    validate_flow_events,
    watermark_timeline,
    write_lineage_jsonl,
)
from repro.lineage.tracker import _WatermarkSet
from repro.monitor import HealthMonitor
from repro.resilience import FaultPlan, PipelineKilled, RetryPolicy
from repro.workloads import run_scenario

CAPS = dict(node_cap=1 << 12, edge_cap=1 << 14)


def _recs(*ts):
    return [{"ts": float(t)} for t in ts]


# ---------------------------------------------------------------------------
# _WatermarkSet
# ---------------------------------------------------------------------------


def test_watermark_set_min_pending_then_max_seen():
    ws = _WatermarkSet()
    assert ws.watermark() is None  # nothing ever seen
    ws.add({1.0: 2, 3.0: 1})
    assert ws.watermark() == 1.0
    ws.remove({1.0: 2})
    assert ws.watermark() == 3.0
    ws.remove({3.0: 1})
    # fully drained: the stream is caught up to the newest event seen
    assert ws.watermark() == 3.0
    assert ws.depth == 0


def test_watermark_set_late_duplicate_reintroduces_old_ts():
    ws = _WatermarkSet()
    ws.add({5.0: 1})
    ws.remove({5.0: 1})
    assert ws.watermark() == 5.0
    ws.add({2.0: 1})  # a late event older than anything pending
    assert ws.watermark() == 2.0
    ws.remove({2.0: 1})
    assert ws.watermark() == 5.0  # max_seen, not the late ts


def test_watermark_set_partial_remove_keeps_ts_pending():
    ws = _WatermarkSet()
    ws.add({1.0: 3})
    ws.remove({1.0: 2})
    assert ws.watermark() == 1.0 and ws.depth == 1


def test_watermark_set_state_roundtrip():
    ws = _WatermarkSet()
    ws.add({1.0: 2, 7.0: 1})
    ws.remove({1.0: 1})
    ws2 = _WatermarkSet()
    ws2.restore_state(ws.state())
    assert ws2.watermark() == ws.watermark() == 1.0
    assert ws2.depth == ws.depth == 2
    assert ws2.max_seen == 7.0


# ---------------------------------------------------------------------------
# tag lifecycle + path classification
# ---------------------------------------------------------------------------


def test_tag_path_precedence():
    t = BatchTag(0, 1, 0.0, 0.0, 0.0, {0.0: 1})
    assert t.path == "direct"
    t.pooled = True
    assert t.path == "buffered"
    t.spilled = True
    assert t.path == "spilled"
    t.archived = True
    assert t.path == "archived"
    d = BatchTag(1, 1, 0.0, 0.0, 0.0, {0.0: 1}, degraded=True)
    assert d.path == "archived"  # degraded direct-put counts as archive


def test_tracker_commit_then_queryable_advances_watermarks():
    trk = LineageTracker(dt=1.0)
    recs = _recs(1.0, 1.0, 2.0)
    trk.observe_intake(recs)
    tag = trk.open_batch(recs, now=2.0)
    assert trk.watermarks()["committed"] is None  # nothing landed yet
    trk.mark_committed(tag, 2.0)
    wm = trk.watermarks()
    assert wm["committed"] == 2.0 and wm["pending_commit"] == 0
    # queryable lags until the snapshot absorbed the delta
    assert wm["queryable"] is None or wm["queryable"] <= 2.0
    assert wm["pending_queryable"] == 3
    trk.mark_queryable(tag, 3.0)
    wm = trk.watermarks()
    assert wm["queryable"] == 2.0 and wm["pending_queryable"] == 0
    assert trk.records_committed == 3
    assert tag.batch_id not in trk.open_tags
    assert trk.path_counts == {"direct": 1}


def test_tracker_buffered_classification_uses_event_age():
    trk = LineageTracker(dt=1.0, buffered_slack=0.5)
    fresh = trk.open_batch(_recs(5.0), now=5.0)
    stale = trk.open_batch(_recs(2.0, 3.0), now=5.0)
    assert not fresh.buffered and fresh.path == "direct"
    assert stale.buffered and stale.path == "buffered"


def test_tracker_dropped_batch_releases_both_watermarks():
    trk = LineageTracker()
    recs = _recs(1.0)
    trk.observe_intake(recs)
    tag = trk.open_batch(recs, now=1.0)
    trk.mark_dropped(tag, 2.0)
    wm = trk.watermarks()
    assert wm["pending_commit"] == 0 and wm["pending_queryable"] == 0
    assert trk.records_dropped == 1
    cons = trk.conservation()
    assert cons["imbalance"] == 0


def test_tracker_conservation_counts_open_tags_and_buffer():
    trk = LineageTracker()
    recs = _recs(1.0, 2.0, 3.0, 4.0)
    trk.observe_intake(recs)
    tag = trk.open_batch(recs[:2], now=2.0)  # two still in the buffer
    trk.mark_committed(tag, 2.0)
    trk.mark_queryable(tag, 2.0)
    cons = trk.conservation(buffered_records=2)
    assert cons["records_in"] == 4
    assert cons["records_committed"] == 2
    assert cons["records_in_flight"] == 2
    assert cons["imbalance"] == 0
    # an unaccounted record shows up as imbalance, not silence
    assert trk.conservation(buffered_records=1)["imbalance"] == 1


def test_tracker_state_roundtrip_preserves_watermarks_and_hists():
    trk = LineageTracker()
    recs = _recs(1.0, 2.0)
    trk.observe_intake(recs)
    tag = trk.open_batch(recs, now=2.0)
    trk.mark_committed(tag, 2.0)
    trk.mark_queryable(tag, 3.0)
    trk.observe_intake(_recs(4.0))  # leave something pending
    t2 = LineageTracker()
    t2.restore_state(trk.state())
    assert t2.watermarks() == trk.watermarks()
    assert t2.lag_percentiles_ms() == trk.lag_percentiles_ms()
    assert t2.conservation() == trk.conservation()
    assert [t.batch_id for t in t2.completed] == \
        [t.batch_id for t in trk.completed]


# ---------------------------------------------------------------------------
# monotone-watermark property (hypothesis when available)
# ---------------------------------------------------------------------------


def _apply_ops(ops):
    """Drive a tracker through (ts, action) ops; after every mark the
    watermarks must be monotone non-decreasing and Wq <= Wc."""
    trk = LineageTracker()
    open_tags = []
    last_c = last_q = None
    t_now = 0.0
    for ts_vals, action in ops:
        t_now += 1.0
        recs = _recs(*ts_vals)
        trk.observe_intake(recs)
        tag = trk.open_batch(recs, now=t_now)
        open_tags.append(tag)
        pick = open_tags[hash((action, len(open_tags))) % len(open_tags)]
        if action == "commit":
            trk.mark_committed(pick, t_now)
        elif action == "query":
            trk.mark_committed(pick, t_now)
            trk.mark_queryable(pick, t_now)
        elif action == "drop":
            trk.mark_dropped(pick, t_now)
        wm = trk.watermarks()
        wc, wq = wm["committed"], wm["queryable"]
        if last_c is not None and wc is not None:
            assert wc >= last_c, "committed watermark regressed"
        if last_q is not None and wq is not None:
            assert wq >= last_q, "queryable watermark regressed"
        if wc is not None and wq is not None:
            assert wq <= wc, "queryable watermark overtook committed"
        last_c = wc if wc is not None else last_c
        last_q = wq if wq is not None else last_q


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment without hypothesis
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(st.lists(
        st.tuples(
            st.lists(st.integers(min_value=0, max_value=12),
                     min_size=1, max_size=4),
            st.sampled_from(["commit", "query", "drop", "hold"])),
        min_size=1, max_size=30))
    def test_watermark_monotone_property(ops):
        _apply_ops(ops)

else:  # deterministic fallback exercising the same invariant

    def test_watermark_monotone_property():
        seqs = [
            [((1, 1), "query"), ((2,), "commit"), ((0,), "query"),
             ((3, 0), "drop"), ((5,), "query")],
            [((4,), "hold"), ((1,), "query"), ((1, 2, 3), "query"),
             ((2,), "drop"), ((9, 0), "commit"), ((9,), "query")],
        ]
        for ops in seqs:
            _apply_ops(ops)


def test_watermark_stalls_under_out_of_order_commits():
    """Committing newer batches first must NOT advance the watermark
    past the still-pending older batch."""
    trk = LineageTracker()
    old = _recs(1.0)
    new = _recs(2.0, 3.0)
    trk.observe_intake(old)
    trk.observe_intake(new)
    t_old = trk.open_batch(old, now=3.0)
    t_new = trk.open_batch(new, now=3.0)
    trk.mark_committed(t_new, 3.0)
    trk.mark_queryable(t_new, 3.0)
    assert trk.watermarks()["committed"] == 1.0  # stalled on the old one
    trk.mark_committed(t_old, 4.0)
    trk.mark_queryable(t_old, 4.0)
    assert trk.watermarks()["committed"] == 3.0


# ---------------------------------------------------------------------------
# e2e: run_scenario(lineage=...)
# ---------------------------------------------------------------------------


def test_e2e_steady_state_freshness_report(tmp_path):
    trk = LineageTracker()
    rep = run_scenario("steady_state", ticks=30, seed=0, lineage=trk,
                       spill_dir=str(tmp_path / "sp"), **CAPS)
    assert rep.lineage_enabled
    assert rep.records_in > 0
    assert rep.records_committed > 0
    assert not rep.conservation_warning
    assert rep.records_in == rep.records_committed + rep.records_dropped \
        + rep.records_in_flight
    assert rep.path_mix and sum(rep.path_mix.values()) > 0
    assert rep.watermark_final["queryable"] is not None
    assert rep.watermark_final["queryable"] <= \
        rep.watermark_final["committed"]
    assert rep.queryable_lag_ms_p99 > 0
    d = rep.to_dict()  # JSON-safe incl. the new fields
    assert d["lineage_enabled"] and "path_mix" in d
    assert "lineage:" in rep.summary()


def test_e2e_lineage_off_keeps_report_inert(tmp_path):
    rep = run_scenario("steady_state", ticks=8, seed=0,
                       spill_dir=str(tmp_path / "sp"), **CAPS)
    assert not rep.lineage_enabled
    assert rep.records_in == 0 and rep.path_mix == {}
    assert rep.conservation_warning == ""


def test_e2e_monitor_sees_freshness_series(tmp_path):
    mon = HealthMonitor()
    rep = run_scenario("steady_state", ticks=30, seed=0, lineage=True,
                       monitor=mon, spill_dir=str(tmp_path / "sp"), **CAPS)
    rows = [r for r in mon.history if r.get("queryable_lag_ms") is not None]
    assert rows, "lineage runs must feed the freshness series"
    assert "freshness" in rep.slo_summary
    assert rep.slo_summary["freshness"]["ticks"] > 0
    # without lineage the series stays None and the SLO is inert
    mon2 = HealthMonitor()
    rep2 = run_scenario("steady_state", ticks=10, seed=0, monitor=mon2,
                        spill_dir=str(tmp_path / "sp2"), **CAPS)
    assert all(r.get("queryable_lag_ms") is None for r in mon2.history)
    assert rep2.slo_summary["freshness"]["ticks"] == 0


def test_e2e_sharded_conservation_holds(tmp_path):
    trk = LineageTracker()
    rep = run_scenario("flash_crowd", ticks=24, seed=1, shards=2,
                       lineage=trk, spill_dir=str(tmp_path / "sp"), **CAPS)
    assert not rep.conservation_warning
    assert rep.records_in > 0
    assert rep.watermark_final["queryable"] is not None


# ---------------------------------------------------------------------------
# acceptance: flash_crowd + store outage
# ---------------------------------------------------------------------------


def test_acceptance_outage_attributed_and_alerts(tmp_path):
    """The ISSUE-10 acceptance run: a mid-run store outage routes
    batches through the archive; the lineage report attributes the
    queryable-lag spike to the archive path, the freshness burn alert
    fires during the outage backlog and clears after the drain, and
    the watermark stalls exactly while batches sit archived."""
    trk = LineageTracker()
    mon = HealthMonitor()
    plan = FaultPlan(fail_times=((20.0, 32.0),))
    rep = run_scenario(
        "flash_crowd", ticks=120, seed=0, speed=2.0, rate_scale=0.5,
        lineage=trk, monitor=mon, fault_plan=plan,
        retry=RetryPolicy(jitter=0.0),
        spill_dir=str(tmp_path / "sp"),
        node_cap=1 << 13, edge_cap=1 << 15)

    # archive path traversed and it is the slow one
    assert rep.path_mix.get("archived", 0) > 0
    fresh = trk.freshness()
    assert fresh["archived"]["queryable"]["p99_ms"] > \
        fresh["direct"]["queryable"]["p99_ms"]

    # freshness burn alert fired during the outage lag spike and cleared
    slo = rep.slo_summary["freshness"]
    onsets = [a for a in slo["alerts"] if a["phase"] == "onset"]
    clears = [a for a in slo["alerts"] if a["phase"] == "clear"]
    assert onsets and clears
    assert 20.0 <= onsets[0]["t"] <= 40.0  # while the outage backlog bit
    assert clears[0]["t"] > onsets[0]["t"]

    # the queryable watermark stalled across the outage window
    stalled = [r for r in trk.timeline if 22.0 <= r["t"] <= 30.0]
    assert stalled
    assert len({r["queryable"] for r in stalled}) == 1
    assert not rep.conservation_warning


# ---------------------------------------------------------------------------
# kill/resume determinism (repro.resilience integration)
# ---------------------------------------------------------------------------


def test_kill_resume_watermarks_and_freshness_identical(tmp_path):
    kw = dict(ticks=40, seed=3, retry=RetryPolicy(jitter=0.0),
              checkpoint_every=8, **CAPS)
    plan = FaultPlan(fail_times=((10.0, 16.0),), crash_at_tick=20)

    ref_trk = LineageTracker()
    ref = run_scenario("flash_crowd", fault_plan=plan.without_crash(),
                       lineage=ref_trk, spill_dir=str(tmp_path / "ref"), **kw)

    with pytest.raises(PipelineKilled):
        run_scenario("flash_crowd", fault_plan=plan,
                     lineage=LineageTracker(),
                     checkpoint_dir=str(tmp_path / "ck"),
                     spill_dir=str(tmp_path / "chaos"), **kw)

    res_trk = LineageTracker()
    res = run_scenario("flash_crowd", fault_plan=plan.without_crash(),
                       lineage=res_trk,
                       checkpoint_dir=str(tmp_path / "ck"), resume=True,
                       spill_dir=str(tmp_path / "chaos"), **kw)
    assert res.store_digest == ref.store_digest
    assert res_trk.watermarks() == ref_trk.watermarks()
    assert res_trk.lag_percentiles_ms() == ref_trk.lag_percentiles_ms()
    assert res_trk.path_counts == ref_trk.path_counts
    assert res_trk.conservation() == ref_trk.conservation()
    assert res.records_in == ref.records_in


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _tracked_run(tmp_path, **kw):
    from repro.telemetry import TelemetryRegistry

    trk = LineageTracker()
    reg = TelemetryRegistry()
    rep = run_scenario("flash_crowd", ticks=24, seed=0, lineage=trk,
                       telemetry=reg, trace=str(tmp_path / "trace.json"),
                       spill_dir=str(tmp_path / "sp"), **CAPS, **kw)
    return trk, reg, rep


def test_sampling_is_deterministic_with_per_path_floor():
    trk = LineageTracker(sample_rate=0.05, min_sampled_per_path=2)
    for i in range(50):
        recs = _recs(float(i))
        trk.observe_intake(recs)
        tag = trk.open_batch(recs, now=float(i))
        if i % 7 == 0:
            trk.mark_archived(tag, float(i))
        trk.mark_committed(tag, float(i))
        trk.mark_queryable(tag, float(i))
    a = [t.batch_id for t in sample_tags(trk)]
    b = [t.batch_id for t in sample_tags(trk)]
    assert a == b  # pure function of batch_id
    by_path = {}
    for t in sample_tags(trk):
        by_path[t.path] = by_path.get(t.path, 0) + 1
    assert by_path.get("archived", 0) >= 2
    assert by_path.get("direct", 0) >= 2


def test_flow_events_land_in_chrome_trace(tmp_path):
    trk, reg, rep = _tracked_run(tmp_path)
    path = str(tmp_path / "trace.json")
    ok, msg = validate_flow_events(path, require_paths=sorted(rep.path_mix))
    assert ok, msg
    with open(path) as f:
        trace = json.load(f)
    flows = [e for e in trace["traceEvents"] if e.get("cat") == "lineage"]
    assert flows
    starts = [e for e in flows if e["ph"] == "s"]
    ends = [e for e in flows if e["ph"] == "f"]
    assert starts and ends
    assert all(e.get("bp") == "e" for e in ends)
    # flow events share the span timeline's clock (µs since t0)
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    t_max = max(e["ts"] + e["dur"] for e in spans)
    assert all(-1e3 <= e["ts"] <= t_max + 1e6 for e in flows)


def test_validate_flow_events_rejects_incomplete_chain():
    trace = {"traceEvents": [
        {"name": "batch:direct", "cat": "lineage", "ph": "s", "id": 1,
         "pid": 0, "tid": 0, "ts": 0.0},
    ]}
    ok, msg = validate_flow_events(trace, require_paths=["direct"])
    assert not ok and "direct" in msg
    ok, _ = validate_flow_events({"traceEvents": []})
    assert not ok


def test_lineage_jsonl_export(tmp_path):
    trk, _, rep = _tracked_run(tmp_path)
    out = str(tmp_path / "lineage.jsonl")
    write_lineage_jsonl(trk, out, meta={"scenario": "flash_crowd"})
    lines = [json.loads(ln) for ln in open(out)]
    meta = lines[0]
    assert meta["type"] == "meta" and meta["scenario"] == "flash_crowd"
    assert meta["watermarks"]["queryable"] is not None
    kinds = {ln["type"] for ln in lines}
    assert {"meta", "batch", "freshness", "watermark"} <= kinds
    batches = [ln for ln in lines if ln["type"] == "batch"]
    assert all(ln["hops"] for ln in batches)
    assert all(ln["path"] in ("direct", "buffered", "spilled", "archived")
               for ln in batches)


def test_harness_lineage_jsonl_kwarg(tmp_path):
    out = str(tmp_path / "lin.jsonl")
    rep = run_scenario("steady_state", ticks=12, seed=0,
                       lineage_jsonl=out,  # implies lineage=True
                       spill_dir=str(tmp_path / "sp"), **CAPS)
    assert rep.lineage_enabled and os.path.exists(out)
    meta = json.loads(open(out).readline())
    assert meta["conservation"]["imbalance"] == 0


def test_prometheus_lines_and_text(tmp_path):
    trk, _, _ = _tracked_run(tmp_path)
    from repro.monitor.export import prometheus_text

    text = prometheus_text(lineage=trk)
    assert 'repro_lineage_watermark{kind="queryable"}' in text
    assert 'repro_lineage_batches_total{path="direct"}' in text
    assert 'repro_lineage_records_total{state="in"}' in text
    assert len(prometheus_lines(trk)) > 8


def test_human_views_render(tmp_path):
    trk, _, _ = _tracked_run(tmp_path)
    ft = freshness_table(trk)
    assert "per-path freshness" in ft and "direct" in ft
    wt = watermark_timeline(trk)
    assert "watermark timeline" in wt
    # empty tracker renders a hint instead of crashing
    assert "was lineage enabled" in freshness_table(LineageTracker())
    assert "no watermark observations" in watermark_timeline(LineageTracker())


# ---------------------------------------------------------------------------
# regression-gate specs
# ---------------------------------------------------------------------------


def test_gate_covers_lineage_metrics():
    from repro.monitor import compare_runs

    bench = {"lineage_freshness": {"derived": {
        "queryable_lag_ms_p99": 10000.0, "ingest_lag_ms_p50": 8000.0}},
        "lineage_overhead": {"derived": {"overhead_pct": 1.0}}}
    worse = {"lineage_freshness": {"derived": {
        "queryable_lag_ms_p99": 30000.0, "ingest_lag_ms_p50": 8000.0}},
        "lineage_overhead": {"derived": {"overhead_pct": 1.2}}}
    v = compare_runs({"benches": bench}, {"benches": worse})
    assert "queryable_lag_ms_p99" in v["regressions"]
    assert v["ok"] is False
    same = compare_runs({"benches": bench}, {"benches": bench})
    assert same["ok"] and same["compared"] == 3
