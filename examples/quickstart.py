"""Quickstart: the paper's pipeline in 30 lines.

Synthesises a bursty tweet stream, runs it through the adaptive-buffer
ingestion pipeline (Algorithm 2 controller + Algorithm 1/3 graph
compression), and prints what the controller did.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.paper_ingest import IngestConfig
from repro.core.pipeline import IngestionPipeline
from repro.ingest.sources import BurstyTweetSource

# a politically-bursty synthetic stream (paper §IV: ~60 rec/s, 5x bursts)
source = BurstyTweetSource(seed=42, mean_rate=60, burst_multiplier=5.0)

# the adaptive pipeline, bounded at 55% consumer load (paper Fig. 12)
pipe = IngestionPipeline(
    IngestConfig(cpu_max=0.55),
    keywords=[],               # stage-1 API filter (keywords)
    uncontrolled=False,        # set True to reproduce the Fig-7 meltdown
    compress=True,             # ingestion-time graph compression
)

report = pipe.run(source.ticks(), max_ticks=120)

mu = report.samples["mu"]
print(f"records ingested      : {report.total_records}")
print(f"insert instructions   : {report.total_instructions} "
      f"(raw {report.raw_instructions})")
print(f"compression ratio     : {report.mean_compression:.3f} "
      f"(paper: mean 0.25, range 0.15-0.35)")
print(f"consumer load mu      : mean {mu.mean():.2f}, max {mu.max():.2f} "
      f"(bound 0.55)")
print(f"buffer actions        : "
      f"{ {a: report.actions.count(a) for a in set(report.actions)} }")
print(f"graph store           : {int(pipe.ingestor.store.n_nodes)} nodes, "
      f"{int(pipe.ingestor.store.n_edges)} edges")
