"""Quickstart: the paper's pipeline in 30 lines, on the composable API.

Synthesises a bursty tweet stream, runs it through the adaptive-buffer
ingestion pipeline (Algorithm 2 controller + Algorithm 1/3 graph
compression), and prints what the controller did.  Then re-runs the
same scenario hash-sharded across 4 per-shard buffer controllers.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import PipelineBuilder
from repro.configs.paper_ingest import IngestConfig

# the adaptive pipeline, bounded at 55% consumer load (paper Fig. 12),
# over a politically-bursty synthetic stream (§IV: ~60 rec/s, 5x bursts)
from repro.ingest.sources import BurstyTweetSource

pipe = (
    PipelineBuilder(IngestConfig(cpu_max=0.55))
    .with_source(BurstyTweetSource(seed=42, mean_rate=60, burst_multiplier=5.0))
    .with_keywords([])         # stage-1 API filter (keywords)
    .uncontrolled(False)       # set True to reproduce the Fig-7 meltdown
    .compressed(True)          # ingestion-time graph compression
    .build()
)
report = pipe.run(max_ticks=120)

mu = report.samples["mu"]
print(f"records ingested      : {report.total_records}")
print(f"insert instructions   : {report.total_instructions} "
      f"(raw {report.raw_instructions})")
print(f"compression ratio     : {report.mean_compression:.3f} "
      f"(paper: mean 0.25, range 0.15-0.35)")
print(f"consumer load mu      : mean {mu.mean():.2f}, max {mu.max():.2f} "
      f"(bound 0.55)")
print(f"buffer actions        : "
      f"{ {a: report.actions.count(a) for a in set(report.actions)} }")
print(f"graph store           : {int(pipe.store.n_nodes)} nodes, "
      f"{int(pipe.store.n_edges)} edges")

# ---- the same scenario, sharded by user across 4 collectors ----
sharded = (
    PipelineBuilder(IngestConfig(cpu_max=0.55))
    .with_source(BurstyTweetSource(seed=42, mean_rate=60, burst_multiplier=5.0))
    .sharded(4)
    .spill_dir("/tmp/repro_spill_qs_shards")
    .build()
)
srep = sharded.run(max_ticks=120)
print(f"\nsharded x4            : records={srep.total_records} "
      f"cr={srep.mean_compression:.3f} "
      f"buffer high-water={srep.max_buffered} (beta_max 50000)")
