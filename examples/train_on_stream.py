"""End-to-end driver: train an LM on the live ingested social stream.

The full path: bursty stream -> two-stage filter -> adaptive buffer ->
tokenised packed batches (double-buffered prefetch) -> pjit train step
with checkpointing.  Default runs a ~20M-param qwen2.5-family model for
200 steps on CPU (a few minutes); --full trains the ~100M variant.

  PYTHONPATH=src python examples/train_on_stream.py
  PYTHONPATH=src python examples/train_on_stream.py --full --steps 300
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import ShapeSpec, get_config
from repro.data.pipeline import stream_batches
from repro.ingest.sources import BurstyTweetSource
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig
from repro.train.trainstep import init_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full", action="store_true", help="~100M params instead of ~20M")
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

# a reduced qwen2.5-family config (same block structure as the full arch)
base = get_config("qwen2.5-3b")
if args.full:  # ~100M params
    cfg = dataclasses.replace(
        base, num_layers=8, d_model=768, num_heads=12, num_kv_heads=2,
        d_ff=2048, vocab_size=32768, microbatch_seqs=4, remat="none",
    )
else:  # ~20M params
    cfg = dataclasses.replace(
        base, num_layers=4, d_model=384, num_heads=6, num_kv_heads=2,
        d_ff=1024, vocab_size=16384, microbatch_seqs=4, remat="none",
    )
total, _ = cfg.param_count()
print(f"model: {total/1e6:.1f}M params ({cfg.num_layers}L d{cfg.d_model})")

shape = ShapeSpec("stream", args.seq, args.batch, "train")
state = init_state(cfg, jax.random.key(0))
step, info = make_train_step(cfg, shape, dp=1, oc=OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps))
jstep = jax.jit(step, donate_argnums=0)
print(f"microbatching: {info}")

src = BurstyTweetSource(seed=0, mean_rate=600.0)  # high-velocity stream
batches = stream_batches(src.ticks(), cfg.vocab_size, args.seq, args.batch)
ckpt = CheckpointManager("/tmp/repro_stream_ckpt")

t0 = time.time()
losses = []
for i, batch in enumerate(batches):
    if i >= args.steps:
        break
    state, m = jstep(state, batch)
    losses.append(float(m["loss"]))
    if (i + 1) % 25 == 0:
        tps = (i + 1) * args.batch * args.seq / (time.time() - t0)
        print(f"step {i+1:4d}  loss {losses[-1]:.3f}  ({tps:,.0f} tok/s)")
    if (i + 1) % 100 == 0:
        ckpt.save(i + 1, state)
ckpt.wait()
print(f"loss: {losses[0]:.3f} -> {min(losses):.3f} over {len(losses)} steps "
      f"({time.time()-t0:.0f}s)")
assert min(losses) < losses[0], "training should reduce loss"
