"""The paper's §IV experiment, end to end (the paper-kind e2e driver):

  (a) "direct stream at natural rate"  — controlled vs uncontrolled
  (b) "file replay at k x natural rate to test the limits"

Reproduces the claims: uncontrolled ingestion pins the consumer (Fig 7);
the adaptive controller bounds it at cpu_max (Fig 12); compression cuts
the instruction load by the Fig-13 band; throttling is rare.  Runs on
the composable API (`repro.api`).

  PYTHONPATH=src python examples/ingest_social_graph.py
"""
import json
import os
import tempfile

from repro.api import PipelineBuilder
from repro.configs.paper_ingest import IngestConfig
from repro.ingest.sources import BurstyTweetSource, FileReplaySource


def report(tag, rep):
    mu = rep.samples["mu"]
    print(f"{tag:28s} mu_mean={mu.mean():.2f} mu_max={mu.max():.2f} "
          f"pinned={float((mu>0.95).mean()):.2f} "
          f"delay_max={rep.samples['delay_s'].max():.1f}s "
          f"cr={rep.mean_compression:.2f} spills={rep.spill_events}")


# ---- (a) natural-rate stream ----
for unc, comp, tag in [
    (True, False, "(a) uncontrolled, raw"),
    (False, True, "(a) controlled + compress"),
]:
    pipe = (
        PipelineBuilder(IngestConfig(cpu_max=0.55))
        .with_source(BurstyTweetSource(seed=7, mean_rate=60, burst_multiplier=5.0))
        .uncontrolled(unc)
        .compressed(comp)
        .simulated_consumer(speed=0.5)
        .spill_dir(f"/tmp/repro_ex_{unc}_{comp}")
        .build()
    )
    report(tag, pipe.run(max_ticks=200))

# ---- (b) file replay at 1x / 3x / 5x the natural rate ----
with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "tweets.jsonl")
    src = BurstyTweetSource(seed=11, mean_rate=200)
    with open(path, "w") as f:
        for tick in src.ticks():
            for r in tick.records:
                f.write(json.dumps(r) + "\n")
            if tick.t > 60:
                break
    for mult in (1.0, 3.0, 5.0):
        pipe = (
            PipelineBuilder(IngestConfig(cpu_max=0.55))
            .with_source(FileReplaySource(path, rate_multiplier=mult,
                                          natural_rate=60))
            .simulated_consumer(speed=0.5)
            .spill_dir(f"/tmp/repro_ex_replay_{mult}")
            .build()
        )
        report(f"(b) replay {mult:.0f}x natural", pipe.run(max_ticks=300))

print("\npaper claims validated: bounded CPU under control, ~25%-band "
      "compression, rare throttling; see EXPERIMENTS.md for the tables.")
