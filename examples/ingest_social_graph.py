"""The paper's §IV experiment, end to end (the paper-kind e2e driver):

  (a) "direct stream" — controlled vs uncontrolled, now driven by a
      *registry scenario* (`repro.workloads`): a flash-crowd stream
      whose rate steps 8x while hashtag diversity collapses, instead
      of the old flat-rate synthetic.
  (b) "file replay at k x natural rate to test the limits"

Reproduces the claims: uncontrolled ingestion pins the consumer (Fig 7);
the adaptive controller bounds it at cpu_max (Fig 12); compression cuts
the instruction load by the Fig-13 band; throttling engages exactly
during the burst.  Runs on the composable API (`repro.api`) + the
workload subsystem (`repro.workloads`).

  PYTHONPATH=src python examples/ingest_social_graph.py
"""
import json
import os
import tempfile

from repro.api import PipelineBuilder
from repro.configs.paper_ingest import IngestConfig
from repro.ingest.sources import FileReplaySource
from repro.workloads import ScenarioSource, get_scenario, run_scenario


def report(tag, rep):
    mu = rep.samples["mu"]
    print(f"{tag:28s} mu_mean={mu.mean():.2f} mu_max={mu.max():.2f} "
          f"pinned={float((mu>0.95).mean()):.2f} "
          f"delay_max={rep.samples['delay_s'].max():.1f}s "
          f"cr={rep.mean_compression:.2f} spills={rep.spill_events}")


# ---- (a) flash-crowd scenario: uncontrolled meltdown vs control ----
for unc, comp, tag in [
    (True, False, "(a) uncontrolled, raw"),
    (False, True, "(a) controlled + compress"),
]:
    pipe = (
        PipelineBuilder(IngestConfig(cpu_max=0.55))
        .with_source(ScenarioSource("flash_crowd", seed=7))
        .uncontrolled(unc)
        .compressed(comp)
        .simulated_consumer(speed=0.5)
        .spill_dir(f"/tmp/repro_ex_{unc}_{comp}")
        .build()
    )
    report(tag, pipe.run(max_ticks=200))

# the same run through the closed-loop harness: the structured report
# with the Algorithm-2 buffer-mode transition timeline
wrep = run_scenario("flash_crowd", ticks=200, seed=7,
                    spill_dir="/tmp/repro_ex_harness")
print(f"(a) harness: {wrep.n_transitions} buffer-mode transitions, "
      f"{wrep.spill_events} spills, "
      f"{wrep.records_per_stream_s:.0f} rec/s sustained")

# ---- (b) file replay at 1x / 3x / 5x the natural rate ----
with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "tweets.jsonl")
    src = ScenarioSource(get_scenario("celebrity_cascade"), seed=11,
                         rate_scale=200.0 / 60.0)
    with open(path, "w") as f:
        for tick in src.ticks():
            for r in tick.records:
                f.write(json.dumps(r) + "\n")
            if tick.t > 60:
                break
    for mult in (1.0, 3.0, 5.0):
        pipe = (
            PipelineBuilder(IngestConfig(cpu_max=0.55))
            .with_source(FileReplaySource(path, rate_multiplier=mult,
                                          natural_rate=60))
            .simulated_consumer(speed=0.5)
            .spill_dir(f"/tmp/repro_ex_replay_{mult}")
            .build()
        )
        report(f"(b) replay {mult:.0f}x natural", pipe.run(max_ticks=300))

print("\npaper claims validated: bounded CPU under control, ~25%-band "
      "compression, throttling only under the flash crowd; see "
      "EXPERIMENTS.md for the tables.")
