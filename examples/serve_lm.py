"""Batched serving example: prefill a prompt batch, decode with KV cache.

Works for any assigned arch (--arch); SSM archs decode with O(1) state.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2.5-3b
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m --gen 32
"""
import argparse

from repro.launch.serve import main as serve_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2.5-3b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

serve_main([
    "--arch", args.arch, "--smoke",
    "--batch", str(args.batch),
    "--prompt-len", "32",
    "--gen", str(args.gen),
])
