"""Closed-loop controller evaluation harness.

`run_scenario` drives a full pipeline (single-shard or sharded)
through a registry scenario and condenses the run into a structured
`WorkloadReport`: sustained throughput, drop/spill/drain counts, the
Algorithm-2 buffer-mode transition timeline, and the table-pressure
throttles the PR-3 fused-upsert path surfaces.  It is the one place
that turns "the pipeline survived" into per-scenario numbers — the
CLI (`python -m repro.launch.workload`), the benchmark suite
(`benchmarks.bench_workloads` -> BENCH_ingest.json) and the e2e tests
all call it.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Union

import numpy as np

from repro.api import PipelineBuilder
from repro.configs.paper_ingest import IngestConfig
from repro.workloads.scenarios import Scenario, get_scenario
from repro.workloads.source import ScenarioSource


@dataclasses.dataclass
class WorkloadReport:
    """Structured result of one scenario run (JSON-safe via to_dict)."""

    scenario: str
    seed: int
    ticks: int
    shards: int
    sketch_guided: bool
    wall_s: float
    stream_s: float
    total_records: int
    records_per_stream_s: float  # sustained throughput in stream time
    records_per_wall_s: float    # what this host actually sustained
    total_instructions: int
    raw_instructions: int
    mean_compression: float
    spill_events: int
    drain_events: int
    dropped_inserts: int         # store-table inserts lost under pressure
    pressure_throttles: int      # one-shot table-pressure throttles fired
    action_counts: Dict[str, int]
    transitions: List[Dict]      # [{t, shard, from, to}] buffer-mode timeline
    mu_mean: float
    mu_p95: float
    mu_max: float
    delay_max_s: float
    store_nodes: int
    store_edges: int
    # dictionary-compression path (repro.compress; zeros when off)
    dict_compress: bool = False
    pattern_refs: int = 0        # total (pattern_id, bindings) references
    dict_hit_rate: float = 0.0   # dictionary hit rate over the whole run
    commit_ms_mean: float = 0.0  # mean successful-commit latency (ms)
    # resilience path (repro.resilience; inert defaults when off)
    commit_failures: int = 0     # failed commit attempts (injected or real)
    retries_replayed: int = 0    # archived batches successfully re-committed
    archived_total: int = 0      # batches ever archived (no-batch-lost LHS)
    archive_remaining: int = 0   # batches still awaiting replay at run end
    pool_overflows: int = 0      # pool-cap diversions to the archive
    degraded_events: int = 0     # ticks served in degraded (store-down) mode
    checkpoints_saved: int = 0
    resumed_from_tick: int = -1  # -1 = fresh run (not resumed)
    store_digest: str = ""       # pytree sha256 of the final GraphStore
    snapshot_digest: str = ""    # pytree sha256 of build_snapshot(store)
    # telemetry (repro.telemetry; empty when the registry is off)
    telemetry_enabled: bool = False
    # per-stage latency breakdown, aggregated across shards:
    # {stage: {count, mean_ms, p50_ms, p95_ms, p99_ms, max_ms, total_s}}
    stage_latency_ms: Dict[str, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)
    audit_decisions: int = 0     # controller audit-trail records
    # health monitoring (repro.monitor; inert defaults when off)
    monitor_enabled: bool = False
    health_events: List[Dict] = dataclasses.field(default_factory=list)
    burst_onset_tick: int = -1   # first "rate" onset (-1 = none detected)
    slo_summary: Dict = dataclasses.field(default_factory=dict)
    slo_breaches: int = 0        # SLO-breaching ticks across all specs
    slo_alerts: int = 0          # multi-window burn-rate alert onsets
    controller_score: float = 1.0  # mean per-decision quality in [0,1]
    decision_quality: Dict = dataclasses.field(default_factory=dict)
    # lineage / freshness (repro.lineage; inert defaults when off)
    lineage_enabled: bool = False
    ingest_lag_ms_p50: float = 0.0   # store staleness (stream-time ms)
    ingest_lag_ms_p99: float = 0.0
    queryable_lag_ms_p99: float = 0.0  # query-surface staleness
    path_mix: Dict[str, int] = dataclasses.field(default_factory=dict)
    # final watermarks: {committed, queryable, max_event_t, pending_*}
    watermark_final: Dict = dataclasses.field(default_factory=dict)
    records_in: int = 0          # records that entered the buffer
    records_committed: int = 0   # ... that landed in the store
    records_dropped: int = 0     # ... terminally lost (lineage-observed)
    records_in_flight: int = 0   # ... still buffered/spilled/archived
    conservation_warning: str = ""  # non-empty iff the invariant broke

    @property
    def n_transitions(self) -> int:
        return len(self.transitions)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["n_transitions"] = self.n_transitions
        return json.loads(json.dumps(d, default=float))  # force JSON-safe

    def summary(self) -> str:
        acts = " ".join(f"{k}={v}" for k, v in sorted(self.action_counts.items()))
        return (
            f"scenario={self.scenario} ticks={self.ticks} shards={self.shards}\n"
            f"records={self.total_records} "
            f"({self.records_per_stream_s:.1f}/s stream, "
            f"{self.records_per_wall_s:.1f}/s wall) "
            f"instructions={self.total_instructions} "
            f"(raw {self.raw_instructions}, cr {self.mean_compression:.3f})\n"
            f"mu: mean={self.mu_mean:.3f} p95={self.mu_p95:.3f} "
            f"max={self.mu_max:.3f} delay_max={self.delay_max_s:.1f}s\n"
            f"control: {acts} | transitions={self.n_transitions} "
            f"spills={self.spill_events} drains={self.drain_events} "
            f"pressure_throttles={self.pressure_throttles} "
            f"dropped_inserts={self.dropped_inserts}\n"
            f"store: {self.store_nodes} nodes, {self.store_edges} edges"
            + (f"\ndict: refs={self.pattern_refs} "
               f"hit_rate={self.dict_hit_rate:.3f} "
               f"commit_ms={self.commit_ms_mean:.2f}"
               if self.dict_compress else "")
            + (self._stage_summary() if self.telemetry_enabled else "")
            + (self._monitor_summary() if self.monitor_enabled else "")
            + (self._lineage_summary() if self.lineage_enabled else "")
        )

    def _lineage_summary(self) -> str:
        mix = " ".join(f"{k}={v}" for k, v in sorted(self.path_mix.items()))
        wq = self.watermark_final.get("queryable")
        warn = f" | WARNING: {self.conservation_warning}" \
            if self.conservation_warning else ""
        return (f"\nlineage: {self.records_in} in -> "
                f"{self.records_committed} committed, "
                f"{self.records_dropped} dropped, "
                f"{self.records_in_flight} in flight | "
                f"lag p50={self.ingest_lag_ms_p50:.0f}ms "
                f"query_p99={self.queryable_lag_ms_p99:.0f}ms | "
                f"paths: {mix or '-'} | Wq="
                + (f"{wq:.1f}" if wq is not None else "-") + warn)

    def _monitor_summary(self) -> str:
        onset = f"burst_onset_tick={self.burst_onset_tick}" \
            if self.burst_onset_tick >= 0 else "no burst onset"
        missed = [n for n, s in self.slo_summary.items()
                  if not s.get("met", True)]
        slos = f"{len(self.slo_summary)} SLOs" \
            + (f" ({len(missed)} missed: {', '.join(sorted(missed))})"
               if missed else " (all met)")
        return (f"\nmonitor: {len(self.health_events)} health events, "
                f"{onset} | {slos}, {self.slo_breaches} breaching ticks, "
                f"{self.slo_alerts} burn alerts | controller_score="
                f"{self.controller_score:.4f}")

    def _stage_summary(self, top: int = 6) -> str:
        if not self.stage_latency_ms:
            return "\ntelemetry: on (no spans recorded)"
        ranked = sorted(self.stage_latency_ms.items(),
                        key=lambda kv: -kv[1].get("total_s", 0.0))[:top]
        rows = "  ".join(
            f"{name}: p50={st['p50_ms']:.2f} p95={st['p95_ms']:.2f}ms"
            for name, st in ranked)
        return (f"\ntelemetry: {len(self.stage_latency_ms)} stages, "
                f"{self.audit_decisions} audited decisions | {rows}")


def _timeline(samples: Dict, actions: List[str], shard: int) -> List[Dict]:
    """Buffer-mode transitions from one pipeline trace."""
    ts = samples.get("t", np.asarray([]))
    out = []
    for i in range(1, len(actions)):
        if actions[i] != actions[i - 1]:
            out.append({"t": float(ts[i]) if i < len(ts) else float(i),
                        "shard": shard,
                        "from": actions[i - 1], "to": actions[i]})
    return out


def run_scenario(
    scenario: Union[Scenario, str],
    *,
    ticks: Optional[int] = None,
    seed: int = 0,
    cfg: Optional[IngestConfig] = None,
    shards: int = 1,
    speed: float = 0.5,
    rate_scale: float = 1.0,
    sketch_guided: bool = False,
    dict_compress: bool = False,
    dict_capacity: int = 4096,
    node_cap: Optional[int] = None,
    edge_cap: Optional[int] = None,
    spill_dir: Optional[str] = None,
    on_event=None,
    telemetry=None,
    monitor=None,
    lineage=None,
    trace: Optional[str] = None,
    trace_jsonl: Optional[str] = None,
    lineage_jsonl: Optional[str] = None,
    fault_plan=None,
    retry=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 16,
    checkpoint_keep: int = 3,
    resume: bool = False,
) -> WorkloadReport:
    """Drive a pipeline through `scenario` and report (module docstring).

    `speed` scales the simulated consumer (0.5 = the paper's half-
    capacity store engine, the setting that makes bursts bite);
    `node_cap`/`edge_cap` shrink the store for CI-sized runs;
    `dict_compress` turns on the GraphZip dictionary-compression path
    (`PipelineBuilder.with_compression`).

    `telemetry` turns on span telemetry + the controller audit trail
    (pass True, or a `repro.telemetry.TelemetryRegistry` to keep for
    inspection); `trace` writes a Perfetto-loadable Chrome trace there
    after the run and `trace_jsonl` the flat JSONL sink — either
    implies telemetry.  With telemetry on the report carries the
    per-stage p50/p95/p99 latency breakdown (`stage_latency_ms`).

    `monitor` turns on online health monitoring (repro.monitor; pass
    True, or a configured `HealthMonitor` to keep for inspection) —
    implies telemetry.  The report then carries the detector
    `health_events` (with `burst_onset_tick`), the per-SLO
    budget/burn summary, and the controller decision-quality score
    (`controller_score`); every audit record gains its `quality`
    verdict in place.

    `lineage` turns on event-time watermarks + per-batch provenance
    (repro.lineage; pass True, or a `LineageTracker` to keep for
    inspection).  The report then carries the freshness SLIs
    (`ingest_lag_ms_p50/p99`, `queryable_lag_ms_p99`), the commit
    path mix, the final watermarks, and the record-conservation
    counters (with `conservation_warning` set iff the invariant
    ``records_in == committed + dropped + in_flight`` broke).  With
    `trace` also set, the Chrome trace gains per-batch flow events;
    `lineage_jsonl` writes the sampled hop logs (implies lineage).

    Resilience (repro.resilience): `fault_plan` injects commit faults
    (and, via `crash_at_tick`, raises `PipelineKilled` mid-run);
    setting it arms a default `RetryPolicy` unless `retry` overrides
    (pass a policy to customise, `False` to disable).  `checkpoint_dir`
    turns on periodic step-atomic checkpoints every `checkpoint_every`
    ticks; `resume=True` restores the latest one (same scenario/seed/
    shards enforced) and runs only the remaining ticks — bit-exact vs
    an uninterrupted run.  With any of these active the report carries
    the retry/archive accounting and the store/snapshot digests.
    """
    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    ticks = int(ticks if ticks is not None else scn.ticks)
    if cfg is None:
        cfg = IngestConfig(
            mean_rate=scn.base_rate,
            store_nodes=node_cap or IngestConfig.store_nodes,
            store_edges=edge_cap or IngestConfig.store_edges,
        )
    elif node_cap or edge_cap:
        # explicit caps always win, also over a caller-supplied cfg
        cfg = dataclasses.replace(
            cfg,
            store_nodes=node_cap or cfg.store_nodes,
            store_edges=edge_cap or cfg.store_edges,
        )
    src = ScenarioSource(scn, seed=seed, rate_scale=rate_scale)
    dropped = [0]
    refs = [0]
    hits = [0.0, 0]  # hit-rate sum, commit count

    def _count_drops(ev):
        if ev.kind == "commit":
            dropped[0] += int(ev.payload.get("dropped", 0))
            refs[0] += int(ev.payload.get("refs", 0))
            hits[0] += float(ev.payload.get("dict_hit_rate", 0.0))
            hits[1] += 1

    reg = None
    if telemetry or trace or trace_jsonl or monitor:
        from repro.telemetry import TelemetryRegistry

        reg = telemetry if isinstance(telemetry, TelemetryRegistry) \
            else TelemetryRegistry()
    mon = None
    if monitor:
        from repro.monitor import HealthMonitor, default_slos

        mon = monitor if isinstance(monitor, HealthMonitor) \
            else HealthMonitor(slos=default_slos(
                cpu_max=cfg.cpu_max, theta2=cfg.theta2,
                checkpoint_every=checkpoint_every
                if checkpoint_dir is not None else 0))
    trk = None
    if lineage or lineage_jsonl:
        from repro.lineage import LineageTracker

        trk = lineage if isinstance(lineage, LineageTracker) \
            else LineageTracker(dt=float(src.dt))

    sdir = spill_dir or f"/tmp/repro_workload_{scn.name}_{seed}"
    b = (PipelineBuilder(cfg)
         .with_source(src)
         .simulated_consumer(speed=speed)
         .spill_dir(sdir)
         .on_event(_count_drops))
    if reg is not None:
        b = b.with_telemetry(reg)
    if mon is not None:
        b = b.with_monitor(mon)
    if trk is not None:
        b = b.with_lineage(trk)
    if sketch_guided:
        b = b.sketch_guided()
    if dict_compress:
        b = b.with_compression(capacity=dict_capacity)
    if fault_plan is not None:
        b = b.with_faults(fault_plan)
    if retry is not False and (retry is not None or fault_plan is not None):
        # a fault plan arms the default policy unless retry=False
        b = b.with_retry(retry if retry not in (None, True) else None,
                         archive_dir=f"{sdir}_archive")
    if shards > 1:
        b = b.sharded(shards)
    if on_event is not None:
        b = b.on_event(on_event)
    pipe = b.build()

    resilient = (fault_plan is not None or checkpoint_dir is not None
                 or (retry is not None and retry is not False))
    ckpt = None
    ckpt_extra = {"scenario": scn.name, "seed": seed, "shards": shards}
    if checkpoint_dir is not None:
        from repro.resilience import PipelineCheckpointer

        ckpt = PipelineCheckpointer(checkpoint_dir, keep=checkpoint_keep,
                                    every=checkpoint_every, telemetry=reg)
    start_tick = 0
    if resume:
        if ckpt is None:
            raise ValueError("resume=True needs checkpoint_dir")
        manifest = ckpt.restore(pipe, src, expect=ckpt_extra)
        start_tick = int(manifest["step"])

    if ckpt is not None or fault_plan is not None:
        from repro.resilience import drive

        stream = drive(src.ticks(), pipe, src, checkpointer=ckpt,
                       fault_plan=fault_plan, start_tick=start_tick,
                       extra=ckpt_extra)
        try:
            rep = pipe.run(stream, max_ticks=max(ticks - start_tick, 0))
        finally:
            if ckpt is not None:
                ckpt.wait()
    else:
        rep = pipe.run(max_ticks=ticks)

    if shards > 1:
        sub = rep.shards
        mu = np.concatenate([r.samples["mu"] for r in sub]) \
            if sub else np.asarray([0.0])
        delay = np.concatenate([r.samples["delay_s"] for r in sub]) \
            if sub else np.asarray([0.0])
        transitions = [tr for si, r in enumerate(sub)
                       for tr in _timeline(r.samples, r.actions, si)]
        transitions.sort(key=lambda tr: tr["t"])
        controllers = [s.controller for s in pipe.shards]
        actions: List[str] = [a for r in sub for a in r.actions]
    else:
        mu = rep.samples["mu"] if len(rep.samples["mu"]) else np.asarray([0.0])
        delay = rep.samples["delay_s"] if len(rep.samples["delay_s"]) \
            else np.asarray([0.0])
        transitions = _timeline(rep.samples, rep.actions, 0)
        controllers = [pipe.buffer_stage.controller]
        actions = list(rep.actions)

    counts: Dict[str, int] = {}
    for a in actions:
        counts[a] = counts.get(a, 0) + 1
    store = pipe.store
    ingestor = getattr(pipe.sink, "ingestor", None)
    commit_ms = [1e3 * c.busy_s for c in ingestor.commits if c.ok] \
        if ingestor is not None else []
    store_digest = snapshot_digest = ""
    if resilient:
        from repro.query.snapshot import build_snapshot
        from repro.resilience import pytree_digest

        store_digest = pytree_digest(store)
        snapshot_digest = pytree_digest(build_snapshot(store))
    mon_report: Dict = {}
    if mon is not None:
        # finish BEFORE the exporters run so every audit record
        # already carries its quality verdict in the trace files
        mon.finish()
        mon_report = mon.report()
    lineage_lags: Dict[str, float] = {}
    cons: Dict = {}
    cons_warning = ""
    if trk is not None:
        # conservation: whatever is still sitting in the stage buffers
        # and spill files is accounted in-flight, not lost
        stages = pipe.shards if shards > 1 else [pipe.buffer_stage]
        buffered = sum(len(st.buffer) + st.spilled_records for st in stages)
        cons = trk.conservation(buffered_records=buffered)
        if cons["imbalance"]:
            cons_warning = (f"record conservation broke: in="
                            f"{cons['records_in']} != committed="
                            f"{cons['records_committed']} + dropped="
                            f"{cons['records_dropped']} + in_flight="
                            f"{cons['records_in_flight']} "
                            f"(imbalance {cons['imbalance']:+d})")
        lineage_lags = trk.lag_percentiles_ms()
        if lineage_jsonl:
            from repro.lineage import write_lineage_jsonl

            write_lineage_jsonl(trk, lineage_jsonl, meta={
                "scenario": scn.name, "seed": seed, "shards": shards,
                "conservation_warning": cons_warning})
    stage_latency: Dict[str, Dict[str, float]] = {}
    n_audit = 0
    if reg is not None:
        from repro.telemetry import write_chrome_trace, write_jsonl

        stage_latency = reg.summary()
        n_audit = len(reg.audit)
        if trace:
            extra = None
            if trk is not None:
                from repro.lineage import flow_events

                extra = flow_events(trk, reg.t0_ns)
            write_chrome_trace(reg, trace, meta={
                "scenario": scn.name, "seed": seed, "shards": shards},
                extra_events=extra)
        if trace_jsonl:
            write_jsonl(reg, trace_jsonl)
    return WorkloadReport(
        scenario=scn.name,
        seed=seed,
        ticks=ticks,
        shards=shards,
        sketch_guided=sketch_guided,
        wall_s=float(rep.wall_s),
        stream_s=float(ticks * src.dt),
        total_records=int(rep.total_records),
        records_per_stream_s=rep.total_records / max(ticks * src.dt, 1e-9),
        records_per_wall_s=rep.total_records / max(rep.wall_s, 1e-9),
        total_instructions=int(rep.total_instructions),
        raw_instructions=int(rep.raw_instructions),
        mean_compression=float(rep.mean_compression),
        spill_events=int(rep.spill_events),
        drain_events=int(rep.drain_events),
        dropped_inserts=dropped[0],
        pressure_throttles=sum(c.pressure_throttles for c in controllers),
        action_counts=counts,
        transitions=transitions,
        mu_mean=float(mu.mean()),
        mu_p95=float(np.percentile(mu, 95)),
        mu_max=float(mu.max()),
        delay_max_s=float(delay.max()),
        store_nodes=int(store.n_nodes),
        store_edges=int(store.n_edges),
        dict_compress=dict_compress,
        pattern_refs=refs[0],
        dict_hit_rate=hits[0] / max(hits[1], 1),
        commit_ms_mean=float(np.mean(commit_ms)) if commit_ms else 0.0,
        commit_failures=sum(1 for c in ingestor.commits if not c.ok)
        if ingestor is not None else 0,
        retries_replayed=getattr(ingestor, "replayed", 0) or 0,
        archived_total=getattr(ingestor, "archived_total", 0) or 0,
        archive_remaining=getattr(ingestor, "archive_depth", 0) or 0,
        pool_overflows=getattr(ingestor, "pool_overflows", 0) or 0,
        degraded_events=int(pipe.metrics.counters["degraded"]),
        checkpoints_saved=ckpt.saves if ckpt is not None else 0,
        resumed_from_tick=start_tick if resume else -1,
        store_digest=store_digest,
        snapshot_digest=snapshot_digest,
        telemetry_enabled=reg is not None,
        stage_latency_ms=stage_latency,
        audit_decisions=n_audit,
        monitor_enabled=mon is not None,
        health_events=mon_report.get("health_events", []),
        burst_onset_tick=mon_report.get("burst_onset_tick", -1),
        slo_summary=mon_report.get("slo", {}),
        slo_breaches=mon_report.get("slo_breaches", 0),
        slo_alerts=mon_report.get("slo_alerts", 0),
        controller_score=mon_report.get("controller_score", 1.0),
        decision_quality=mon_report.get("quality", {}),
        lineage_enabled=trk is not None,
        ingest_lag_ms_p50=lineage_lags.get("ingest_lag_ms_p50", 0.0),
        ingest_lag_ms_p99=lineage_lags.get("ingest_lag_ms_p99", 0.0),
        queryable_lag_ms_p99=lineage_lags.get("queryable_lag_ms_p99", 0.0),
        path_mix=dict(trk.path_counts) if trk is not None else {},
        watermark_final=trk.watermarks() if trk is not None else {},
        records_in=cons.get("records_in", 0),
        records_committed=cons.get("records_committed", 0),
        records_dropped=cons.get("records_dropped", 0),
        records_in_flight=cons.get("records_in_flight", 0),
        conservation_warning=cons_warning,
    )
