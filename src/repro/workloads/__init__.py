"""Bursty social-media scenario generator + closed-loop evaluation.

The workload subsystem turns the repo from "ingests one stream" into
"evaluated across a family of adversarial streams":

  * `repro.workloads.samplers` — jit-compiled, counter-based traffic
    processes (Hawkes self-excitation, diurnal cycles, flash-crowd
    steps, multiplicative jitter),
  * `repro.kernels.sampler`    — the fused per-record id kernel (Zipf
    heavy-hitter users, hot-topic hashtag mixing, retweet-cascade
    mentions) with a bit-exact jnp oracle,
  * `Scenario` / `register` / `get_scenario` / `list_scenarios` — the
    named registry (steady_state, flash_crowd, celebrity_cascade,
    diurnal, spam_storm, election_night, plus yours),
  * `ScenarioSource`           — a `Source`-protocol adapter usable
    anywhere a `BurstyTweetSource` is (PipelineBuilder, sharded),
  * `run_scenario` / `WorkloadReport` — the closed-loop harness that
    scores the Algorithm-2 controller per scenario (throughput,
    spills, buffer-mode transitions, table-pressure throttles).

CLI: `python -m repro.launch.workload --scenario flash_crowd`.
"""
from repro.workloads.scenarios import (
    Scenario,
    get_scenario,
    list_scenarios,
    register,
)
from repro.workloads.source import ScenarioSource
from repro.workloads.harness import WorkloadReport, run_scenario
from repro.workloads.samplers import RateChunk, rate_trajectory

__all__ = [
    "Scenario", "register", "get_scenario", "list_scenarios",
    "ScenarioSource",
    "WorkloadReport", "run_scenario",
    "RateChunk", "rate_trajectory",
]
