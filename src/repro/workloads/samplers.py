"""Jittable traffic-rate trajectory samplers (counter-based PRNG).

One jit-compiled scan produces a chunk of per-tick (intensity, count)
pairs combining every burst mechanism real social streams exhibit:

  * diurnal cycle     — sinusoidal envelope (compressed "day"),
  * flash crowd       — a step at `flash_t` of height `flash_mult`
    relaxing exponentially with time constant `flash_decay` (the
    breaking-news shape of the paper's >250% velocity spikes),
  * Hawkes self-excitation — every event raises future intensity by
    alpha * beta * exp(-beta * dt) (branching ratio ~alpha): retweet
    storms where volume feeds on itself, the mechanism behind the
    heavy burst tails GraphTango-style evaluations stress,
  * multiplicative noise — the paper's 15-45% tick-to-tick jitter.

Counts are drawn per tick with the same counter-based PRNG as the id
kernel (`repro.kernels.sampler.counter_mix`), via a Gaussian
approximation to Poisson(lam) — exact enough above lam ~ 10 and fully
vectorisable; the whole trajectory is a pure function of (seed, t0,
excite0), so chunks compose deterministically: generating 4 chunks of
64 ticks is bit-identical to one chunk of 256.

All rates are non-negative by construction (tests assert the
invariant under hypothesis-driven parameter sweeps).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.sampler import counter_mix, uniform01

# rate draws salt the seed so tick counters never collide with the
# per-record lanes of the id kernel (which use the unsalted seed)
RATE_SALT = 0xA511CE5
_TWO_PI = 6.2831853


class RateChunk(NamedTuple):
    rates: jax.Array   # (ticks,) float32 realised intensity lambda_k
    env: jax.Array     # (ticks,) float32 deterministic envelope (no Hawkes/noise)
    counts: jax.Array  # (ticks,) int32 records per tick
    excite: jax.Array  # scalar float32 Hawkes state to carry into the next chunk


def _normal(seed, ctr):
    """One standard normal per lane (Box-Muller on counter draws)."""
    u1 = uniform01(counter_mix(seed, ctr))
    u2 = uniform01(counter_mix(seed, ctr + jnp.uint32(1)))
    r = jnp.sqrt(-2.0 * jnp.log(jnp.maximum(1.0 - u1, 1e-7)))
    return r * jnp.cos(_TWO_PI * u2)


@functools.partial(jax.jit, static_argnames=("ticks",))
def rate_trajectory(seed, ticks: int, t0, excite0, base_rate, noise_frac,
                    hawkes_alpha, hawkes_beta, diurnal_amp, diurnal_period,
                    flash_t, flash_mult, flash_decay, rate_cap, dt=1.0):
    """One chunk of the tick-rate process (see module docstring).

    t0 is the absolute tick index of the chunk start; excite0 the
    Hawkes state carried from the previous chunk (0.0 at stream
    start).  Returns a `RateChunk`.
    """
    seed = jnp.asarray(seed, jnp.uint32) ^ jnp.uint32(RATE_SALT)
    idx = jnp.arange(ticks, dtype=jnp.int32)
    tick_abs = jnp.asarray(t0, jnp.int32) + idx
    t = tick_abs.astype(jnp.float32) * dt

    env = base_rate * (1.0 + diurnal_amp * jnp.sin(_TWO_PI * t / diurnal_period))
    flash = jnp.where(
        t >= flash_t,
        1.0 + (flash_mult - 1.0) * jnp.exp(-(t - flash_t) / flash_decay),
        1.0)
    env = env * flash

    g = jnp.exp(-hawkes_beta * dt)  # per-tick decay of the excitation state

    def step(excite, inp):
        env_k, k = inp
        lam = env_k + hawkes_alpha * hawkes_beta * excite
        ctr = k.astype(jnp.uint32) * jnp.uint32(4)
        lam = lam * (1.0 + noise_frac * (2.0 * uniform01(counter_mix(seed, ctr)) - 1.0))
        lam = jnp.clip(lam, 0.0, rate_cap)
        z = _normal(seed, ctr + jnp.uint32(1))
        c = jnp.maximum(jnp.round(lam * dt + jnp.sqrt(lam * dt) * z), 0.0)
        c = jnp.minimum(c, rate_cap * dt).astype(jnp.int32)
        excite = g * (excite + c.astype(jnp.float32))
        return excite, (lam, c)

    excite, (rates, counts) = jax.lax.scan(
        step, jnp.asarray(excite0, jnp.float32), (env, tick_abs))
    return RateChunk(rates=rates, env=env, counts=counts, excite=excite)
