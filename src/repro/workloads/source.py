"""`ScenarioSource` — a registry scenario as a pipeline `Source`.

Drives the composable ingestion API (`PipelineBuilder`, sharded or
not) with the bursty traffic a named `Scenario` describes.  Device
work happens in two jit-compiled strides so record synthesis never
bottlenecks ingest:

  * tick rates/counts come from `rate_trajectory` one CHUNK of ticks
    at a time (Hawkes state carried across chunks, bit-identical to
    one long chunk),
  * record ids come from the fused counter-based sampling kernel
    (`repro.kernels.ops.traffic_sample`) one fixed-size block per
    tick, so shapes are static and the trace compiles once.

Everything downstream of (scenario, seed) is deterministic: two
sources with equal arguments yield byte-identical record streams, and
the per-tick hot-topic share follows the realised intensity (burst
level b = 1 - base/lambda), so content diversity collapses exactly
when volume spikes — the correlation Algorithm 2's compression
predictor feeds on.

Records are tweet-shaped dicts (`id`/`user`/`hashtags`/`mentions`/
`text`/`ts`) compatible with `tweet_mapping` and the two-stage filter.
"""
from __future__ import annotations

import collections
from typing import Iterator, List, Optional, Union

import numpy as np

from repro.ingest.sources import StreamTick
from repro.kernels.sampler import NSTREAMS
from repro.workloads.samplers import rate_trajectory
from repro.workloads.scenarios import Scenario, get_scenario

CHUNK = 64  # ticks of rate trajectory per device call


class ScenarioSource:
    """Source-protocol adapter over a named (or inline) `Scenario`."""

    def __init__(self, scenario: Union[Scenario, str], seed: int = 0,
                 dt: float = 1.0, block: int = 2048,
                 rate_scale: float = 1.0, use_kernel: Optional[bool] = None,
                 recent_window: int = 500):
        self.scenario = (get_scenario(scenario)
                         if isinstance(scenario, str) else scenario)
        self.seed = int(seed)
        self.dt = float(dt)
        self.block = int(block)
        self.rate_scale = float(rate_scale)
        self.use_kernel = use_kernel
        self.t = 0.0
        self._tick_no = 0
        self._rec_no = 0     # record counter: ids AND PRNG lane base
        self._excite = 0.0   # Hawkes carry across trajectory chunks
        self._recent: collections.deque = collections.deque(maxlen=recent_window)
        # (rate, count) pairs of the current trajectory chunk not yet
        # yielded.  Kept on the instance (not generator-internal) so a
        # checkpoint (repro.resilience) can capture the cursor mid-chunk
        # — `_tick_no`/`_excite` advance a whole CHUNK at a time, so
        # without this a resumed source would skip the chunk remainder.
        self._pending: List[tuple] = []

    # ------------------------------------------------------------------
    def _sample_ids(self, n: int, burst_level: float):
        """n record-id tuples from the fused kernel (blocked, padded)."""
        from repro.kernels import ops

        scn = self.scenario
        ip, fp = scn.iparams(), scn.fparams(burst_level)
        out = []
        taken = 0
        while taken < n:
            # uint32 counter space wraps for streams past ~500M records
            ctr0 = np.uint32(((self._rec_no + taken) * NSTREAMS) & 0xFFFFFFFF)
            cols = ops.traffic_sample(np.uint32(self.seed), ctr0, self.block,
                                      ip, fp, use_kernel=self.use_kernel)
            k = min(self.block, n - taken)
            out.append([np.asarray(c)[:k] for c in cols])
            taken += k
        return [np.concatenate(parts) for parts in zip(*out)]

    def _materialise(self, n: int, burst_level: float) -> List[dict]:
        scn = self.scenario
        uid, tag, mention, u_dup, u_dupi = self._sample_ids(n, burst_level)
        recs: List[dict] = []
        for i in range(n):
            self._rec_no += 1
            if self._recent and float(u_dup[i]) < scn.duplicate_frac:
                j = int(float(u_dupi[i]) * len(self._recent))
                recs.append(dict(self._recent[min(j, len(self._recent) - 1)]))
                continue
            rec = {
                "id": f"t{self._rec_no}",
                "user": f"u{int(uid[i])}",
                "hashtags": [f"h{int(tag[i])}"],
                "mentions": [f"u{int(mention[i])}"],
                "text": f"{scn.name} record {self._rec_no}",
                "ts": self.t,
            }
            recs.append(rec)
            self._recent.append(rec)
        return recs

    # ------------------------------------------------------------------
    def ticks(self) -> Iterator[StreamTick]:
        scn = self.scenario
        base = scn.base_rate * self.rate_scale
        while True:
            if not self._pending:
                chunk = rate_trajectory(
                    np.uint32(self.seed), CHUNK, self._tick_no, self._excite,
                    base, scn.noise_frac, scn.hawkes_alpha, scn.hawkes_beta,
                    scn.diurnal_amp, scn.diurnal_period, scn.flash_t,
                    scn.flash_mult, scn.flash_decay, scn.rate_cap_mult * base,
                    dt=self.dt)
                rates = np.asarray(chunk.rates)
                counts = np.asarray(chunk.counts)
                self._excite = float(chunk.excite)
                self._tick_no += CHUNK
                self._pending = [(float(lam), int(c))
                                 for lam, c in zip(rates, counts)]
            lam, c = self._pending.pop(0)
            # burst level in [0,1): 0 at baseline, ->1 as lam >> base;
            # drives the hot-topic share (diversity drops in bursts)
            b = max(0.0, 1.0 - base / max(lam, base))
            self.t += self.dt
            yield StreamTick(self.t, self._materialise(c, b))

    # ---- checkpoint surface (repro.resilience) -----------------------
    def state(self) -> dict:
        """Exact stream cursor: counters, Hawkes carry, the un-yielded
        chunk remainder, and the duplicate-sampling window."""
        return {
            "t": self.t,
            "tick_no": self._tick_no,
            "rec_no": self._rec_no,
            "excite": self._excite,
            "pending": list(self._pending),
            "recent": [dict(r) for r in self._recent],
        }

    def restore_state(self, s: dict) -> None:
        self.t = float(s["t"])
        self._tick_no = int(s["tick_no"])
        self._rec_no = int(s["rec_no"])
        self._excite = float(s["excite"])
        self._pending = [tuple(p) for p in s["pending"]]
        self._recent = collections.deque(s["recent"],
                                         maxlen=self._recent.maxlen)
