"""Named workload scenarios — the adversarial-stream family.

A `Scenario` is a frozen parameter bundle for the trajectory sampler
(`repro.workloads.samplers.rate_trajectory`) and the id kernel
(`repro.kernels.sampler`), registered by name so pipelines, the
harness CLI and the benchmark suite all speak the same vocabulary:

    src = ScenarioSource("flash_crowd", seed=0)
    report = run_scenario("celebrity_cascade", ticks=200)

The built-ins cover the burst mechanisms the paper's Algorithm-2
controller must survive (and the ones its evaluation never stressed):

  steady_state      calm baseline: Poisson-ish jitter only — the
                    control loop should stay in push mode throughout.
  flash_crowd       breaking news: an 8x rate step decaying over ~80s
                    while hashtag diversity collapses onto the hot
                    topic (the paper's #ReleaseTheMemo shape).
  celebrity_cascade strongly self-exciting retweet storms (Hawkes
                    branching ~0.85) with copy-model cascades: volume
                    feeds on itself in heavy bursts.
  diurnal           compressed day/night cycle (+-85% around the
                    mean) with mild self-excitation — slow, large
                    swings that test buffer shrink/drain recovery.
  spam_storm        bot flood: 6x step, half the records duplicates,
                    a tiny hot-tag set and a handful of bot accounts
                    dominating (steep Zipf) — maximum table pressure
                    per unique key.
  election_night    everything at once: diurnal swell + flash spikes
                    + strong self-excitation; the torture test.

`register()` adds custom scenarios; the registry is ordered (dict
insertion order) so benchmark rows are stable across runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    # --- tick-rate process (samplers.rate_trajectory) ---
    base_rate: float = 60.0      # records/s baseline (paper: ~60 at 1% sample)
    noise_frac: float = 0.25     # multiplicative tick jitter (paper: 15-45%)
    hawkes_alpha: float = 0.0    # self-excitation branching ratio, < 1
    hawkes_beta: float = 0.5     # excitation decay (1/s)
    diurnal_amp: float = 0.0     # sinusoidal envelope amplitude, < 1
    diurnal_period: float = 240.0  # compressed "day" length (s)
    flash_t: float = 1e9         # flash-crowd step time (s); 1e9 = never
    flash_mult: float = 1.0      # step height (x base)
    flash_decay: float = 40.0    # step relaxation time constant (s)
    rate_cap_mult: float = 50.0  # safety clip: lambda <= cap * base_rate
    # --- id sampling (kernels.sampler.traffic_body) ---
    n_users: int = 20_000
    n_tags: int = 4_000
    zipf_user: float = 1.3       # user-activity skew (a != 1)
    zipf_tag: float = 1.2        # long-tail hashtag skew
    zipf_mention: float = 2.0    # celebrity-mention skew
    copy_frac: float = 0.3       # retweet-cascade copy-model probability
    topic_frac: float = 0.1      # calm-time share of hot-topic hashtags
    topic_frac_burst: float = 0.8  # hot-topic share at full burst
    burst_ntags: int = 12        # size of the hot-topic set
    topic_base: int = 17         # first hot-topic hashtag id
    duplicate_frac: float = 0.125  # paper: 5-20% duplicate tweets
    # --- harness defaults ---
    ticks: int = 240             # suggested run length (ticks of dt=1s)

    def iparams(self) -> np.ndarray:
        """int32 params for `repro.kernels.ops.traffic_sample`."""
        return np.asarray([self.n_users, self.n_tags, self.burst_ntags,
                           self.topic_base], np.int32)

    def fparams(self, burst_level: float = 0.0) -> np.ndarray:
        """float32 params for `traffic_sample` at a given burst level
        in [0, 1]: hot-topic share interpolates topic_frac ->
        topic_frac_burst (diversity drops exactly when volume spikes)."""
        b = float(np.clip(burst_level, 0.0, 1.0))
        frac = self.topic_frac + (self.topic_frac_burst - self.topic_frac) * b
        return np.asarray([self.zipf_user, self.zipf_tag, self.zipf_mention,
                           frac, self.copy_frac], np.float32)


_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario, overwrite: bool = False) -> Scenario:
    if scenario.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {', '.join(_REGISTRY)}"
        ) from None


def list_scenarios() -> List[Scenario]:
    return list(_REGISTRY.values())


register(Scenario(
    name="steady_state",
    description="calm baseline: jittered Poisson at the paper's ~60 rec/s; "
                "the controller should never leave push mode",
))
register(Scenario(
    name="flash_crowd",
    description="breaking news: 8x rate step at t=30s decaying over ~80s, "
                "hashtag diversity collapsing onto the hot topic",
    flash_t=30.0, flash_mult=8.0, flash_decay=80.0,
    hawkes_alpha=0.25, topic_frac_burst=0.85, burst_ntags=8,
))
register(Scenario(
    name="celebrity_cascade",
    description="self-exciting retweet storms (Hawkes branching ~0.85) with "
                "copy-model cascades and steep celebrity-mention skew",
    hawkes_alpha=0.85, hawkes_beta=0.4, copy_frac=0.75,
    zipf_user=1.6, zipf_mention=2.5, noise_frac=0.2,
))
register(Scenario(
    name="diurnal",
    description="compressed day/night cycle: +-85% sinusoidal swing over a "
                "240s 'day' with mild self-excitation",
    diurnal_amp=0.85, diurnal_period=240.0, hawkes_alpha=0.2,
))
register(Scenario(
    name="spam_storm",
    description="bot flood: 6x step, ~50% duplicates, 3 hot tags and a few "
                "bot accounts dominating (steep Zipf) — max table pressure",
    flash_t=20.0, flash_mult=6.0, flash_decay=120.0,
    duplicate_frac=0.5, zipf_user=2.5, zipf_tag=2.0,
    topic_frac=0.4, topic_frac_burst=0.95, burst_ntags=3, n_tags=500,
))
register(Scenario(
    name="election_night",
    description="torture test: diurnal swell + flash spike + strong "
                "self-excitation, all at once",
    diurnal_amp=0.6, diurnal_period=300.0,
    flash_t=45.0, flash_mult=5.0, flash_decay=60.0,
    hawkes_alpha=0.6, topic_frac_burst=0.9, copy_frac=0.5,
))
