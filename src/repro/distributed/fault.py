"""Fault tolerance: failure detection, elastic re-mesh, stragglers.

Posture for 1000+ nodes (what runs here is the same control logic
driven by injected faults, since the container has one host):

 * Failure detection: every train step runs under a deadline; a raised
   device error or missed heartbeat marks the step failed.
 * Recovery: restore the latest committed checkpoint (checkpoints are
   mesh-agnostic) onto a SHRUNKEN mesh — the `data` axis drops the lost
   host's shard (elastic re-mesh) — and resume.  Growing back happens
   the same way at the next checkpoint boundary.
 * Straggler mitigation: per-step wall-time EWMA; a step slower than
   `straggler_factor` x EWMA flags the host.  Real deployments swap the
   flagged host out at the next boundary; here the event is recorded
   and surfaced.  The ingestion buffer (paper's Algorithm 2!) absorbs
   the producer-side stall while the fleet reconfigures — the paper's
   mechanism doing double duty at pod scale (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager


class InjectedFault(RuntimeError):
    pass


@dataclasses.dataclass
class FaultEvent:
    step: int
    kind: str  # "failure" | "straggler" | "recovered"
    detail: str
    wall_s: float


class FaultTolerantRunner:
    def __init__(
        self,
        ckpt: CheckpointManager,
        make_step: Callable,          # (dp_size) -> jitted step fn
        state_template: Callable,     # () -> state pytree (for restore)
        dp_size: int,
        ckpt_every: int = 20,
        straggler_factor: float = 3.0,
        fail_schedule: Optional[dict] = None,  # step -> "crash"|"slow"
    ):
        self.ckpt = ckpt
        self.make_step = make_step
        self.state_template = state_template
        self.dp = dp_size
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.fail_schedule = fail_schedule or {}
        self.events: List[FaultEvent] = []
        self._ewma = None

    def run(self, state, batches, start_step: int = 0, max_steps: int = 100):
        step_fn = self.make_step(self.dp)
        step = start_step
        metrics_hist = []
        it = iter(batches)
        while step < max_steps:
            batch = next(it)
            t0 = time.perf_counter()
            try:
                mode = self.fail_schedule.get(step)
                if mode == "crash":
                    # one-shot: the node dies during this step
                    self.fail_schedule.pop(step)
                    raise InjectedFault(f"node failure at step {step}")
                if mode == "slow":
                    time.sleep((self._ewma or 0.05) * (self.straggler_factor + 1))
                state, m = step_fn(state, batch)
                jax.block_until_ready(m["loss"])
            except (InjectedFault, RuntimeError) as e:
                self.events.append(
                    FaultEvent(step, "failure", str(e), time.perf_counter() - t0)
                )
                # ---- elastic recovery: shrink the data axis, restore ----
                self.dp = max(1, self.dp - 1)
                step_fn = self.make_step(self.dp)
                restore_step = self.ckpt.latest_step()
                if restore_step is not None:
                    state = self.ckpt.restore(self.state_template())
                    step = restore_step
                self.events.append(
                    FaultEvent(step, "recovered", f"resumed on dp={self.dp}", 0.0)
                )
                continue

            dt = time.perf_counter() - t0
            if self._ewma is None:
                self._ewma = dt
            if dt > self.straggler_factor * self._ewma:
                self.events.append(
                    FaultEvent(step, "straggler", f"{dt:.3f}s vs ewma {self._ewma:.3f}s", dt)
                )
            self._ewma = 0.9 * self._ewma + 0.1 * dt

            metrics_hist.append({k: float(v) for k, v in m.items()})
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, metrics_hist
