"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Params and activations are annotated with *logical* axis names; a rule
table maps them to mesh axes.  Resolution is divisibility-aware: a rule
only applies when the dimension size divides the product of the mesh
axes, otherwise the dim falls back to replicated.  This lets one rule
table serve every assigned architecture (kv heads of 2 or 32, vocabs of
32000 or 151936, ...).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

# logical name -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: Dict[str, Any] = {
    # --- data / batch ---
    "batch": ("pod", "data"),
    # --- parameter FSDP shard dim (ZeRO-3 over pod x data: cross-pod
    #     gathers are hierarchical on real ICI/DCI) ---
    "fsdp": ("pod", "data"),
    # --- tensor parallel dims ---
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    # --- sequence parallelism (activations) ---
    "seq_sp": "model",
    # --- decode-time KV length sharding (flash-decoding style) ---
    "kv_len": "model",
    # --- never sharded ---
    "layers": None,
    "groups": None,
    "experts": None,
    "stack": None,
    "conv": None,
    "state": None,
    "qk": None,
    "pos": None,
    "patch": None,
    None: None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Tuple[Tuple[Optional[str], Any], ...]

    @staticmethod
    def default(**overrides) -> "ShardingRules":
        d = dict(DEFAULT_RULES)
        d.update(overrides)
        return ShardingRules(tuple(d.items()))

    @staticmethod
    def for_profile(profile: str) -> "ShardingRules":
        """Resolve a config's sharding_profile to rules.

        "2d": FSDP over data x TP over model (default; big models).
        "dp": both mesh axes carry batch; params 2D-FSDP over
              (data, model); no tensor-parallel collectives at all —
              the small-model right-sizing profile (§Perf q2)."""
        if profile == "dp":
            return ShardingRules.default(
                batch=("pod", "data", "model"),
                fsdp=("pod", "data", "model"),
                heads=None, kv_heads=None, mlp=None,
                ssm_inner=None, ssm_heads=None,
                # kv_len / seq_sp keep the model axis: per-tensor axis
                # accounting means they only engage when batch could not
                # fill both axes (prefill gb=32, decode gb=128) — context
                # parallelism for free where DP runs out
                vocab=None,
            )
        return ShardingRules.default()

    def lookup(self, name: Optional[str]):
        for k, v in self.rules:
            if k == name:
                return v
        return None


def _axes_in_mesh(mesh: Mesh, axis) -> Tuple[str, ...]:
    if axis is None:
        return ()
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    return tuple(a for a in axes if a in mesh.axis_names)


def logical_to_spec(
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: ShardingRules,
    dims: Optional[Sequence[int]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec for `mesh`.

    If `dims` is given, sharding a dim is skipped unless the dim size is
    divisible by the product of the mapped mesh axis sizes.
    """
    spec = []
    used: set = set()
    for i, name in enumerate(logical):
        axes = _axes_in_mesh(mesh, rules.lookup(name))
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            spec.append(None)
            continue
        if dims is not None:
            prod = int(np.prod([mesh.shape[a] for a in axes]))
            if prod == 0 or dims[i] % prod != 0:
                # try a prefix of the axes that divides
                ok = ()
                p = 1
                for a in axes:
                    p *= mesh.shape[a]
                    if dims[i] % p == 0:
                        ok = ok + (a,)
                if not ok:
                    spec.append(None)
                    continue
                axes = ok
        used.update(axes)
        spec.append(axes if len(axes) > 1 else axes[0])
    return P(*spec)


def named_sharding(
    mesh: Mesh,
    logical: Sequence[Optional[str]],
    rules: ShardingRules,
    dims: Optional[Sequence[int]] = None,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, mesh, rules, dims))


# ---------------------------------------------------------------------------
# Activation constraints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------


_ACTIVE_RULES: list = []


class use_rules:
    """Context manager: activation `shard()` constraints follow these
    rules while tracing (profile-dependent layouts)."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()


def current_rules() -> ShardingRules:
    return _ACTIVE_RULES[-1] if _ACTIVE_RULES else ShardingRules.default()


def shard(x, logical: Sequence[Optional[str]], rules: Optional[ShardingRules] = None):
    """with_sharding_constraint by logical names; safe without a mesh."""
    rules = rules or current_rules()
    try:
        mesh = get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        spec = logical_to_spec(logical, mesh, rules, dims=x.shape)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def get_abstract_mesh():
    # public API only from jax 0.5; older versions fall back to no mesh
    # (callers degrade to their local/unsharded path)
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    m = fn()
    if m is None or m.empty:
        return None
    return m


# ---------------------------------------------------------------------------
# ParamSpec: single source of truth for shapes / init / sharding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal | alog | dtbias
    scale: float = 1.0
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def spec_avals(specs, dtype_override: Optional[str] = None):
    import jax.numpy as jnp

    def mk(s: ParamSpec):
        dt = jnp.dtype(dtype_override or s.dtype)
        return jax.ShapeDtypeStruct(s.shape, dt)

    return jax.tree.map(mk, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_shardings(specs, mesh: Mesh, rules: Optional[ShardingRules] = None):
    rules = rules or ShardingRules.default()

    def mk(s: ParamSpec):
        return named_sharding(mesh, s.logical, rules, dims=s.shape)

    return jax.tree.map(mk, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_params(specs, key, dtype_override: Optional[str] = None):
    """Materialise real parameters (smoke tests / real training)."""
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))

    def mk(s: ParamSpec, k):
        dt = jnp.dtype(dtype_override or s.dtype)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        if s.init == "alog":  # mamba A_log init: log uniform [1,16]
            u = jax.random.uniform(k, s.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        if s.init == "dtbias":  # softplus^-1 of uniform dt
            u = jax.random.uniform(k, s.shape, jnp.float32, 1e-3, 1e-1)
            return (u + jnp.log(-jnp.expm1(-u))).astype(dt)
        std = s.scale / max(1.0, float(s.shape[0]) ** 0.5) if s.init == "normal" else 0.02 * s.scale
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt)

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])
