"""Gradient compression for the cross-pod collective.

Two schemes, both with error feedback (the residual of this step's
quantisation is added back into the next step's gradient, preserving
convergence — Karimireddy et al. style):

  * int8 block quantisation: 4x wire reduction on the fp32 grad
    all-reduce (the dominant cross-pod collective for FSDP training).
  * top-k sparsification: keep the k largest-|g| entries per tensor.

`make_compressor(kind)` returns (init_state, compress) where compress
maps (grads, state) -> (decompressed grads, new state).  The wrapper is
deliberately quantise->dequantise: XLA then carries the int8/sparse form
through the reduce (on the wire this is the cross-pod reduce precision);
napkin + measured wire bytes live in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantisation. Returns (q, scales)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q: jax.Array, scale: jax.Array, shape, n: int) -> jax.Array:
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return deq.reshape(shape)


def int8_roundtrip(x: jax.Array) -> jax.Array:
    q, s = _quant_int8(x)
    return _dequant_int8(q, s, x.shape, x.size)


def topk_roundtrip(x: jax.Array, frac: float = 0.05) -> jax.Array:
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(x.shape)


def make_compressor(kind: str = "int8", topk_frac: float = 0.05):
    """Returns (init_state_fn, compress_fn) with error feedback."""

    if kind == "none":
        return (lambda params: None), (lambda g, s: (g, s))

    def init_state(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(grads, err):
        def one(g, e):
            g = g.astype(jnp.float32) + e
            if kind == "int8":
                sent = int8_roundtrip(g)
            elif kind == "topk":
                sent = topk_roundtrip(g, topk_frac)
            else:
                raise ValueError(kind)
            return sent, g - sent  # residual feeds back next step

        pairs = jax.tree.map(one, grads, err)
        sent = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
        return sent, new_err

    return init_state, compress


def wire_bytes(params_count: int, kind: str, topk_frac: float = 0.05) -> float:
    """Napkin model of the cross-pod gradient collective, bytes/device."""
    if kind == "int8":
        return params_count * (1 + 4 / BLOCK)  # int8 + fp32 scale per block
    if kind == "topk":
        return params_count * topk_frac * 8  # value + index
    return params_count * 4.0
