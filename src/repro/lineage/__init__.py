"""repro.lineage — per-batch provenance, event-time watermarks, and
freshness SLIs for the ingest->query path.

Layer three of the observability stack: PR-7 spans time the *stages*,
PR-9 series watch the *aggregates*, this package follows the *data* —
every batch carries a monotone id + event-time envelope from the
source through buffer/spill/pool/archive to the queryable snapshot,
and the watermark pair (committed vs queryable) turns that into the
user-facing question: how stale is the graph a query sees, and which
hop made it so?

Entry points: ``PipelineBuilder.with_lineage()``,
``run_scenario(lineage=True)``, ``python -m repro.launch.lineage``.
"""
from repro.lineage.tracker import (
    PATHS,
    BatchTag,
    LineageTracker,
)
from repro.lineage.export import (
    flow_events,
    freshness_table,
    prometheus_lines,
    sample_tags,
    validate_flow_events,
    watermark_timeline,
    write_lineage_jsonl,
)

__all__ = [
    "PATHS",
    "BatchTag",
    "LineageTracker",
    "flow_events",
    "freshness_table",
    "prometheus_lines",
    "sample_tags",
    "validate_flow_events",
    "watermark_timeline",
    "write_lineage_jsonl",
]
