"""Lineage exporters: hash-sampled hop logs as JSONL + Chrome-trace
flow events, the per-path freshness table, and the watermark timeline.

Sampling is a deterministic hash over the monotone ``batch_id``
(Knuth multiplicative), so the same run always exports the same tags
— plus the earliest few tags of *every* traversed path are always
included, so a short CI smoke still gets >=1 flow per path.

Flow events use the Chrome ``trace_event`` flow phases (``"s"`` start,
``"t"`` step, ``"f"`` end sharing one ``id``): loaded next to the
PR-7 span trace they render as Perfetto arrows following one batch
from the buffer through pool/archive detours to the queryable store.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.lineage.tracker import BatchTag, LineageTracker, PATHS

_KNUTH = 0x9E3779B1


def _sampled(batch_id: int, rate: float) -> bool:
    return ((batch_id * _KNUTH) & 0xFFFFFFFF) < int(rate * (1 << 32))


def sample_tags(tracker: LineageTracker,
                rate: Optional[float] = None) -> List[BatchTag]:
    """Deterministic hash sample of the completed tags, guaranteeing
    at least `tracker.min_sampled_per_path` earliest tags per path."""
    rate = tracker.sample_rate if rate is None else float(rate)
    floor = tracker.min_sampled_per_path
    taken: Dict[str, int] = {}
    out: List[BatchTag] = []
    for tag in tracker.completed:
        p = tag.path
        if _sampled(tag.batch_id, rate) or taken.get(p, 0) < floor:
            out.append(tag)
            taken[p] = taken.get(p, 0) + 1
    return out


# ---------------------------------------------------------------------------
# Chrome-trace flow events
# ---------------------------------------------------------------------------

def _tid(shard: Optional[int]) -> int:
    # mirror repro.telemetry.export: track 0 = main, shard s = s+1
    return 0 if shard is None else int(shard) + 1


def flow_events(tracker: LineageTracker, t0_ns: int,
                rate: Optional[float] = None) -> List[Dict]:
    """Sampled batch hop logs as trace_event *flow* events, placed on
    the span timeline via each hop's host timestamp (`t0_ns` is the
    telemetry registry's run origin, ``reg.t0_ns``)."""
    events: List[Dict] = []
    for tag in sample_tags(tracker, rate=rate):
        hops = tag.hops
        if len(hops) < 2:
            continue  # an arrow needs two ends
        last = len(hops) - 1
        for j, (hop, t, wall_ns) in enumerate(hops):
            ph = "s" if j == 0 else ("f" if j == last else "t")
            ev = {
                "name": f"batch:{tag.path}", "cat": "lineage", "ph": ph,
                "id": tag.batch_id, "pid": 0, "tid": _tid(tag.shard),
                "ts": (wall_ns - t0_ns) / 1e3,
                "args": {"hop": hop, "t": t, "batch_id": tag.batch_id,
                         "n_records": tag.n_records, "path": tag.path},
            }
            if ph == "f":
                ev["bp"] = "e"  # bind the arrow end to the enclosing slice
            events.append(ev)
    return events


def validate_flow_events(trace, require_paths: Sequence[str] = ()
                         ) -> Tuple[bool, str]:
    """(ok, message): the trace carries well-formed lineage flow
    events and every path in `require_paths` has >=1 complete
    (start..finish) flow chain."""
    if isinstance(trace, str):
        try:
            if trace.lstrip().startswith("{"):
                trace = json.loads(trace)
            else:
                with open(trace) as f:
                    trace = json.load(f)
        except (OSError, ValueError) as e:
            return False, f"trace does not parse: {e!r}"
    if not isinstance(trace, dict) or not isinstance(
            trace.get("traceEvents"), list):
        return False, "missing traceEvents list"
    flows = [e for e in trace["traceEvents"]
             if isinstance(e, dict) and e.get("cat") == "lineage"
             and e.get("ph") in ("s", "t", "f")]
    if not flows:
        return False, "no lineage flow events"
    for e in flows:
        if not all(k in e for k in ("name", "id", "ts", "pid", "tid")):
            return False, f"malformed flow event: {e}"
    chains: Dict[Tuple[str, int], set] = {}
    for e in flows:
        path = str(e["name"]).split(":", 1)[-1]
        chains.setdefault((path, e["id"]), set()).add(e["ph"])
    complete = {p for (p, _), phs in chains.items()
                if "s" in phs and "f" in phs}
    missing = [p for p in require_paths if p not in complete]
    if missing:
        return False, f"paths with no complete flow chain: {missing}"
    return True, (f"{len(flows)} flow events over "
                  f"{len(chains)} batches, paths={sorted(complete)}")


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------

def write_lineage_jsonl(tracker: LineageTracker, path: str,
                        meta: Optional[Dict] = None,
                        rate: Optional[float] = None) -> str:
    """One meta line (watermarks, conservation, sampling), then one
    line per sampled tag, then the per-path freshness histograms."""
    tags = sample_tags(tracker, rate=rate)
    with open(path, "w") as f:
        f.write(json.dumps({
            "type": "meta", "exporter": "repro.lineage",
            "batches_opened": tracker.batches_opened,
            "batches_committed": tracker.batches_committed,
            "batches_dropped": tracker.batches_dropped,
            "replays": tracker.replays,
            "sampled": len(tags),
            "sample_rate": tracker.sample_rate,
            "tags_evicted": tracker.completed_dropped,
            "watermarks": tracker.watermarks(),
            "conservation": tracker.conservation(),
            **(meta or {}),
        }) + "\n")
        for tag in tags:
            f.write(json.dumps({"type": "batch", **tag.to_dict()}) + "\n")
        for pth, row in tracker.freshness().items():
            f.write(json.dumps({"type": "freshness", "path": pth,
                                **row}) + "\n")
        for row in tracker.timeline:
            f.write(json.dumps({"type": "watermark", **row}) + "\n")
    return path


# ---------------------------------------------------------------------------
# human-readable views (launch.lineage)
# ---------------------------------------------------------------------------

def freshness_table(tracker: LineageTracker) -> str:
    """Per-path freshness: batch counts + ingest/queryable lag stats."""
    fresh = tracker.freshness()
    out = ["== per-path freshness (stream-time lag, ms) =="]
    if not fresh:
        out.append("(no batches committed — was lineage enabled?)")
        return "\n".join(out)
    out.append(f"{'path':<10}{'batches':>8}{'share':>8}"
               f"{'ingest_p50':>12}{'ingest_p99':>12}"
               f"{'query_p50':>12}{'query_p99':>12}{'query_max':>12}")
    total = sum(r["batches"] for r in fresh.values()) or 1
    for pth in PATHS:
        if pth not in fresh:
            continue
        r = fresh[pth]
        ing, qry = r["ingest"], r["queryable"]
        out.append(
            f"{pth:<10}{r['batches']:>8}{r['batches'] / total:>8.1%}"
            f"{ing['p50_ms']:>12.1f}{ing['p99_ms']:>12.1f}"
            f"{qry['p50_ms']:>12.1f}{qry['p99_ms']:>12.1f}"
            f"{qry['max_ms']:>12.1f}")
    lag = tracker.lag_percentiles_ms()
    out.append(f"{'all':<10}{total:>8}{'':>8}"
               f"{'':>12}{lag['ingest_lag_ms_p99']:>12.1f}"
               f"{'':>12}{lag['queryable_lag_ms_p99']:>12.1f}{'':>12}")
    return "\n".join(out)


def watermark_timeline(tracker: LineageTracker, max_rows: int = 20) -> str:
    """The watermark trajectory (evenly subsampled to `max_rows`)."""
    rows = list(tracker.timeline)
    out = [f"== watermark timeline ({len(rows)} ticks) =="]
    if not rows:
        out.append("(no watermark observations)")
        return "\n".join(out)
    out.append(f"{'t':>8}{'committed':>11}{'queryable':>11}"
               f"{'ingest_lag':>12}{'query_lag':>12}{'pending':>9}")
    step = max(1, len(rows) // max_rows)
    shown = rows[::step]
    if shown[-1] is not rows[-1]:
        shown.append(rows[-1])
    for r in shown:
        out.append(f"{r['t']:>8.1f}{r['committed']:>11.1f}"
                   f"{r['queryable']:>11.1f}"
                   f"{r['ingest_lag_ms']:>11.0f}ms"
                   f"{r['queryable_lag_ms']:>11.0f}ms"
                   f"{r['pending_queryable']:>9}")
    return "\n".join(out)


def prometheus_lines(tracker: LineageTracker) -> List[str]:
    """Lineage gauges for the Prometheus exposition (appended by
    `repro.monitor.export.prometheus_text` when given a tracker)."""
    wm = tracker.watermarks()
    lines = [
        "# HELP repro_lineage_watermark Event-time watermarks "
        "(stream seconds).",
        "# TYPE repro_lineage_watermark gauge",
    ]
    for k in ("committed", "queryable", "max_event_t"):
        v = wm.get(k)
        if v is not None:
            lines.append(f'repro_lineage_watermark{{kind="{k}"}} {v}')
    lines += [
        "# HELP repro_lineage_batches_total Committed batches per path.",
        "# TYPE repro_lineage_batches_total counter",
    ]
    for pth in PATHS:
        n = tracker.path_counts.get(pth, 0)
        lines.append(f'repro_lineage_batches_total{{path="{pth}"}} {n}')
    lines += [
        "# HELP repro_lineage_lag_ms Freshness lag percentiles "
        "(stream-time ms).",
        "# TYPE repro_lineage_lag_ms gauge",
    ]
    for pth, row in tracker.freshness().items():
        for kind in ("ingest", "queryable"):
            for q in ("p50_ms", "p99_ms"):
                lines.append(
                    f'repro_lineage_lag_ms{{path="{pth}",kind="{kind}",'
                    f'quantile="{q[:-3]}"}} {row[kind][q]}')
    cons = tracker.conservation()
    lines += [
        "# HELP repro_lineage_records_total Record conservation counters.",
        "# TYPE repro_lineage_records_total counter",
    ]
    for k in ("records_in", "records_committed", "records_dropped"):
        lines.append(f'repro_lineage_records_total{{state="{k[8:]}"}} '
                     f'{cons[k]}')
    return lines
