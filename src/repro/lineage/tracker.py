"""Batch provenance + event-time watermarks (`LineageTracker`).

The third observability layer (after PR-7 spans and PR-9 per-tick
series): record-level freshness.  Every batch the pipeline commits
gets a `BatchTag` — a monotone ``batch_id``, the batch's event-time
envelope (stamped by the counter-deterministic simulated clock at the
source), and a hop log of everywhere the batch dwelled on its way to
the store (buffer, spill, ingestion pool, archive, commit, snapshot/
sketch).  The tracker folds tags into:

  * a **committed low watermark** — the oldest event time not yet
    landed in the graph store — and a **queryable watermark** that
    only advances once the commit's ``CommitDelta`` has been absorbed
    by the snapshot maintainer / sketch (the `commit_hook` fan-out),
    i.e. once a query could actually see the data;
  * **per-path freshness histograms** — direct-push vs buffered vs
    spilled vs archived-retry batches get separate ingest-lag and
    queryable-lag distributions (the log-bucket `Histogram` from
    `repro.telemetry`), so a lag spike is attributable to the hop
    that caused it;
  * **conservation counters** — ``records_in`` at buffer intake vs
    committed/dropped/in-flight at the end of a run (silent loss on
    the spill/archive/degraded paths shows up as an imbalance).

Everything is keyed on the *simulated* stream clock, so watermarks
and freshness histograms are deterministic for a given scenario seed
and identical across checkpoint/resume; host wall-clock only rides
along in the hop log for Chrome-trace flow events.

Zero-cost when absent: every integration point guards on the tracker
reference being non-None, and nothing here is constructed unless
`PipelineBuilder.with_lineage()` / `run_scenario(lineage=...)` asked
for it.
"""
from __future__ import annotations

import heapq
import time
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.telemetry.spans import Histogram

# Commit routes a batch can take (ordered by precedence when flags
# overlap: an archived batch that was also spilled reports "archived"
# — the dominant detour is the one that set its freshness).
PATHS = ("direct", "buffered", "spilled", "archived")


@dataclass
class BatchTag:
    """Provenance for one committed batch (picklable; rides through
    `state()/restore_state()` checkpoints alongside its batch)."""

    batch_id: int
    n_records: int
    event_t_min: float          # oldest record event time in the batch
    event_t_max: float          # newest record event time in the batch
    t_open: float               # stream time the batch left the buffer
    ts_counts: Dict[float, int]  # event time -> record count (watermarks)
    shard: Optional[int] = None
    spilled: bool = False       # detoured through the disk spill store
    buffered: bool = False      # waited >= a tick in the record buffer
    pooled: bool = False        # held in the ingestion pool (busy store)
    archived: bool = False      # archived after a failed commit
    degraded: bool = False      # archived by degraded-mode direct put
    replays: int = 0            # archive replay attempts
    dropped: bool = False       # terminally lost (no archive available)
    t_commit: Optional[float] = None     # stream time the store took it
    t_queryable: Optional[float] = None  # ... and queries could see it
    # hop log: (hop name, stream time, host perf_counter_ns) — the
    # wall-clock column exists only to place Chrome-trace flow events
    # onto the PR-7 span timeline; nothing compares it across runs
    hops: List[Tuple[str, float, int]] = field(default_factory=list)

    @property
    def path(self) -> str:
        """The dominant commit route (archive > spill > buffer > direct)."""
        if self.archived or self.degraded:
            return "archived"
        if self.spilled:
            return "spilled"
        if self.buffered or self.pooled:
            return "buffered"
        return "direct"

    def hop(self, name: str, now: float) -> None:
        self.hops.append((name, float(now), time.perf_counter_ns()))

    def to_dict(self) -> Dict:
        return {
            "batch_id": self.batch_id, "shard": self.shard,
            "path": self.path, "n_records": self.n_records,
            "event_t_min": self.event_t_min, "event_t_max": self.event_t_max,
            "t_open": self.t_open, "t_commit": self.t_commit,
            "t_queryable": self.t_queryable, "replays": self.replays,
            "dropped": self.dropped, "degraded": self.degraded,
            "hops": [{"hop": h, "t": t, "wall_ns": ns}
                     for (h, t, ns) in self.hops],
        }


class _WatermarkSet:
    """Multiset of pending event times with an O(log n) running min.

    ``add`` at buffer intake, ``remove`` when the records land; the
    watermark is the oldest still-pending event time — or, once the
    set drains empty, the newest event time ever seen (the stream is
    fully caught up).  Lazy-deletion heap: stale heads are popped on
    read, duplicate pushes are harmless.
    """

    __slots__ = ("pending", "_heap", "max_seen", "seen")

    def __init__(self):
        self.pending: Dict[float, int] = {}
        self._heap: List[float] = []
        self.max_seen = 0.0
        self.seen = False

    def add(self, ts_counts: Dict[float, int]) -> None:
        for ts, c in ts_counts.items():
            if ts not in self.pending:
                heapq.heappush(self._heap, ts)
            self.pending[ts] = self.pending.get(ts, 0) + c
            if not self.seen or ts > self.max_seen:
                self.max_seen = ts
            self.seen = True

    def remove(self, ts_counts: Dict[float, int]) -> None:
        for ts, c in ts_counts.items():
            left = self.pending.get(ts, 0) - c
            if left > 0:
                self.pending[ts] = left
            else:
                self.pending.pop(ts, None)

    def watermark(self) -> Optional[float]:
        while self._heap and self._heap[0] not in self.pending:
            heapq.heappop(self._heap)
        if self._heap:
            return self._heap[0]
        return self.max_seen if self.seen else None

    @property
    def depth(self) -> int:
        return sum(self.pending.values())

    def state(self) -> Dict:
        return {"pending": dict(self.pending), "max_seen": self.max_seen,
                "seen": self.seen}

    def restore_state(self, s: Dict) -> None:
        self.pending = dict(s["pending"])
        self._heap = list(self.pending)
        heapq.heapify(self._heap)
        self.max_seen = float(s["max_seen"])
        self.seen = bool(s["seen"])


def _ts_counts(records: List[dict]) -> Dict[float, int]:
    return dict(Counter(float(r.get("ts", 0.0)) for r in records))


class LineageTracker:
    """Watermarks + per-path freshness + per-batch hop logs for a run.

    Wiring (done by `PipelineBuilder.with_lineage`): the buffer
    stage(s) call `observe_intake` on every `extend`; `controlled_tick`
    opens a tag per batch and hands it to the ingestor; the ingestor
    marks pool/archive/commit/queryable transitions as the batch moves
    through GRAPHPUSH; `bind(hub)` subscribes the tracker so every
    ``"tick"`` event re-emits a ``"watermark"`` event carrying the
    current ingest/queryable staleness (the `freshness` SLO input).
    """

    def __init__(self, sample_rate: float = 0.25,
                 min_sampled_per_path: int = 3, dt: float = 1.0,
                 buffered_slack: float = 0.5, max_tags: int = 4096,
                 max_timeline: int = 4096):
        self.sample_rate = float(sample_rate)
        self.min_sampled_per_path = int(min_sampled_per_path)
        self.dt = float(dt)
        self.buffered_slack = float(buffered_slack)
        self.max_tags = int(max_tags)
        # conservation counters (records)
        self.records_in = 0
        self.records_committed = 0
        self.records_dropped = 0
        # batch counters
        self.batches_opened = 0
        self.batches_committed = 0
        self.batches_dropped = 0
        self.replays = 0
        self._next_batch_id = 0
        # watermark state
        self._commit_ws = _WatermarkSet()
        self._query_ws = _WatermarkSet()
        self._wm_committed: Optional[float] = None
        self._wm_queryable: Optional[float] = None
        # per-path freshness: ("ingest"|"queryable", path) -> Histogram
        self._hists: Dict[Tuple[str, str], Histogram] = {}
        self.path_counts: Dict[str, int] = {}
        # finished tags (bounded) + watermark timeline rows
        self.completed: Deque[BatchTag] = deque(maxlen=self.max_tags)
        self.completed_dropped = 0
        self.open_tags: Dict[int, BatchTag] = {}
        self.timeline: Deque[Dict] = deque(maxlen=int(max_timeline))
        self._hub = None

    # ------------------------------------------------------------------
    # intake + tagging (pipeline side)
    # ------------------------------------------------------------------
    def observe_intake(self, records: List[dict]) -> None:
        """Records entered the buffer: both watermarks now owe them."""
        if not records:
            return
        counts = _ts_counts(records)
        self.records_in += len(records)
        self._commit_ws.add(counts)
        self._query_ws.add(counts)

    def open_batch(self, records: List[dict], now: float,
                   shard: Optional[int] = None,
                   spilled: bool = False) -> BatchTag:
        """A batch left the buffer toward the sink; tag it."""
        counts = _ts_counts(records)
        tag = BatchTag(
            batch_id=self._next_batch_id,
            n_records=len(records),
            event_t_min=min(counts) if counts else float(now),
            event_t_max=max(counts) if counts else float(now),
            t_open=float(now),
            ts_counts=counts,
            shard=shard,
            spilled=bool(spilled),
        )
        self._next_batch_id += 1
        self.batches_opened += 1
        # records stamped this tick have ts == now exactly; anything
        # older than the slack sat in the buffer at least one decide
        tag.buffered = (now - tag.event_t_max) > self.buffered_slack * self.dt
        tag.hop("open", now)
        self.open_tags[tag.batch_id] = tag
        return tag

    def stage_commit(self, tag: BatchTag, sink) -> bool:
        """Hand the tag to the sink's ingestor (if it has one) for the
        upcoming `commit`.  Returns True when an ingestor took custody
        (it will apply the pool/archive/commit marks itself)."""
        ing = getattr(sink, "ingestor", None)
        if ing is not None and hasattr(ing, "_lineage_next"):
            ing._lineage_next = tag
            return True
        return False

    def after_commit(self, tag: BatchTag, out: Optional[Dict],
                     now: float, handed: bool = False) -> None:
        """Resolve a tag no ingestor took custody of (custom sinks):
        the commit result is all the provenance there is."""
        if handed:
            return
        if out and out.get("committed"):
            self.mark_committed(tag, now)
            self.mark_queryable(tag, now)
        else:
            self.mark_dropped(tag, now)

    # ------------------------------------------------------------------
    # hop marks (ingestor side)
    # ------------------------------------------------------------------
    def mark_pooled(self, tag: BatchTag, now: float) -> None:
        tag.pooled = True
        tag.hop("pool", now)

    def mark_archived(self, tag: BatchTag, now: float,
                      degraded: bool = False) -> None:
        tag.archived = True
        tag.degraded = tag.degraded or degraded
        tag.hop("archive", now)

    def mark_replay(self, tag: BatchTag, now: float) -> None:
        tag.replays += 1
        self.replays += 1
        tag.hop("retry", now)

    def mark_committed(self, tag: BatchTag, now: float) -> None:
        if tag.t_commit is not None:
            return
        tag.t_commit = float(now)
        tag.hop("commit", now)
        self.records_committed += tag.n_records
        self.batches_committed += 1
        self._commit_ws.remove(tag.ts_counts)
        lag_ns = int(max(0.0, now - tag.event_t_min) * 1e9)
        self._hist("ingest", tag.path).record_ns(lag_ns)
        self._advance()

    def mark_queryable(self, tag: BatchTag, now: float) -> None:
        """The commit's delta landed in the snapshot/sketch: queries
        can now see these records — the queryable watermark moves."""
        if tag.t_queryable is not None:
            return
        tag.t_queryable = float(now)
        tag.hop("queryable", now)
        self._query_ws.remove(tag.ts_counts)
        lag_ns = int(max(0.0, now - tag.event_t_min) * 1e9)
        self._hist("queryable", tag.path).record_ns(lag_ns)
        self.path_counts[tag.path] = self.path_counts.get(tag.path, 0) + 1
        self._advance()
        self._finish(tag)

    def mark_dropped(self, tag: BatchTag, now: float) -> None:
        if tag.dropped:
            return
        tag.dropped = True
        tag.hop("drop", now)
        self.records_dropped += tag.n_records
        self.batches_dropped += 1
        if tag.t_commit is None:
            self._commit_ws.remove(tag.ts_counts)
        if tag.t_queryable is None:
            self._query_ws.remove(tag.ts_counts)
        self._advance()
        self._finish(tag)

    def _finish(self, tag: BatchTag) -> None:
        self.open_tags.pop(tag.batch_id, None)
        if len(self.completed) == self.completed.maxlen:
            self.completed_dropped += 1
        self.completed.append(tag)

    def _hist(self, kind: str, path: str) -> Histogram:
        h = self._hists.get((kind, path))
        if h is None:
            h = self._hists[(kind, path)] = Histogram()
        return h

    # ------------------------------------------------------------------
    # watermarks
    # ------------------------------------------------------------------
    def _advance(self) -> None:
        wc = self._commit_ws.watermark()
        if wc is not None:
            self._wm_committed = wc if self._wm_committed is None \
                else max(self._wm_committed, wc)
        wq = self._query_ws.watermark()
        if wq is not None:
            self._wm_queryable = wq if self._wm_queryable is None \
                else max(self._wm_queryable, wq)
        # Wq <= Wc by construction (query pending is a superset of
        # commit pending); the clamp keeps it an invariant even if a
        # custom sink marks out of order
        if self._wm_queryable is not None and self._wm_committed is not None:
            self._wm_queryable = min(self._wm_queryable, self._wm_committed)

    def watermarks(self) -> Dict:
        return {
            "committed": self._wm_committed,
            "queryable": self._wm_queryable,
            "max_event_t": self._commit_ws.max_seen
            if self._commit_ws.seen else None,
            "pending_commit": self._commit_ws.depth,
            "pending_queryable": self._query_ws.depth,
        }

    def current_lags_ms(self, now: float) -> Dict[str, Optional[float]]:
        """Staleness of the store (ingest) and of the query surface
        (queryable) at stream time `now`, in milliseconds."""
        c = None if self._wm_committed is None else \
            max(0.0, (now - self._wm_committed) * 1e3)
        q = None if self._wm_queryable is None else \
            max(0.0, (now - self._wm_queryable) * 1e3)
        return {"ingest_lag_ms": c, "queryable_lag_ms": q}

    # ------------------------------------------------------------------
    # per-tick hook (freshness SLI feed)
    # ------------------------------------------------------------------
    def bind(self, hub) -> "LineageTracker":
        """Subscribe to `hub` so every tick re-emits the watermark
        staleness as a ``"watermark"`` event (picked up by the monitor
        as the `queryable_lag_ms` / `ingest_lag_ms` series).  Bind
        AFTER the monitor so the nested emit lands in the tick row the
        monitor just opened."""
        self._hub = hub
        hub.subscribe(self.on_event)
        return self

    def on_event(self, ev) -> None:
        if ev.kind != "tick":
            return
        lags = self.current_lags_ms(ev.t)
        if lags["queryable_lag_ms"] is None:
            return
        row = {
            "t": float(ev.t),
            "committed": self._wm_committed,
            "queryable": self._wm_queryable,
            "ingest_lag_ms": lags["ingest_lag_ms"],
            "queryable_lag_ms": lags["queryable_lag_ms"],
            "pending_commit": self._commit_ws.depth,
            "pending_queryable": self._query_ws.depth,
        }
        self.timeline.append(row)
        if self._hub is not None:
            payload = {k: v for k, v in row.items() if k != "t"}
            self._hub.emit("watermark", ev.t, **payload)

    # ------------------------------------------------------------------
    # aggregation / reporting
    # ------------------------------------------------------------------
    def aggregate_hist(self, kind: str) -> Histogram:
        out = Histogram()
        for (k, _), h in self._hists.items():
            if k == kind:
                out.merge(h)
        return out

    def freshness(self) -> Dict[str, Dict]:
        """Per-path freshness table: ingest + queryable lag stats."""
        out: Dict[str, Dict] = {}
        for path in PATHS:
            ing = self._hists.get(("ingest", path))
            qry = self._hists.get(("queryable", path))
            if ing is None and qry is None:
                continue
            out[path] = {
                "batches": self.path_counts.get(path, 0),
                "ingest": (ing or Histogram()).stats(),
                "queryable": (qry or Histogram()).stats(),
            }
        return out

    def lag_percentiles_ms(self) -> Dict[str, float]:
        ing = self.aggregate_hist("ingest")
        qry = self.aggregate_hist("queryable")
        ms = 1e-6
        return {
            "ingest_lag_ms_p50": round(ing.percentile_ns(0.50) * ms, 6),
            "ingest_lag_ms_p99": round(ing.percentile_ns(0.99) * ms, 6),
            "queryable_lag_ms_p99": round(qry.percentile_ns(0.99) * ms, 6),
        }

    def in_flight_records(self) -> int:
        """Records inside open tags (pool / archive / mid-commit)."""
        return sum(t.n_records for t in self.open_tags.values())

    def conservation(self, buffered_records: int = 0) -> Dict:
        """The end-of-run invariant: everything that entered the
        buffer is committed, dropped, or demonstrably still in flight
        (stage buffers + spill are passed in as `buffered_records`)."""
        in_flight = int(buffered_records) + self.in_flight_records()
        imbalance = self.records_in - (self.records_committed
                                       + self.records_dropped + in_flight)
        return {
            "records_in": self.records_in,
            "records_committed": self.records_committed,
            "records_dropped": self.records_dropped,
            "records_in_flight": in_flight,
            "imbalance": imbalance,
        }

    # ------------------------------------------------------------------
    # checkpoint surface (repro.resilience)
    # ------------------------------------------------------------------
    def state(self) -> Dict:
        return {
            "records_in": self.records_in,
            "records_committed": self.records_committed,
            "records_dropped": self.records_dropped,
            "batches_opened": self.batches_opened,
            "batches_committed": self.batches_committed,
            "batches_dropped": self.batches_dropped,
            "replays": self.replays,
            "next_batch_id": self._next_batch_id,
            "commit_ws": self._commit_ws.state(),
            "query_ws": self._query_ws.state(),
            "wm_committed": self._wm_committed,
            "wm_queryable": self._wm_queryable,
            "path_counts": dict(self.path_counts),
            "hists": {k: {"counts": list(h.counts), "count": h.count,
                          "sum_ns": h.sum_ns, "max_ns": h.max_ns}
                      for k, h in self._hists.items()},
            "completed": list(self.completed),
            "completed_dropped": self.completed_dropped,
            "open_tags": dict(self.open_tags),
            "timeline": list(self.timeline),
        }

    def restore_state(self, s: Dict) -> None:
        self.records_in = int(s["records_in"])
        self.records_committed = int(s["records_committed"])
        self.records_dropped = int(s["records_dropped"])
        self.batches_opened = int(s["batches_opened"])
        self.batches_committed = int(s["batches_committed"])
        self.batches_dropped = int(s["batches_dropped"])
        self.replays = int(s["replays"])
        self._next_batch_id = int(s["next_batch_id"])
        self._commit_ws = _WatermarkSet()
        self._commit_ws.restore_state(s["commit_ws"])
        self._query_ws = _WatermarkSet()
        self._query_ws.restore_state(s["query_ws"])
        self._wm_committed = s["wm_committed"]
        self._wm_queryable = s["wm_queryable"]
        self.path_counts = dict(s["path_counts"])
        self._hists = {}
        for k, hs in s["hists"].items():
            h = Histogram()
            h.counts = list(hs["counts"])
            h.count = int(hs["count"])
            h.sum_ns = int(hs["sum_ns"])
            h.max_ns = int(hs["max_ns"])
            self._hists[tuple(k)] = h
        self.completed = deque(s["completed"], maxlen=self.max_tags)
        self.completed_dropped = int(s["completed_dropped"])
        self.open_tags = dict(s["open_tags"])
        self.timeline = deque(s["timeline"], maxlen=self.timeline.maxlen)
