"""Batched serving loop: continuous decode over a request batch.

`BatchServer` owns params + cache and exposes the two compiled entry
points (`prefill`, `step`); requests are admitted in batches (the
serving analogue of the paper's mini-batch commit) and decode proceeds
lock-step across the batch — the shape the decode_32k / long_500k
dry-run cells lower.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.kvcache import pad_cache_to
from repro.train.trainstep import make_serve_step


class BatchServer:
    def __init__(self, cfg: ModelConfig, params, horizon: int = 256):
        self.cfg = cfg
        self.params = params
        self.horizon = horizon
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=1)
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0}

    def generate(self, batch: dict, max_new: int = 32,
                 stop_token: Optional[int] = None) -> np.ndarray:
        """Prefill the prompt batch, then decode `max_new` tokens."""
        cfg = self.cfg
        t0 = time.perf_counter()
        logits, cache = M.prefill(self.params, cfg, batch)
        prompt_len = batch["tokens"].shape[1] + (cfg.num_patches or 0)
        total = prompt_len + max_new
        cache = pad_cache_to(cache, total)
        jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.perf_counter() - t0

        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(next_tok)]
        t0 = time.perf_counter()
        for i in range(max_new - 1):
            next_tok, cache = self._step(
                self.params, cache, next_tok, jnp.int32(prompt_len + i)
            )
            out.append(np.asarray(next_tok))
            if stop_token is not None and bool((out[-1] == stop_token).all()):
                break
        jax.block_until_ready(next_tok)
        self.stats["decode_s"] += time.perf_counter() - t0
        gen = np.stack(out, axis=1)
        self.stats["tokens"] += int(gen.size)
        return gen

    @property
    def tokens_per_s(self) -> float:
        return self.stats["tokens"] / max(self.stats["decode_s"], 1e-9)
