"""KV-cache utilities for serving.

Cache *specs* (shapes + shardings) live with each model family
(`repro.models.model.cache_specs`); this module owns the lifecycle
operations a server performs on them: allocating to a horizon, growing
a prefill cache into the serving buffer, and the rolling-window
semantics used by SWA archs (slot = pos % window, matching
`models.layers.decode_attention` and `transformer._pack_swa_cache`).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import spec_avals
from repro.models import model as M


def alloc_cache(cfg: ModelConfig, batch: int, horizon: int):
    """Zero-filled decode cache for `horizon` total positions."""
    from repro.distributed.sharding import init_params

    return init_params(M.cache_specs(cfg, batch, horizon), jax.random.key(0))


def pad_cache_to(cache: Any, total_len: int):
    """Grow prefill caches (length == prompt) to the serving horizon.

    K/V tensors are (L, B, S, m, h); SSM states are length-free and pass
    through untouched."""

    def f(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 5:
            pad = total_len - x.shape[2]
            if pad > 0:
                return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return x

    return jax.tree_util.tree_map_with_path(f, cache)


def cache_bytes(cfg: ModelConfig, batch: int, horizon: int) -> int:
    """Serving-capacity planning: bytes of the decode cache."""
    avals = spec_avals(M.cache_specs(cfg, batch, horizon))
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(avals))
