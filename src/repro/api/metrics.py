"""Structured metrics + event hooks for the ingestion loop.

Replaces the ad-hoc PerfSample plumbing: the pipeline emits typed
`PipelineEvent`s into a `MetricsHub`, which keeps the per-tick
`PerfSample` trace, counts events, fans out to subscriber hooks, and
assembles the final `PipelineReport`.  Hooks let callers watch the
loop live (dashboards, early-stop, logging) without touching it.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.buffer import PerfSample
from repro.telemetry.spans import TelemetryRegistry


@dataclasses.dataclass
class PipelineEvent:
    """One loop event.  `kind` is one of: tick, push, hold, throttle,
    spill, drain, commit, commit-failed, sample, report — plus the
    resilience audit events (repro.resilience): retry (archived
    batches replayed), degraded (batch archived while the store is
    down), pool_overflow (pool hard cap diverted a batch to the
    archive), checkpoint (step written)."""

    kind: str
    t: float
    payload: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PipelineReport:
    samples: dict
    actions: List[str]
    total_records: int
    total_instructions: int
    raw_instructions: int
    spill_events: int
    drain_events: int
    compression_ratios: np.ndarray
    wall_s: float

    @property
    def mean_compression(self) -> float:
        cr = self.compression_ratios
        return float(cr.mean()) if cr.size else 1.0


class MetricsHub:
    """Event bus + trace accumulator for one pipeline run.

    Event counts live in a `repro.telemetry.TelemetryRegistry` (the
    hub's `counters` is the registry's always-on Counter, so the
    pre-telemetry surface — ``hub.counters["spill"]`` — is unchanged).
    Pass a shared registry (or let `PipelineBuilder.with_telemetry`
    do it) and every span the pipeline records lands next to these
    counts; by default the hub owns a disabled registry, so span
    calls threaded through it cost one branch and allocate nothing.

    Hook semantics (pinned by tests/test_telemetry.py): counters
    increment on every `emit` whether or not hooks are attached; a
    `PipelineEvent` is only constructed when at least one hook is
    subscribed, and subscribers attached mid-run observe every
    subsequent event (never a replay of earlier ones).
    """

    def __init__(self, telemetry: Optional[TelemetryRegistry] = None):
        self.trace: List[PerfSample] = []
        self.telemetry = telemetry if telemetry is not None \
            else TelemetryRegistry(enabled=False)
        self._hooks: List[Callable[[PipelineEvent], None]] = []
        # the attached repro.monitor.HealthMonitor, when one is wired
        # (PipelineBuilder.with_monitor); it subscribes like any other
        # hook — this reference only exists so dashboards/exporters can
        # find the judge next to the signals
        self.monitor = None
        # the attached repro.lineage.LineageTracker, when one is wired
        # (PipelineBuilder.with_lineage): `controlled_tick` looks it up
        # here to tag batches; None keeps the hot path branch-only
        self.lineage = None

    @property
    def counters(self) -> collections.Counter:
        return self.telemetry.counters

    def subscribe(self, hook: Callable[[PipelineEvent], None]) -> "MetricsHub":
        self._hooks.append(hook)
        return self

    def emit(self, kind: str, t: float, **payload):
        self.counters[kind] += 1
        if self._hooks:
            ev = PipelineEvent(kind, t, payload)
            for h in self._hooks:
                h(ev)

    def record(self, sample: PerfSample):
        self.trace.append(sample)
        self.emit("sample", sample.t, action=sample.action, mu=sample.mu,
                  beta=sample.beta, spill_depth=sample.spill_depth)

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> dict:
        return {"trace": list(self.trace), "counters": dict(self.counters)}

    def restore_state(self, s: dict) -> None:
        self.trace = list(s["trace"])
        c = self.counters  # the registry's live Counter: mutate in place
        c.clear()
        c.update(s["counters"])

    # ---- trace -> arrays (same layout the seed controller produced) ----
    def trace_arrays(self):
        keys = [f.name for f in dataclasses.fields(PerfSample) if f.name != "action"]
        return {k: np.asarray([getattr(s, k) for s in self.trace]) for k in keys}, [
            s.action for s in self.trace
        ]

    def build_report(self, total_records: int, total_instructions: int,
                     raw_instructions: int, compression_ratios: List[float],
                     wall_s: float) -> PipelineReport:
        samples, actions = self.trace_arrays()
        rep = PipelineReport(
            samples=samples,
            actions=actions,
            total_records=total_records,
            total_instructions=total_instructions,
            raw_instructions=raw_instructions,
            spill_events=self.counters["spill"],
            drain_events=self.counters["drain"],
            compression_ratios=np.asarray(compression_ratios),
            wall_s=wall_s,
        )
        t_last = self.trace[-1].t if self.trace else 0.0
        self.emit("report", t_last, report=rep)
        return rep
