"""Composable streaming-ingestion API.

The paper's seven-step pipeline (Filter -> Buffer -> Transform ->
Batch-Optimize -> Ingest -> Pool -> Store) decomposed into explicit,
independently swappable protocols:

  * `Source`   — anything with a `ticks()` iterator of `StreamTick`s
                 (`BurstyTweetSource`, `FileReplaySource`, your own).
  * `Stage`    — per-tick record processing: `FilterStage`,
                 `TransformStage` (model transformation + graph
                 compression), `BufferControlStage` (Algorithm 2).
  * `Consumer` — the store-engine load model: `SimulatedConsumer`
                 (queued finite-capacity engine, the closed-loop
                 simulation) or `MeasuredConsumer` (busy-fraction of
                 the real compiled ingest step).
  * `Sink`     — commit target: `GraphStoreSink` (GRAPHPUSH pool +
                 device graph store), or any object with `commit()`.

`StreamPipeline` wires one of each into the paper's control loop;
`PipelineBuilder` is the fluent facade; `ShardedPipeline` hash-
partitions the stream by user across N per-shard buffer controllers
feeding a shared store — the first scale-out scenario.  `MetricsHub`
carries the structured per-tick trace and user event hooks.
"""
from repro.api.protocols import Consumer, Sink, Source, Stage, TickContext
from repro.api.consumers import MeasuredConsumer, SimulatedConsumer
from repro.api.sinks import GraphStoreSink
from repro.api.stages import BufferControlStage, FilterStage, TransformStage
from repro.api.metrics import MetricsHub, PipelineEvent, PipelineReport
from repro.api.pipeline import StreamPipeline
from repro.api.sharded import ShardedPipeline, ShardedReport
from repro.api.builder import PipelineBuilder

__all__ = [
    "Source", "Stage", "Consumer", "Sink", "TickContext",
    "SimulatedConsumer", "MeasuredConsumer",
    "GraphStoreSink",
    "FilterStage", "TransformStage", "BufferControlStage",
    "MetricsHub", "PipelineEvent", "PipelineReport",
    "StreamPipeline", "PipelineBuilder",
    "ShardedPipeline", "ShardedReport",
]
