"""`StreamPipeline`: the paper's closed control loop over pluggable parts.

Each tick: Source -> FilterStage -> BufferControlStage; the controller
(Algorithm 2) decides push/hold/throttle/drain from the predictive
models; pushed buckets go through TransformStage (Algorithm 1 + graph
compression) into the Sink (Algorithm 3 GRAPHPUSH), and the Consumer
absorbs the instruction load and reports occupancy mu back to the
controller.  `uncontrolled=True` bypasses the controller — the paper's
meltdown baseline (Figs. 1-3, 7).

The loop itself is the only fixed part; every box is swappable via the
constructor (or `PipelineBuilder`).
"""
from __future__ import annotations

import time
from typing import Iterable, Optional, Sequence

from repro.api.consumers import SimulatedConsumer
from repro.api.metrics import MetricsHub, PipelineReport
from repro.api.protocols import Source, TickContext
from repro.api.sinks import GraphStoreSink
from repro.api.stages import BufferControlStage, FilterStage, TransformStage
from repro.configs.paper_ingest import IngestConfig
from repro.core.buffer import PerfSample


def maybe_retry_archive(sink, hub: MetricsHub, now: float) -> int:
    """Backoff-governed archive replay (repro.resilience): runs every
    tick, but ONLY when the sink's ingestor carries a `RetryPolicy` —
    legacy pipelines (no policy) keep the manual `retry_archive()`
    surface and never auto-retry.  The policy's gate makes this cheap:
    while the backoff window is open the call returns without touching
    the store, so a dead connection is probed exponentially rarely
    instead of once per tick."""
    ing = getattr(sink, "ingestor", None)
    if ing is None or getattr(ing, "retry_policy", None) is None:
        return 0
    if not getattr(ing, "archive_depth", 0):
        return 0
    with hub.telemetry.span("retry.archive"):
        n = sink.retry_archive(now) if hasattr(sink, "retry_archive") \
            else ing.retry_archive(now)
    if n:
        hub.emit("retry", now, replayed=n, remaining=ing.archive_depth)
    return n


def controlled_tick(buf: BufferControlStage, transform, sink, consumer,
                    hub: MetricsHub, state: dict, now: float, dt: float,
                    consume_dt: Optional[float] = None):
    """One controlled tick (Algorithm 2 steps 2-7) on one buffer.

    Shared by `StreamPipeline` (one buffer) and `ShardedPipeline` (one
    call per shard) so the loop semantics cannot drift between them.
    `consume_dt` is the slice of the tick this buffer may drain from
    the consumer — dt/n_shards when N buffers share one consumer.
    `state` carries the cross-tick scalars: last_beta_e/last_mu for the
    mu-model updates, and the records/instr/raw/crs totals.
    """
    cdt = dt if consume_dt is None else consume_dt
    tel = hub.telemetry
    pm = buf.perfmon
    aud = buf.controller.audit
    lineage = getattr(hub, "lineage", None)
    with tel.span("decide"):
        dec = buf.decide(len(buf) * 4.0, 0.0, now=now)

    if dec.action in ("push", "drain+push") and len(buf) >= 1:
        if dec.action == "drain+push" and buf.spill_depth:
            with tel.span("spill.drain"):
                buf.drain_spill()
            hub.emit("drain", now, depth=buf.spill_depth)
        batch = buf.take_batch()
        if batch:
            tag = handed = None
            if lineage is not None:
                tag = lineage.open_batch(
                    batch, now, shard=getattr(tel, "shard", None),
                    spilled=buf.last_take_spilled)
            et, n_instr, raw_i = transform.encode(batch)
            if tag is not None:
                handed = lineage.stage_commit(tag, sink)
            out = sink.commit(et, now=now)
            if tag is not None:
                lineage.after_commit(tag, out, now, handed=handed)
            with tel.span("consume"):
                mu = consumer.consume(n_instr, cdt, now=now)
            committed = out.get("committed", False)
            rho = out.get("rho", 1.0) if committed else 1.0
            cr = float(et.compression_ratio())
            hub.emit("commit" if committed else "commit-failed", now,
                     instructions=n_instr, raw=raw_i, rho=rho, cr=cr,
                     dropped=out.get("dropped", 0),
                     probe_rounds=out.get("probe_rounds", 0),
                     pressure=out.get("pressure", 0.0),
                     refs=out.get("refs", 0),
                     dict_hit_rate=out.get("dict_hit_rate", 0.0))
            if out.get("pool_overflow"):
                hub.emit("pool_overflow", now, total=out["pool_overflow"])
            if out.get("degraded"):
                hub.emit("degraded", now, archived=out.get("archived", 0))
            if committed:
                # table pressure -> Algorithm-2 controller (back-pressure)
                pm.observe_pressure(out.get("pressure", 0.0),
                                    out.get("dropped", 0))
                if "dict_hit_rate" in out:
                    # compressibility -> the controller's "data content"
                    # input (dictionary compression, repro.compress)
                    pm.observe_compression(out["dict_hit_rate"], cr)
            pm.observe_mu(mu)
            if aud is not None:
                # predicted-vs-realized for the audit trail
                aud.resolve(mu, float(et.size()))
            pm.observe_bucket(rho, float(et.density()), float(et.size()))
            pm.observe_mu_outcome(state["last_mu"], state["last_beta_e"], mu)
            state["last_beta_e"], state["last_mu"] = float(et.size()), mu
            state["instr"] += n_instr
            state["raw"] += raw_i
            state["crs"].append(cr)
            hub.emit("push", now, records=len(batch))
            hub.record(PerfSample(now, mu, rho, float(et.density()),
                                  len(buf), float(et.size()),
                                  *pm.velocity(), dec.action,
                                  buf.spill_depth, cr, consumer.delay_s))
    elif dec.action == "throttle":
        # spill the whole buffer to disk (data throttling)
        if len(buf):
            with tel.span("spill.flush"):
                buf.spill_all()
            hub.emit("spill", now, depth=buf.spill_depth)
        mu = consumer.consume(0, cdt, now=now)
        pm.observe_mu(mu)
        if aud is not None:
            aud.resolve(mu, 0.0)
        hub.emit("throttle", now)
        hub.record(PerfSample(now, mu, 0.0, 0.0, 0,
                              dec.beta_e, *pm.velocity(),
                              "throttle", buf.spill_depth, 1.0,
                              consumer.delay_s))
    else:  # hold
        mu = consumer.consume(0, cdt, now=now)
        pm.observe_mu(mu)
        if aud is not None:
            aud.resolve(mu, 0.0)
        hub.emit("hold", now, buffered=len(buf))
        hub.record(PerfSample(now, mu, 0.0, 0.0, len(buf),
                              dec.beta_e, *pm.velocity(),
                              "hold", buf.spill_depth, 1.0,
                              consumer.delay_s))

    # archived batches replay on every action (the connection may be
    # back while the controller holds/throttles) — policy-gated, above
    maybe_retry_archive(sink, hub, now)


class StreamPipeline:
    def __init__(
        self,
        cfg: Optional[IngestConfig] = None,
        source: Optional[Source] = None,
        filter_stage: Optional[FilterStage] = None,
        transform: Optional[TransformStage] = None,
        buffer_stage: Optional[BufferControlStage] = None,
        consumer=None,
        sink=None,
        uncontrolled: bool = False,
        metrics: Optional[MetricsHub] = None,
        spill_dir: str = "/tmp/repro_spill",
        stages: Sequence = (),
    ):
        self.cfg = cfg or IngestConfig()
        self.source = source
        self.filter_stage = filter_stage or FilterStage()
        self.stages = list(stages)  # extra Stage-protocol record stages
        self.transform = transform or TransformStage(
            max_edges_per_batch=self.cfg.max_edges_per_batch)
        # explicit None check: an empty BufferControlStage is falsy
        # (__len__ == 0), so `or` would silently discard the caller's
        # stage — and with it the builder's controller and spill_dir
        self.buffer_stage = BufferControlStage(
            cfg=self.cfg, spill_dir=spill_dir) if buffer_stage is None \
            else buffer_stage
        self.consumer = consumer or SimulatedConsumer()
        self.sink = sink or GraphStoreSink(
            node_cap=self.cfg.store_nodes, edge_cap=self.cfg.store_edges)
        self.uncontrolled = uncontrolled
        self.metrics = metrics or MetricsHub()
        self.telemetry = self.metrics.telemetry
        # cross-tick loop scalars; owned by the pipeline (not run()) so
        # checkpoint/resume (repro.resilience) can capture and restore
        # them — a resumed run continues the totals, not restarts them
        self.loop_state: Optional[dict] = None

    # ---- convenience accessors ----
    @property
    def controller(self):
        return self.buffer_stage.controller

    @property
    def buffer(self):
        return self.buffer_stage.buffer

    @property
    def store(self):
        return self.sink.store

    @property
    def system_delay_s(self) -> float:
        """alpha (Eq. 3): seconds of work queued at the consumer."""
        return self.consumer.delay_s

    # ------------------------------------------------------------------
    def _transform_and_commit(self, records, now: float, dt: float):
        lineage = getattr(self.metrics, "lineage", None)
        tag = handed = None
        if lineage is not None:
            tag = lineage.open_batch(
                records, now, spilled=self.buffer_stage.last_take_spilled)
        et, n_instr, raw_instr = self.transform.encode(records)
        if tag is not None:
            handed = lineage.stage_commit(tag, self.sink)
        out = self.sink.commit(et, now=now)
        if tag is not None:
            lineage.after_commit(tag, out, now, handed=handed)
        mu = self.consumer.consume(n_instr, dt, now=now)
        committed = out.get("committed", False)
        rho = out.get("rho", 1.0) if committed else 1.0
        cr = float(et.compression_ratio())
        self.metrics.emit("commit" if committed else "commit-failed", now,
                          instructions=n_instr, raw=raw_instr, rho=rho, cr=cr,
                          dropped=out.get("dropped", 0),
                          probe_rounds=out.get("probe_rounds", 0),
                          pressure=out.get("pressure", 0.0),
                          refs=out.get("refs", 0),
                          dict_hit_rate=out.get("dict_hit_rate", 0.0))
        return et, mu, rho, cr, n_instr, raw_instr

    # ------------------------------------------------------------------
    def run(self, source_ticks: Optional[Iterable] = None,
            max_ticks: int = 300) -> PipelineReport:
        if source_ticks is None:
            if self.source is None:
                raise ValueError("no source: pass source_ticks or set source")
            source_ticks = self.source.ticks()
        buf = self.buffer_stage
        pm = buf.perfmon
        hub = self.metrics
        t_start = time.time()
        state = self.loop_state
        if state is None:
            state = {"last_beta_e": self.cfg.beta_init, "last_mu": 0.0,
                     "records": 0, "instr": 0, "raw": 0, "crs": []}
            self.loop_state = state

        tel = self.telemetry
        for i, tick in enumerate(source_ticks):
            if i >= max_ticks:
                break
            now, dt = tick.t, 1.0
            ctx = TickContext(t=now, dt=dt, index=i)
            with tel.span("tick"):
                # ---- 1. filter (+ any extra record stages) ----
                with tel.span("filter"):
                    recs = self.filter_stage(tick.records, ctx)
                for stage in self.stages:
                    recs = stage(recs, ctx)
                state["records"] += len(recs)
                pm.observe_rate(now, len(recs))
                hub.emit("tick", now, raw=len(tick.records), kept=len(recs))
                # ---- 2. buffer ----
                buf.extend(recs)

                if self.uncontrolled:
                    # paper Figs. 1-3/7: push every tick, no control
                    if len(buf):
                        batch = buf.take_all()
                        et, mu, rho, cr, ni, ri = self._transform_and_commit(
                            batch, now, dt)
                        pm.observe_mu(mu)
                        state["instr"] += ni
                        state["raw"] += ri
                        state["crs"].append(cr)
                        hub.emit("push", now, records=len(batch))
                        hub.record(PerfSample(now, mu, rho,
                                              float(et.density()),
                                              len(buf), float(et.size()),
                                              *pm.velocity(), "push",
                                              buf.spill_depth, cr,
                                              self.consumer.delay_s))
                    maybe_retry_archive(self.sink, hub, now)
                    continue

                # ---- 3-7. controlled path ----
                controlled_tick(buf, self.transform, self.sink,
                                self.consumer, hub, state, now, dt)

        return hub.build_report(state["records"], state["instr"],
                                state["raw"], state["crs"],
                                time.time() - t_start)

    # ---- checkpoint surface (repro.resilience) -----------------------
    def state(self) -> dict:
        """Host-side resumable state: everything the checkpointer's
        array manifest does not cover (see resilience/checkpoint.py)."""
        s: dict = {
            "loop": None if self.loop_state is None else
                {**self.loop_state, "crs": list(self.loop_state["crs"])},
            "buffer": self.buffer_stage.state(),
            "metrics": self.metrics.state(),
            "stages": [st.state() if hasattr(st, "state") else None
                       for st in self.stages],
        }
        if hasattr(self.consumer, "state"):
            s["consumer"] = self.consumer.state()
        if hasattr(self.sink, "state"):
            s["sink"] = self.sink.state()
        tracker = getattr(self.metrics, "lineage", None)
        if tracker is not None:
            s["lineage"] = tracker.state()
        return s

    def restore_state(self, s: dict) -> None:
        self.loop_state = None if s["loop"] is None else dict(s["loop"])
        self.buffer_stage.restore_state(s["buffer"])
        self.metrics.restore_state(s["metrics"])
        for st, st_s in zip(self.stages, s["stages"]):
            if st_s is not None and hasattr(st, "restore_state"):
                st.restore_state(st_s)
        if "consumer" in s and hasattr(self.consumer, "restore_state"):
            self.consumer.restore_state(s["consumer"])
        if "sink" in s and hasattr(self.sink, "restore_state"):
            self.sink.restore_state(s["sink"])
        tracker = getattr(self.metrics, "lineage", None)
        if tracker is not None and "lineage" in s:
            tracker.restore_state(s["lineage"])
