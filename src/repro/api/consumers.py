"""Consumer implementations (the store-engine load model).

`SimulatedConsumer` is the queued-consumer model extracted from the
original `IngestionPipeline._consume_mu`: a finite-capacity engine
with a commit queue.  Sustained over-delivery pins mu at 1.0 (the
Fig. 2 meltdown) and builds backlog — exactly the system-delay term
alpha of Eq. 3.

`MeasuredConsumer` is the measured path: mu is the busy-fraction of
the real compiled ingest step over the trailing occupancy window
(`GraphIngestor.occupancy`), the TPU-native stand-in for the paper's
Zabbix CPU-user-time (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional

from repro.core.ingestor import GraphIngestor


class SimulatedConsumer:
    """Queued consumer: capacity `base_capacity * speed` instructions/s
    at mu=1, short Zabbix-style smoothing window on the occupancy."""

    def __init__(self, speed: float = 1.0, base_capacity: float = 3_000.0):
        self.speed = speed
        self.capacity = base_capacity * speed  # instructions/s at mu=1
        self._backlog = 0.0
        self._mu = 0.0

    def consume(self, instructions: int, dt: float, now: Optional[float] = None) -> float:
        self._backlog += instructions
        can = self.capacity * dt
        done = min(self._backlog, can)
        self._backlog -= done
        inst_mu = done / can
        self._mu = 0.5 * self._mu + 0.5 * inst_mu
        return min(self._mu, 1.0)

    @property
    def delay_s(self) -> float:
        """alpha (Eq. 3): seconds of work queued at the consumer."""
        return self._backlog / self.capacity

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> dict:
        return {"backlog": self._backlog, "mu": self._mu}

    def restore_state(self, s: dict) -> None:
        self._backlog = float(s["backlog"])
        self._mu = float(s["mu"])


class MeasuredConsumer:
    """Occupancy measured from real commits on a `GraphIngestor`."""

    def __init__(self, ingestor: GraphIngestor):
        self.ingestor = ingestor

    def consume(self, instructions: int, dt: float, now: Optional[float] = None) -> float:
        import time

        return self.ingestor.occupancy(now if now is not None else time.time())

    @property
    def delay_s(self) -> float:
        return self.ingestor.pending_work_s()
