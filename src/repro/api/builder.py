"""`PipelineBuilder` — the fluent facade over the composable API.

    pipe = (PipelineBuilder(IngestConfig(cpu_max=0.55))
            .with_source(BurstyTweetSource(seed=0))
            .with_keywords(["memo"])
            .simulated_consumer(speed=0.5)
            .spill_dir("/tmp/my_spill")
            .build())
    report = pipe.run(max_ticks=300)

`sharded(n)` switches `build()` to a `ShardedPipeline`; every part
not set explicitly gets the paper default.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Sequence, Union

from repro.api.consumers import MeasuredConsumer, SimulatedConsumer
from repro.api.metrics import MetricsHub, PipelineEvent
from repro.api.pipeline import StreamPipeline
from repro.api.sharded import ShardedPipeline
from repro.api.sinks import GraphStoreSink
from repro.api.stages import BufferControlStage, FilterStage, TransformStage
from repro.configs.paper_ingest import IngestConfig
from repro.core.buffer import BufferController
from repro.core.transform import MappingSpec

# placeholder in the stage list for a build-time-constructed SketchStage
_SKETCH_SLOT = object()
# placeholder for a build-time-constructed DictionaryStage (repro.compress)
_DICT_SLOT = object()


class PipelineBuilder:
    def __init__(self, cfg: Optional[IngestConfig] = None):
        self.cfg = cfg or IngestConfig()
        self._source = None
        self._filter: Optional[FilterStage] = None
        self._keywords: Sequence[str] = ()
        self._mapping: Optional[MappingSpec] = None
        self._transform: Optional[TransformStage] = None
        self._compress = True
        self._uncontrolled = False
        self._consumer = None
        self._sink = None
        self._controller: Optional[BufferController] = None
        self._spill_dir = "/tmp/repro_spill"
        self._n_shards = 1
        self._shard_key: Optional[Callable[[dict], str]] = None
        self._metrics: Optional[MetricsHub] = None
        self._hooks = []
        self._stages = []
        self._sketch_stage = None
        self._sketch_kw = {}
        self._query_sink_opts = None
        self._sketch_guided = False
        self._dict_stage = None
        self._compression_kw = None
        self._telemetry = None
        self._monitor = None
        self._monitor_kw = None
        self._lineage = None
        self._lineage_kw = None
        self._fault_plan = None
        self._fault_injector = None
        self._retry = None

    # ---- parts ----
    def with_source(self, source) -> "PipelineBuilder":
        self._source = source
        return self

    def with_filter(self, stage: FilterStage) -> "PipelineBuilder":
        self._filter = stage
        return self

    def with_keywords(self, keywords: Iterable[str]) -> "PipelineBuilder":
        self._keywords = list(keywords)
        return self

    def with_mapping(self, mapping: MappingSpec) -> "PipelineBuilder":
        self._mapping = mapping
        return self

    def with_transform(self, transform: TransformStage) -> "PipelineBuilder":
        self._transform = transform
        return self

    def with_stage(self, stage) -> "PipelineBuilder":
        """Append an extra Stage-protocol record stage (runs after the
        filter, before the buffer), e.g. a `repro.query.SketchStage`."""
        self._stages.append(stage)
        return self

    def with_sketch(self, sketch_stage=None, **kw) -> "PipelineBuilder":
        """Maintain an ingestion-time graph sketch (repro.query): adds
        a `SketchStage` after the filter.  When no stage is passed,
        one is created at build time inheriting the builder's mapping
        and the config's max_edges_per_batch (so the sketch observes
        exactly the edges the transform commits); retrieve it via
        `.sketch_stage` after build(), or keep the reference you pass."""
        self._sketch_stage = sketch_stage
        self._sketch_kw = dict(kw)
        self._stages.append(_SKETCH_SLOT)
        return self

    @property
    def sketch_stage(self):
        """The `SketchStage` added by `with_sketch` (after build())."""
        return self._sketch_stage

    def with_query_sink(self, **kw) -> "PipelineBuilder":
        """Wrap the sink in a `repro.query.QuerySink` at build time:
        commit-consistent sketch + live "sketch" MetricsHub events.
        Keyword args are forwarded to `QuerySink` (depth, width,
        answer_every, top_k, ...)."""
        self._query_sink_opts = dict(kw)
        return self

    def sketch_guided(self, flag: bool = True) -> "PipelineBuilder":
        """Sketch-guided control (ROADMAP): feed the QuerySink's live
        heavy-hitter/diversity signal back into each Algorithm-2
        controller via the MetricsHub "sketch" events.  Implies
        `with_query_sink()` when one wasn't configured."""
        self._sketch_guided = flag
        return self

    def with_compression(self, stage=None, **kw) -> "PipelineBuilder":
        """Ingestion-time dictionary compression (repro.compress, the
        paper's GraphZip layer): mines star/cascade patterns per bucket,
        rewrites recurring edges into `(pattern_id, bindings)` references
        against a device-resident dictionary, and commits them through
        the pattern-aware GRAPHPUSH path (`commit_compressed`).  When no
        stage is passed one is created at build time from the keyword
        args (capacity, star_min, hot_min, ttl, use_kernel); retrieve it
        via `.dictionary_stage` after build()."""
        self._dict_stage = stage
        self._compression_kw = dict(kw)
        self._stages.append(_DICT_SLOT)
        return self

    @property
    def dictionary_stage(self):
        """The `DictionaryStage` added by `with_compression` (after build())."""
        return self._dict_stage

    def with_consumer(self, consumer) -> "PipelineBuilder":
        self._consumer = consumer
        return self

    def simulated_consumer(self, speed: float = 1.0) -> "PipelineBuilder":
        self._consumer = SimulatedConsumer(speed=speed)
        return self

    def measured_consumer(self) -> "PipelineBuilder":
        """Use the real commit busy-fraction as mu (set at build time,
        once the sink's ingestor exists)."""
        self._consumer = "measured"
        return self

    def with_sink(self, sink) -> "PipelineBuilder":
        self._sink = sink
        return self

    def with_controller(self, controller: BufferController) -> "PipelineBuilder":
        self._controller = controller
        return self

    # ---- behaviour knobs ----
    def uncontrolled(self, flag: bool = True) -> "PipelineBuilder":
        self._uncontrolled = flag
        return self

    def compressed(self, flag: bool = True) -> "PipelineBuilder":
        self._compress = flag
        return self

    def spill_dir(self, path: str) -> "PipelineBuilder":
        self._spill_dir = path
        return self

    def sharded(self, n_shards: int,
                shard_key: Optional[Callable[[dict], str]] = None) -> "PipelineBuilder":
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self._n_shards = n_shards
        self._shard_key = shard_key
        return self

    def with_metrics(self, hub: MetricsHub) -> "PipelineBuilder":
        self._metrics = hub
        return self

    def with_telemetry(self, registry=None) -> "PipelineBuilder":
        """Span telemetry + controller audit trail (repro.telemetry):
        threads one `TelemetryRegistry` through every layer — the
        MetricsHub (event counters + loop spans), the transform
        (map/dedup), the sink's ingestor (commit.upsert/wait/hooks),
        the sketch/dictionary stages, the snapshot maintainer, and an
        `AuditTrail` per controller (per-shard).  Pass a registry to
        share one across pipelines, or nothing to create one; read it
        back via `pipe.telemetry` / `pipe.metrics.telemetry`."""
        from repro.telemetry import TelemetryRegistry

        if registry is None or registry is True:
            registry = TelemetryRegistry()
        self._telemetry = registry
        return self

    def with_monitor(self, monitor=None, **kw) -> "PipelineBuilder":
        """Online health monitoring (repro.monitor): subscribe a
        `HealthMonitor` to the pipeline's MetricsHub and tap the
        telemetry registry for per-tick series — streaming anomaly
        detection (EWMA + Page–Hinkley `HealthEvent`s), SLO error
        budgets with burn-rate alerts, and controller decision-quality
        scoring.  Implies `with_telemetry()` (the monitor needs the
        span histograms and the audit trail).  Pass a configured
        monitor, or keyword args forwarded to `HealthMonitor` (series,
        slos, cpu_max, on_tick); read it back via `.health_monitor`
        (also set as `pipe.monitor` / `hub.monitor` after build)."""
        self._monitor = monitor
        self._monitor_kw = dict(kw)
        if self._telemetry is None:
            self.with_telemetry()
        return self

    @property
    def health_monitor(self):
        """The `HealthMonitor` wired by `with_monitor` (after build())."""
        return self._monitor

    def with_lineage(self, tracker=None, **kw) -> "PipelineBuilder":
        """Batch provenance + event-time watermarks (repro.lineage):
        tag every batch at the buffer with a monotone id + event-time
        envelope, follow it through spill/pool/archive to the
        queryable snapshot, and maintain the committed/queryable
        watermark pair plus per-path freshness histograms.  Pass a
        configured `LineageTracker`, or keyword args forwarded to it
        (sample_rate, dt, buffered_slack, ...); read it back via
        `.lineage_tracker` (also set as `pipe.lineage` /
        `hub.lineage` after build)."""
        self._lineage = tracker if tracker is not None \
            and tracker is not True else None
        self._lineage_kw = dict(kw)
        return self

    @property
    def lineage_tracker(self):
        """The `LineageTracker` wired by `with_lineage` (after build())."""
        return self._lineage

    def on_event(self, hook: Callable[[PipelineEvent], None]) -> "PipelineBuilder":
        self._hooks.append(hook)
        return self

    # ---- resilience (repro.resilience) ----
    def with_faults(self, plan) -> "PipelineBuilder":
        """Counter-deterministic fault injection: wire a `FaultPlan`
        (or a ready `FaultInjector`) as the sink ingestor's `fail_hook`
        at build time.  Read the injector back via `.fault_injector`
        (e.g. to inspect the attempt counter after a run)."""
        self._fault_plan = plan
        return self

    @property
    def fault_injector(self):
        """The `FaultInjector` wired by `with_faults` (after build())."""
        return self._fault_injector

    def with_retry(self, policy=None, *, max_archive: Optional[int] = None,
                   pool_cap: Optional[int] = None,
                   archive_dir: Optional[str] = None,
                   degrade_after: Optional[int] = None) -> "PipelineBuilder":
        """Backoff-governed commit retry: attach a `RetryPolicy`
        (default-constructed when none is given) to the sink's
        ingestor at build time.  This arms the per-tick auto-retry in
        the loop, the exponential-backoff gate, the degraded push mode,
        and — via the keyword overrides — the bounded archive
        (`max_archive` in-memory batches, disk spill beyond) and the
        pool hard cap."""
        from repro.resilience import RetryPolicy

        self._retry = (policy if policy is not None else RetryPolicy(), {
            "max_archive": max_archive, "pool_cap": pool_cap,
            "archive_dir": archive_dir, "degrade_after": degrade_after,
        })
        return self

    # ---- assembly ----
    def _resolve_stages(self):
        """Materialise the sketch slot with the builder's mapping/cap."""
        stages = []
        for st in self._stages:
            if st is _SKETCH_SLOT:
                if self._sketch_stage is None:
                    from repro.query.stage import SketchStage

                    kw = dict(self._sketch_kw)
                    kw.setdefault("mapping", self._mapping)
                    kw.setdefault("max_edges_per_batch",
                                  self.cfg.max_edges_per_batch)
                    self._sketch_stage = SketchStage(**kw)
                stages.append(self._sketch_stage)
            elif st is _DICT_SLOT:
                # materialised by build() before the pipeline exists
                if self._dict_stage is not None:
                    stages.append(self._dict_stage)
            else:
                stages.append(st)
        return stages

    def build(self) -> Union[StreamPipeline, ShardedPipeline]:
        filt = self._filter or FilterStage(self._keywords)
        transform = self._transform or TransformStage(
            mapping=self._mapping,
            max_edges_per_batch=self.cfg.max_edges_per_batch,
            compress=self._compress,
        )
        sink = self._sink or GraphStoreSink(
            node_cap=self.cfg.store_nodes, edge_cap=self.cfg.store_edges)
        consumer = self._consumer
        if consumer == "measured":
            if not isinstance(sink, GraphStoreSink):
                raise ValueError("measured_consumer() needs a GraphStoreSink")
            consumer = MeasuredConsumer(sink.ingestor)
        elif consumer is None:
            consumer = SimulatedConsumer()
        metrics = self._metrics or MetricsHub(telemetry=self._telemetry)
        if self._metrics is not None and self._telemetry is not None:
            metrics.telemetry = self._telemetry
        for h in self._hooks:
            metrics.subscribe(h)
        qs_opts = self._query_sink_opts
        if self._sketch_guided and qs_opts is None:
            qs_opts = {}  # sketch events need a QuerySink (build-local:
            # turning sketch_guided off again must not leave one behind)
        if qs_opts is not None:
            from repro.query.stage import QuerySink

            sink = QuerySink(sink, hub=metrics, **qs_opts)
        if self._compression_kw is not None:
            from repro.compress import CompressingTransform, DictionaryStage

            if self._dict_stage is None:
                self._dict_stage = DictionaryStage(**self._compression_kw)
            # rewrite happens in the transform (after Algorithm-1 encode);
            # the dictionary learns from SUCCESSFUL commits only, via the
            # ingestor's commit-hook fan-out (pooled/retried batches must
            # still admit their patterns exactly once).  `.ingestor`
            # passes through a QuerySink wrap.
            transform = CompressingTransform(transform, self._dict_stage)
            ingestor = getattr(sink, "ingestor", None)
            if ingestor is not None and hasattr(ingestor, "commit_hooks"):
                ingestor.commit_hooks.append(self._dict_stage.observe_commit)
        if self._fault_plan is not None or self._retry is not None:
            ingestor = getattr(sink, "ingestor", None)
            if ingestor is None:
                raise ValueError("with_faults()/with_retry() need a sink "
                                 "with a GraphIngestor underneath")
            if self._fault_plan is not None:
                from repro.resilience import FaultInjector, FaultPlan

                self._fault_injector = (
                    FaultInjector(self._fault_plan)
                    if isinstance(self._fault_plan, FaultPlan)
                    else self._fault_plan)
                ingestor.fail_hook = self._fault_injector
            if self._retry is not None:
                policy, overrides = self._retry
                ingestor.retry_policy = policy
                for name, val in overrides.items():
                    if val is not None:
                        setattr(ingestor, name, val)

        if self._n_shards > 1:
            if self._uncontrolled:
                raise ValueError("sharded pipelines are always controlled")
            if self._controller is not None:
                raise ValueError("with_controller() is single-shard only: "
                                 "each shard builds its own controller")
            pipe = ShardedPipeline(
                cfg=self.cfg,
                n_shards=self._n_shards,
                source=self._source,
                filter_stage=filt,
                transform=transform,
                consumer=consumer,
                sink=sink,
                spill_dir=self._spill_dir,
                shard_key=self._shard_key,
                metrics=metrics,
                stages=self._resolve_stages(),
            )
            controllers = [s.controller for s in pipe.shards]
        else:
            buffer_stage = BufferControlStage(
                controller=self._controller, cfg=self.cfg,
                spill_dir=self._spill_dir)
            pipe = StreamPipeline(
                cfg=self.cfg,
                source=self._source,
                filter_stage=filt,
                transform=transform,
                buffer_stage=buffer_stage,
                consumer=consumer,
                sink=sink,
                uncontrolled=self._uncontrolled,
                metrics=metrics,
                stages=self._resolve_stages(),
            )
            controllers = [buffer_stage.controller]
        if self._sketch_guided:
            # policy hook: live sketch events -> every controller's
            # diversity hint (sketch-guided control, see docs/API.md)
            def _guide(ev, _ctrls=controllers):
                if ev.kind == "sketch":
                    for c in _ctrls:
                        c.observe_sketch(ev.payload)

            metrics.subscribe(_guide)
        if self._telemetry is not None:
            self._wire_telemetry(pipe, transform, sink, controllers)
        if self._monitor is not None or self._monitor_kw is not None:
            from repro.monitor import HealthMonitor

            if self._monitor is None:
                self._monitor = HealthMonitor(**self._monitor_kw)
            self._monitor.bind(metrics, cfg=self.cfg)
            metrics.monitor = self._monitor
            pipe.monitor = self._monitor
        if self._lineage is not None or self._lineage_kw is not None:
            from repro.lineage import LineageTracker

            if self._lineage is None:
                self._lineage = LineageTracker(**(self._lineage_kw or {}))
            tracker = self._lineage
            metrics.lineage = tracker
            pipe.lineage = tracker
            # intake observation at every buffer stage, tag custody at
            # the ingestor, and the per-shard hubs `controlled_tick`
            # actually receives
            if isinstance(pipe, ShardedPipeline):
                for b in pipe.shards:
                    b.lineage = tracker
                for h in pipe._hubs:
                    h.lineage = tracker
            else:
                pipe.buffer_stage.lineage = tracker
            ingestor = getattr(sink, "ingestor", None)
            if ingestor is not None and hasattr(ingestor, "lineage"):
                ingestor.lineage = tracker
            # bind AFTER the monitor so the per-tick "watermark" event
            # lands in the tick row the monitor just opened
            tracker.bind(metrics)
        return pipe

    def _wire_telemetry(self, pipe, transform, sink, controllers):
        """Thread the registry through every instrumented layer."""
        from repro.telemetry import AuditTrail

        reg = self._telemetry
        if hasattr(transform, "telemetry"):
            transform.telemetry = reg  # CompressingTransform forwards
        for st in pipe.stages:  # SketchStage / DictionaryStage / customs
            if hasattr(st, "telemetry"):
                st.telemetry = reg
        # the sink chain: QuerySink wrapper, its maintainer, and the
        # GraphStoreSink's ingestor underneath (commit sub-spans)
        if hasattr(sink, "telemetry"):
            sink.telemetry = reg
        maintainer = getattr(sink, "maintainer", None)
        if maintainer is not None:
            maintainer.telemetry = reg
        ingestor = getattr(sink, "ingestor", None)
        if ingestor is not None and hasattr(ingestor, "telemetry"):
            ingestor.telemetry = reg
        # one audit trail per controller, tagged with its shard
        for si, c in enumerate(controllers):
            c.audit = AuditTrail(reg, shard=si)

    def run(self, max_ticks: int = 300):
        """Build and run in one call (source must be set)."""
        return self.build().run(max_ticks=max_ticks)
