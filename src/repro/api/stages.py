"""Stage implementations: the swappable steps of the seven-step loop.

  FilterStage        — two-stage filtering (§II-A): source-API keyword
                       filter + analysis filter.
  TransformStage     — model transformation (Algorithm 1 CREATEEDGE)
                       plus ingestion-time graph compression; owns the
                       instruction accounting for both paths.
  BufferControlStage — the adaptive buffer + Algorithm 2 controller
                       state (buffer list, spill store, decisions).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.protocols import TickContext
from repro.configs.paper_ingest import IngestConfig
from repro.core.buffer import BufferController, ControllerDecision
from repro.core.edge_table import EdgeTable, from_raw_batch
from repro.core.transform import MappingSpec, create_edges, tweet_mapping
from repro.ingest.filter import analysis_filter, api_keyword_filter, apply_filters


class FilterStage:
    """§II-A two-stage filter as one record stage."""

    name = "filter"

    def __init__(self, keywords: Sequence[str] = (),
                 stage2: Callable[[dict], bool] = analysis_filter):
        self.stage1 = api_keyword_filter(list(keywords))
        self.stage2 = stage2

    def __call__(self, records: List[dict], ctx: Optional[TickContext] = None) -> List[dict]:
        return apply_filters(records, self.stage1, self.stage2)


class TransformStage:
    """Records -> compressed device edge table + instruction counts.

    `compress=False` keeps the compressed table for the store (the
    store only speaks edge tables) but accounts the ingestion load at
    the raw instruction stream — the paper's uncompressed baseline.
    """

    name = "transform"

    def __init__(self, mapping: Optional[MappingSpec] = None,
                 max_edges_per_batch: int = 8_192, compress: bool = True,
                 telemetry=None):
        from repro.telemetry.spans import NULL_REGISTRY

        self.mapping = mapping or tweet_mapping()
        self.max_edges_per_batch = max_edges_per_batch
        self.compress = compress
        self.telemetry = telemetry or NULL_REGISTRY

    def encode(self, records: List[dict]) -> Tuple[EdgeTable, int, int]:
        tel = self.telemetry
        with tel.span("transform.map"):
            raw = create_edges(records, self.mapping)
        cap = max(64, 1 << int(np.ceil(np.log2(max(raw.n_edges, 1)))))
        cap = min(cap, self.max_edges_per_batch)
        with tel.span("transform.dedup"):
            et = from_raw_batch(raw, cap)
        raw_instr = 3 * raw.n_edges
        if not self.compress:
            # uncompressed baseline: ingestion load = raw instructions
            n_instr = raw_instr
        else:
            n_instr = int(et.n_nodes) + int(et.n_edges)
        return et, n_instr, raw_instr


class BufferControlStage:
    """The adaptive buffer (Algorithm 2) as a pipeline stage: owns the
    in-memory record buffer, the spill store, and the controller."""

    name = "buffer"

    def __init__(self, controller: Optional[BufferController] = None,
                 cfg: Optional[IngestConfig] = None,
                 spill_dir: str = "/tmp/repro_spill"):
        self.controller = controller or BufferController(cfg or IngestConfig(),
                                                         spill_dir=spill_dir)
        self.buffer: List[dict] = []
        self.max_buffered = 0  # high-water mark (sharding bound checks)
        # provenance (repro.lineage): per-record came-back-from-spill
        # flags parallel to `buffer`, the count of records currently
        # detoured to disk, and whether the last take touched spill
        self._spill_flags: List[bool] = []
        self.spilled_records = 0
        self.last_take_spilled = False
        self.lineage = None  # LineageTracker (set by builder wiring)

    # ---- buffer plumbing ----
    def extend(self, records: List[dict]):
        if self.lineage is not None:
            self.lineage.observe_intake(records)
        self.buffer.extend(records)
        self._spill_flags.extend([False] * len(records))
        self.max_buffered = max(self.max_buffered, len(self.buffer))

    def take_batch(self) -> List[dict]:
        """Pop up to beta records (the controller's current bucket)."""
        batch = self.buffer[: self.controller.beta]
        self.buffer = self.buffer[self.controller.beta :]
        taken = self._spill_flags[: len(batch)]
        self._spill_flags = self._spill_flags[len(batch):]
        self.last_take_spilled = any(taken)
        return batch

    def take_all(self) -> List[dict]:
        batch, self.buffer = self.buffer, []
        self.last_take_spilled = any(self._spill_flags)
        self._spill_flags = []
        return batch

    def spill_all(self) -> int:
        """Data throttling: flush the whole buffer to disk."""
        n = len(self.buffer)
        if self.buffer:
            self.controller.spill.flush(self.buffer)
            self.buffer = []
            self._spill_flags = []
            self.spilled_records += n
        return n

    def drain_spill(self):
        """Step 6: reload spilled data into the buffer."""
        drained = self.controller.spill.drain()
        self.spilled_records = max(0, self.spilled_records - len(drained))
        self.buffer.extend(drained)
        self._spill_flags.extend([True] * len(drained))
        self.max_buffered = max(self.max_buffered, len(self.buffer))

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> dict:
        return {
            "buffer": list(self.buffer),
            "max_buffered": self.max_buffered,
            "controller": self.controller.state(),
            "spill_flags": list(self._spill_flags),
            "spilled_records": self.spilled_records,
        }

    def restore_state(self, s: dict) -> None:
        self.buffer = list(s["buffer"])
        self.max_buffered = int(s["max_buffered"])
        self.controller.restore_state(s["controller"])
        # .get: checkpoints written before lineage landed lack these
        self._spill_flags = list(s.get("spill_flags",
                                       [False] * len(self.buffer)))
        self.spilled_records = int(s.get("spilled_records", 0))

    # ---- controller passthrough ----
    def decide(self, size_est: float, density: float,
               now: Optional[float] = None) -> ControllerDecision:
        return self.controller.decide(size_est, density, now=now)

    @property
    def perfmon(self):
        return self.controller.perfmon

    @property
    def spill_depth(self) -> int:
        return self.controller.spill.depth

    def __len__(self) -> int:
        return len(self.buffer)
