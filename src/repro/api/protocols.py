"""The four ingestion protocols (structural typing, no registration).

Any object matching the shape plugs in: the pipeline never isinstance-
checks beyond these `runtime_checkable` protocols, so third-party
sources/stages/consumers/sinks need no base class — mirror of how
GraphTango hides its hybrid representation behind one update API.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Protocol, Tuple, runtime_checkable

from repro.ingest.sources import StreamTick


@dataclasses.dataclass
class TickContext:
    """Per-tick state handed to stages (time base + loop position)."""

    t: float  # stream time of this tick
    dt: float  # tick duration (s)
    index: int  # tick number within the run


@runtime_checkable
class Source(Protocol):
    """A stream of `StreamTick`s.  `BurstyTweetSource` and
    `FileReplaySource` already satisfy this contract."""

    def ticks(self) -> Iterator[StreamTick]: ...


@runtime_checkable
class Stage(Protocol):
    """A per-tick record processor (filter/enrich/split).  Stages are
    pure record -> record; heavier roles get their own protocols."""

    name: str

    def __call__(self, records: List[dict], ctx: Optional[TickContext] = None) -> List[dict]: ...


@runtime_checkable
class Transform(Protocol):
    """Model transformation + graph compression: records -> device
    edge table plus the two instruction counters the controller and
    the report need (compressed, raw)."""

    name: str

    def encode(self, records: List[dict]) -> Tuple[object, int, int]: ...


@runtime_checkable
class Consumer(Protocol):
    """Load model of the store engine.  `consume` absorbs a commit of
    `instructions` over `dt` seconds and returns the occupancy mu in
    [0,1]; `delay_s` is the system-delay alpha (Eq. 3)."""

    def consume(self, instructions: int, dt: float, now: Optional[float] = None) -> float: ...

    @property
    def delay_s(self) -> float: ...


@runtime_checkable
class Sink(Protocol):
    """Commit target (Algorithm 3 GRAPHPUSH or any store binding).
    Returns the commit stats dict: at minimum `committed`, plus `rho`
    (bucket diversity) when the commit landed."""

    def commit(self, et, now: Optional[float] = None) -> Dict: ...
