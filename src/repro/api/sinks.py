"""Sink implementations (graph-store commit targets).

`GraphStoreSink` binds the pipeline to the device-resident property
graph through `GraphIngestor` (Algorithm 3 GRAPHPUSH: bounded pool,
archive-and-retry on commit failure).  Any object with the same
`commit()` shape — a Neo4j driver, a file writer, a no-op counter —
drops in unchanged.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.ingestor import GraphIngestor
from repro.graphstore.store import GraphStore, init_store


class GraphStoreSink:
    """GRAPHPUSH into the JAX hash-table store via the ingestion pool."""

    def __init__(self, ingestor: Optional[GraphIngestor] = None,
                 store: Optional[GraphStore] = None,
                 node_cap: int = 1 << 20, edge_cap: int = 1 << 21,
                 max_pool_size: int = 4, fail_hook=None,
                 occupancy_window: float = 8.0):
        if ingestor is None:
            store = store if store is not None else init_store(node_cap, edge_cap)
            ingestor = GraphIngestor(store, max_pool_size=max_pool_size,
                                     fail_hook=fail_hook,
                                     occupancy_window=occupancy_window)
        self.ingestor = ingestor

    def commit(self, et, now: Optional[float] = None) -> Dict:
        return self.ingestor.push(et, now=now)

    def retry_archive(self, now: Optional[float] = None) -> int:
        return self.ingestor.retry_archive(now)

    @property
    def store(self) -> GraphStore:
        return self.ingestor.store

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> Dict:
        return {"ingestor": self.ingestor.state()}

    def restore_state(self, s: Dict) -> None:
        self.ingestor.restore_state(s["ingestor"])
