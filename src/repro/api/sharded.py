"""`ShardedPipeline` — the first scale-out scenario.

Hash-partitions the filtered record stream by user across N shards,
each with its own adaptive buffer + Algorithm 2 controller (own spill
store, own PerfMon), all feeding one shared Sink/Consumer — the
paper's bounded DBMS ingestion pool fronted by parallel collectors.
Because the consumer is shared, every shard's controller observes the
*aggregate* occupancy mu and they collectively back off under load:
the control law needs no modification to go multi-collector.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Callable, Iterable, List, Optional, Sequence

import numpy as np

from repro.api.consumers import SimulatedConsumer
from repro.api.metrics import MetricsHub, PipelineEvent, PipelineReport
from repro.api.pipeline import controlled_tick
from repro.api.protocols import Source, TickContext
from repro.api.sinks import GraphStoreSink
from repro.api.stages import BufferControlStage, FilterStage, TransformStage
from repro.configs.paper_ingest import IngestConfig


def default_shard_key(rec: dict) -> str:
    """Partition by user (graph locality: a user's edges co-locate)."""
    return str(rec.get("user") or rec.get("author") or rec.get("id") or "")


@dataclasses.dataclass
class ShardedReport:
    shards: List[PipelineReport]
    total_records: int
    total_instructions: int
    raw_instructions: int
    max_buffered: List[int]  # per-shard buffer high-water mark
    spill_events: int
    drain_events: int
    wall_s: float

    @property
    def mean_compression(self) -> float:
        crs = np.concatenate([r.compression_ratios for r in self.shards]) \
            if self.shards else np.asarray([])
        return float(crs.mean()) if crs.size else 1.0

    def mu_arrays(self) -> List[np.ndarray]:
        return [r.samples["mu"] for r in self.shards]


class ShardedPipeline:
    def __init__(
        self,
        cfg: Optional[IngestConfig] = None,
        n_shards: int = 2,
        source: Optional[Source] = None,
        filter_stage: Optional[FilterStage] = None,
        transform: Optional[TransformStage] = None,
        consumer=None,
        sink=None,
        spill_dir: str = "/tmp/repro_spill_shard",
        shard_key: Optional[Callable[[dict], str]] = None,
        metrics: Optional[MetricsHub] = None,
        stages: Sequence = (),
    ):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.cfg = cfg or IngestConfig()
        self.n_shards = n_shards
        self.source = source
        self.filter_stage = filter_stage or FilterStage()
        self.stages = list(stages)  # extra Stage-protocol record stages
        self.transform = transform or TransformStage(
            max_edges_per_batch=self.cfg.max_edges_per_batch)
        self.consumer = consumer or SimulatedConsumer()
        self.sink = sink or GraphStoreSink(
            node_cap=self.cfg.store_nodes, edge_cap=self.cfg.store_edges)
        self.shard_key = shard_key or default_shard_key
        self.metrics = metrics or MetricsHub()
        self.telemetry = self.metrics.telemetry
        self.shards = [
            BufferControlStage(cfg=self.cfg, spill_dir=f"{spill_dir}/shard{i}")
            for i in range(n_shards)
        ]
        # per-shard hubs: own counters (ShardedReport sums them), but
        # spans land in the aggregate registry tagged with the shard
        self._hubs = [MetricsHub(telemetry=self.telemetry.child(i))
                      for i in range(n_shards)]
        # forward every shard event to the caller's hub, tagged with the
        # shard index, so on_event() subscribers see the whole fleet
        for si, hub in enumerate(self._hubs):
            hub.subscribe(lambda ev, si=si: self._forward(ev, si))
        # per-shard cross-tick loop scalars; pipeline-owned so
        # checkpoint/resume (repro.resilience) can capture/restore them
        self.loop_states: Optional[List[dict]] = None

    def _forward(self, ev: PipelineEvent, shard: int):
        # route through emit (not the hooks directly) so the aggregate
        # hub's counters see shard-level spill/drain/commit events too;
        # subscribers keep receiving the shard-tagged payload
        self.metrics.emit(ev.kind, ev.t, **{**ev.payload, "shard": shard})

    @property
    def store(self):
        return self.sink.store

    def _partition(self, records: List[dict]) -> List[List[dict]]:
        parts: List[List[dict]] = [[] for _ in range(self.n_shards)]
        for r in records:
            h = zlib.crc32(self.shard_key(r).encode("utf-8"))
            parts[h % self.n_shards].append(r)
        return parts

    # ------------------------------------------------------------------
    def _shard_step(self, si: int, part: List[dict], now: float, dt: float,
                    state: dict):
        """One controlled tick on shard `si`: the exact single-shard
        loop body (`controlled_tick`), with this shard's slice of the
        shared consumer's capacity (dt/N, so N shards together drain
        one consumer-tick, not N)."""
        buf = self.shards[si]
        buf.perfmon.observe_rate(now, len(part))
        state["records"] += len(part)
        buf.extend(part)
        with self.telemetry.span("shard.tick", shard=si):
            controlled_tick(buf, self.transform, self.sink, self.consumer,
                            self._hubs[si], state, now, dt,
                            consume_dt=dt / self.n_shards)

    # ------------------------------------------------------------------
    def run(self, source_ticks: Optional[Iterable] = None,
            max_ticks: int = 300) -> ShardedReport:
        if source_ticks is None:
            if self.source is None:
                raise ValueError("no source: pass source_ticks or set source")
            source_ticks = self.source.ticks()
        t_start = time.time()
        states = self.loop_states
        if states is None:
            states = [
                {"last_beta_e": self.cfg.beta_init, "last_mu": 0.0,
                 "records": 0, "instr": 0, "raw": 0, "crs": []}
                for _ in range(self.n_shards)
            ]
            self.loop_states = states
        tel = self.telemetry
        for i, tick in enumerate(source_ticks):
            if i >= max_ticks:
                break
            now, dt = tick.t, 1.0
            ctx = TickContext(t=now, dt=dt, index=i)
            with tel.span("tick"):
                with tel.span("filter"):
                    recs = self.filter_stage(tick.records, ctx)
                for stage in self.stages:
                    recs = stage(recs, ctx)
                self.metrics.emit("tick", now, raw=len(tick.records),
                                  kept=len(recs))
                with tel.span("partition"):
                    parts = self._partition(recs)
                for si, part in enumerate(parts):
                    self._shard_step(si, part, now, dt, states[si])

        wall = time.time() - t_start
        # the partition is total: per-shard record counts sum to the
        # filtered stream (and survive checkpoint/resume, unlike a local)
        total_records = sum(st["records"] for st in states)
        reports = [
            hub.build_report(
                total_records=st["records"],
                total_instructions=st["instr"],
                raw_instructions=st["raw"],
                compression_ratios=st["crs"],
                wall_s=wall,
            )
            for hub, st in zip(self._hubs, states)
        ]
        return ShardedReport(
            shards=reports,
            total_records=total_records,
            total_instructions=sum(st["instr"] for st in states),
            raw_instructions=sum(st["raw"] for st in states),
            max_buffered=[b.max_buffered for b in self.shards],
            spill_events=sum(h.counters["spill"] for h in self._hubs),
            drain_events=sum(h.counters["drain"] for h in self._hubs),
            wall_s=wall,
        )

    # ---- checkpoint surface (repro.resilience) -----------------------
    def state(self) -> dict:
        s: dict = {
            "loops": None if self.loop_states is None else
                [{**st, "crs": list(st["crs"])} for st in self.loop_states],
            "shards": [b.state() for b in self.shards],
            "hubs": [h.state() for h in self._hubs],
            "metrics": self.metrics.state(),
            "stages": [st.state() if hasattr(st, "state") else None
                       for st in self.stages],
        }
        if hasattr(self.consumer, "state"):
            s["consumer"] = self.consumer.state()
        if hasattr(self.sink, "state"):
            s["sink"] = self.sink.state()
        tracker = getattr(self.metrics, "lineage", None)
        if tracker is not None:
            s["lineage"] = tracker.state()
        return s

    def restore_state(self, s: dict) -> None:
        self.loop_states = None if s["loops"] is None else \
            [dict(st) for st in s["loops"]]
        for b, b_s in zip(self.shards, s["shards"]):
            b.restore_state(b_s)
        for h, h_s in zip(self._hubs, s["hubs"]):
            h.restore_state(h_s)
        self.metrics.restore_state(s["metrics"])
        for st, st_s in zip(self.stages, s["stages"]):
            if st_s is not None and hasattr(st, "restore_state"):
                st.restore_state(st_s)
        if "consumer" in s and hasattr(self.consumer, "restore_state"):
            self.consumer.restore_state(s["consumer"])
        if "sink" in s and hasattr(self.sink, "restore_state"):
            self.sink.restore_state(s["sink"])
        tracker = getattr(self.metrics, "lineage", None)
        if tracker is not None and "lineage" in s:
            tracker.restore_state(s["lineage"])
