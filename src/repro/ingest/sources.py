"""Stream sources (§IV Data Set).

`BurstyTweetSource` synthesises a politically-themed tweet stream with
the statistics the paper reports: ~60 records/s baseline (1% Twitter
sample), 15-45% velocity fluctuation on normal days, >250% during
bursts, 5-20% duplicate tweets, and — crucially for graph compression —
*temporal clustering*: during a burst many users reuse a small set of
hot hashtags (the #ReleaseTheMemo effect of Fig. 13), so content
diversity drops exactly when volume spikes.

`FileReplaySource` replays stored records at a programmable rate
multiplier (the paper's experiment mode (b): "streaming data from
tweets stored in files, where we programmatically control the
streaming rate to test the limits").
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class StreamTick:
    t: float
    records: List[dict]


class BurstyTweetSource:
    def __init__(
        self,
        mean_rate: float = 60.0,
        burst_multiplier: float = 5.0,
        duplicate_frac: float = 0.15,
        n_users: int = 20_000,
        n_hashtags: int = 4_000,
        burst_hashtags: int = 12,
        p_burst_start: float = 0.01,
        p_burst_end: float = 0.08,
        seed: int = 0,
        dt: float = 1.0,
    ):
        self.rng = np.random.default_rng(seed)
        self.mean_rate = mean_rate
        self.burst_multiplier = burst_multiplier
        self.duplicate_frac = duplicate_frac
        self.n_users = n_users
        self.n_hashtags = n_hashtags
        self.burst_hashtags = burst_hashtags
        self.p_burst_start = p_burst_start
        self.p_burst_end = p_burst_end
        self.dt = dt
        self.t = 0.0
        self.in_burst = False
        self.burst_topic: Optional[np.ndarray] = None
        self._tweet_no = 0
        self._recent: List[dict] = []

    # Zipf-ish popularity over users/hashtags
    def _zipf_pick(self, n: int, size: int, a: float = 1.3) -> np.ndarray:
        r = self.rng.zipf(a, size=size)
        return np.minimum(r, n) - 1

    def _make_tweet(self) -> dict:
        self._tweet_no += 1
        uid = int(self._zipf_pick(self.n_users, 1)[0])
        if self.in_burst and self.rng.random() < 0.8:
            # burst: hot-topic hashtags, heavy reuse (low diversity)
            k = self.rng.integers(2, 5)
            tags = self.rng.choice(self.burst_topic, size=k, replace=False)
        else:
            k = self.rng.integers(1, 4)
            tags = self._zipf_pick(self.n_hashtags, k)
        # political mentions concentrate on few accounts (zipf)
        nm = self.rng.integers(1, 4)
        mentions = self._zipf_pick(self.n_users, nm, a=2.0)
        return {
            "id": f"t{self._tweet_no}",
            "user": f"u{uid}",
            "hashtags": [f"h{int(h)}" for h in np.atleast_1d(tags)],
            "mentions": [f"u{int(m)}" for m in np.atleast_1d(mentions)],
            "text": f"synthetic tweet {self._tweet_no}",
            "ts": self.t,
        }

    def ticks(self) -> Iterator[StreamTick]:
        while True:
            # burst state machine
            if not self.in_burst and self.rng.random() < self.p_burst_start:
                self.in_burst = True
                self.burst_topic = self.rng.integers(
                    0, self.n_hashtags, size=self.burst_hashtags
                )
            elif self.in_burst and self.rng.random() < self.p_burst_end:
                self.in_burst = False

            rate = self.mean_rate * (
                self.burst_multiplier if self.in_burst else 1.0
            )
            # 15-45% fluctuation on top
            rate *= 1.0 + self.rng.uniform(-0.25, 0.35)
            n = self.rng.poisson(max(rate, 0.1) * self.dt)
            recs = []
            for _ in range(n):
                if self._recent and self.rng.random() < self.duplicate_frac:
                    recs.append(dict(self.rng.choice(self._recent)))
                else:
                    tw = self._make_tweet()
                    recs.append(tw)
                    self._recent.append(tw)
                    if len(self._recent) > 500:
                        self._recent.pop(0)
            self.t += self.dt
            yield StreamTick(self.t, recs)


class FileReplaySource:
    """Replay a jsonl file at `rate_multiplier` x its natural rate.

    The replay cursor — byte offset, undelivered record buffer and the
    fractional-rate carry — lives on the instance, so a checkpoint
    (repro.resilience) can capture it mid-file and a resumed source
    continues from the exact next record."""

    def __init__(self, path: str, rate_multiplier: float = 1.0, dt: float = 1.0,
                 natural_rate: float = 4.9):
        self.path = path
        self.rate = natural_rate * rate_multiplier
        self.dt = dt
        self.t = 0.0
        self._offset = 0  # byte offset of the next unread line
        self._buf: List[dict] = []  # read but not yet delivered
        self._acc = 0.0  # fractional-record carry (non-integer rates)

    def ticks(self) -> Iterator[StreamTick]:
        per_tick = self.rate * self.dt
        if per_tick <= 0:
            raise ValueError("replay rate must be positive")
        with open(self.path) as f:
            f.seek(self._offset)
            while True:
                line = f.readline()
                if not line:
                    break
                self._offset = f.tell()
                self._buf.append(json.loads(line))
                want = self._acc + per_tick
                k = int(want)
                if len(self._buf) >= k:
                    self._acc = want - k
                    out, self._buf = self._buf[:k], self._buf[k:]
                    self.t += self.dt
                    yield StreamTick(self.t, out)
        # drain the tail at the programmed rate (no EOF burst)
        while self._buf:
            want = self._acc + per_tick
            k = min(int(want), len(self._buf))
            self._acc = want - k
            out, self._buf = self._buf[:k], self._buf[k:]
            self.t += self.dt
            yield StreamTick(self.t, out)

    # ---- checkpoint surface (repro.resilience) -----------------------
    def state(self) -> dict:
        return {"t": self.t, "offset": self._offset,
                "buf": [dict(r) for r in self._buf], "acc": self._acc}

    def restore_state(self, s: dict) -> None:
        self.t = float(s["t"])
        self._offset = int(s["offset"])
        self._buf = list(s["buf"])
        self._acc = float(s["acc"])
