"""Two-stage filtering (§II-A Filter).

Stage 1 is the source-API filter (keyword list passed to the streaming
API — here applied to the synthetic stream the same way Twitter would).
Stage 2 is the analysis-specific filter (e.g. drop records that carry
no graph signal, the paper's "remove tweets with only emojis").
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Sequence


def api_keyword_filter(keywords: Sequence[str]) -> Callable[[dict], bool]:
    kws = [k.lower() for k in keywords]

    def f(rec: dict) -> bool:
        if not kws:
            return True
        hay = " ".join(
            [rec.get("text", "")] + list(rec.get("hashtags", ()))
        ).lower()
        return any(k in hay for k in kws)

    return f


def analysis_filter(rec: dict) -> bool:
    """Drop records with no graph content (no hashtags AND no mentions
    -> only the owner edge; keep those, but drop empty/malformed)."""
    return bool(rec.get("id")) and bool(rec.get("user"))


def apply_filters(records: Iterable[dict], stage1, stage2=analysis_filter) -> List[dict]:
    return [r for r in records if stage1(r) and stage2(r)]
