"""Analytic matmul-FLOP counter over a closed jaxpr.

Independent cross-check of the loop-expanded HLO analysis: walks the
jaxpr (pre-SPMD, global program), multiplying `scan` bodies by their
trip count and counting 2*M*N*K for every dot_general.  Includes remat
recompute (checkpointed bodies appear as additional remat scans /
custom vjps inside the backward scan), so

    useful_ratio = 6*N*D / jaxpr_flops

measures remat + MoE-capacity overhead directly.
"""
from __future__ import annotations

import numpy as np
from jax import core as jcore


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 1


def count_eqn_dot(eqn) -> float:
    dn = eqn.params.get("dimension_numbers")
    (lc, rc), (lb, rb) = dn
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    contract = 1
    for d in lc:
        contract *= lhs.shape[d]
    return 2.0 * _aval_size(out) * contract


def count_jaxpr(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += count_eqn_dot(eqn)
        elif prim == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += eqn.params["length"] * count_jaxpr(body)
        elif prim == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            total += count_jaxpr(body)  # unknown trip; rare in our models
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max(count_jaxpr(b.jaxpr) for b in branches)
        elif prim in ("pjit", "closed_call", "core_call", "remat_call", "xla_call"):
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                total += count_jaxpr(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
        elif prim == "custom_vjp_call" or prim == "custom_jvp_call":
            inner = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
            if inner is not None:
                total += count_jaxpr(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
        elif prim == "remat2" or prim == "checkpoint":
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                total += count_jaxpr(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
    return total


def traced_flops(fn, *avals) -> float:
    import jax

    closed = jax.make_jaxpr(fn)(*avals)
    return count_jaxpr(closed.jaxpr)
