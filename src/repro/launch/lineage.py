"""Lineage entrypoint: run a scenario with event-time watermarks and
per-batch provenance on, and print the freshness view of the run.

  PYTHONPATH=src python -m repro.launch.lineage --scenario flash_crowd
  PYTHONPATH=src python -m repro.launch.lineage --scenario flash_crowd \
      --outage 20:30 --jsonl-out lineage.jsonl --trace-out trace.json
  PYTHONPATH=src python -m repro.launch.lineage --dryrun

Where `launch.telemetry` prints what the pipeline spent its time on
and `launch.monitor` whether it stayed healthy, this prints how stale
the data a query would see actually was: the per-path freshness table
(direct vs buffered vs spilled vs archived-retry commit routes), the
watermark trajectory, the record-conservation verdict, and the
`freshness` SLO budget/burn status.  `--outage t0:t1` injects a store
outage (every commit in the window fails, batches detour through the
archive) so the archive path's lag contribution is visible on demand.

`--trace-out` writes the Chrome trace WITH lineage flow events —
loaded in ui.perfetto.dev the sampled batches render as arrows
following each batch from the buffer through its detours to the
queryable store.  `--jsonl-out` writes the sampled per-batch hop logs.

`--dryrun` is the CI smoke: a short run that re-parses the emitted
trace and exits nonzero unless every traversed path has at least one
complete flow chain and the final queryable watermark is non-null.
x64 is enabled for exact 64-bit node identity (as in launch.ingest).
"""
import jax

jax.config.update("jax_enable_x64", True)

import argparse
import os
import tempfile


def _parse_outage(spec):
    t0, _, t1 = spec.partition(":")
    try:
        lo, hi = float(t0), float(t1)
    except ValueError:
        raise SystemExit(f"--outage wants t0:t1 (got {spec!r})")
    if hi <= lo:
        raise SystemExit(f"--outage window is empty: {spec!r}")
    return lo, hi


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="flash_crowd")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--speed", type=float, default=0.5)
    ap.add_argument("--node-cap", type=int, default=None)
    ap.add_argument("--edge-cap", type=int, default=None)
    ap.add_argument("--sample-rate", type=float, default=0.25,
                    help="deterministic hash-sample rate for exported "
                         "per-batch hop logs")
    ap.add_argument("--outage", default=None, metavar="T0:T1",
                    help="inject a store outage over this simulated-"
                         "time window (commits fail, archive absorbs)")
    ap.add_argument("--timeline-rows", type=int, default=20)
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome trace incl. lineage flow "
                         "events here (Perfetto-loadable)")
    ap.add_argument("--jsonl-out", default=None,
                    help="write the sampled per-batch hop logs here")
    ap.add_argument("--prom-out", default=None,
                    help="write the Prometheus exposition (incl. the "
                         "lineage gauges) here")
    ap.add_argument("--dryrun", action="store_true",
                    help="small end-to-end run + flow-event/watermark "
                         "validation (CI smoke)")
    args = ap.parse_args(argv)

    from repro.lineage import (
        LineageTracker,
        freshness_table,
        validate_flow_events,
        watermark_timeline,
    )
    from repro.monitor import HealthMonitor
    from repro.workloads import run_scenario

    if args.dryrun:
        args.ticks = min(args.ticks or 60, 60)
        args.node_cap = args.node_cap or 1 << 12
        args.edge_cap = args.edge_cap or 1 << 14
        if args.trace_out is None:
            # the validation needs a trace on disk even if the caller
            # did not ask to keep one
            args.trace_out = os.path.join(
                tempfile.mkdtemp(prefix="repro_lineage_"), "trace.json")
        if args.outage is None:
            # exercise the archive path so the smoke covers a detour
            args.outage = "20:26"

    fault_plan = None
    if args.outage:
        from repro.resilience import FaultPlan

        lo, hi = _parse_outage(args.outage)
        fault_plan = FaultPlan(fail_times=((lo, hi),))

    trk = LineageTracker(sample_rate=args.sample_rate)
    mon = HealthMonitor()
    rep = run_scenario(
        args.scenario,
        ticks=args.ticks,
        seed=args.seed,
        shards=args.shards,
        speed=args.speed,
        node_cap=args.node_cap,
        edge_cap=args.edge_cap,
        lineage=trk,
        monitor=mon,
        trace=args.trace_out,
        lineage_jsonl=args.jsonl_out,
        fault_plan=fault_plan,
    )

    print(rep.summary())
    print()
    print(freshness_table(trk))
    print()
    print(watermark_timeline(trk, max_rows=args.timeline_rows))
    print()
    verdict = "BALANCED" if not rep.conservation_warning \
        else rep.conservation_warning
    print(f"conservation: in={rep.records_in} "
          f"committed={rep.records_committed} "
          f"dropped={rep.records_dropped} "
          f"in_flight={rep.records_in_flight} -> {verdict}")
    slo = rep.slo_summary.get("freshness")
    if slo:
        alerts = [a for a in slo["alerts"] if a["phase"] == "onset"]
        print(f"freshness SLO: {slo['objective']} — "
              f"{slo['breaches']}/{slo['ticks']} breaching ticks "
              f"(budget consumed {slo['budget_consumed']:.2f}x), "
              f"{len(alerts)} burn alerts"
              + (f", first onset tick {slo['first_alert_tick']}"
                 if alerts else ""))
    if args.prom_out:
        from repro.monitor.export import write_prometheus

        write_prometheus(args.prom_out, monitor=mon, lineage=trk)
        print(f"(wrote Prometheus exposition to {args.prom_out})")
    if args.trace_out:
        print(f"(wrote Chrome trace with flow events to {args.trace_out})")
    if args.jsonl_out:
        print(f"(wrote lineage JSONL to {args.jsonl_out})")

    if args.dryrun:
        ok = rep.total_records > 0 and not rep.conservation_warning
        msg = "records flowed, conservation holds" if ok else \
            (rep.conservation_warning or "no records flowed")
        if ok and rep.watermark_final.get("queryable") is None:
            ok, msg = False, "final queryable watermark is null"
        if ok:
            ok, msg = validate_flow_events(
                args.trace_out,
                require_paths=sorted(rep.path_mix))
        print(f"dryrun {'ok' if ok else 'FAILED'}: {msg}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
