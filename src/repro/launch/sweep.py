"""Run the full dry-run matrix: every (arch x shape) on both meshes.

Each cell runs in a fresh subprocess (XLA locks the device count at
first init, and per-cell isolation keeps one bad cell from killing the
sweep).  Results land in results/dryrun/<arch>__<shape>__<mesh>.json.

  PYTHONPATH=src python -m repro.launch.sweep              # all cells
  PYTHONPATH=src python -m repro.launch.sweep --mesh pod   # single-pod only
  PYTHONPATH=src python -m repro.launch.sweep --arch mixtral-8x7b
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "zamba2-7b",
    "mamba2-780m",
    "mixtral-8x7b",
    "qwen2-moe-a2.7b",
    "llama3-405b",
    "qwen2.5-3b",
    "stablelm-1.6b",
    "qwen3-4b",
    "phi-3-vision-4.2b",
    "whisper-medium",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cell_path(outdir, arch, shape, multi_pod):
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(outdir, f"{arch}__{shape}__{mesh}.json")


def run_one(arch, shape, multi_pod, outdir, timeout=1200, baseline=False):
    out = cell_path(outdir, arch, shape, multi_pod)
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--out", out,
    ]
    if multi_pod:
        cmd.append("--multi-pod")
    if baseline:
        cmd.append("--baseline")
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    t0 = time.time()
    try:
        p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
        ok = p.returncode == 0 and os.path.exists(out)
        err = "" if ok else (p.stderr or "")[-2000:]
    except subprocess.TimeoutExpired:
        ok, err = False, f"timeout after {timeout}s"
    dt = time.time() - t0
    return ok, dt, err


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--baseline", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else SHAPES
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                out = cell_path(args.outdir, arch, shape, mp)
                if os.path.exists(out) and not args.force:
                    print(f"cached  {arch} x {shape} x {'2x16x16' if mp else '16x16'}")
                    continue
                ok, dt, err = run_one(arch, shape, mp, args.outdir, baseline=args.baseline)
                tag = "ok" if ok else "FAIL"
                print(f"{tag:5s} {arch} x {shape} x {'2x16x16' if mp else '16x16'} ({dt:.0f}s)")
                if not ok:
                    failures.append((arch, shape, mp, err))
                    print("      " + err.replace("\n", "\n      ")[:1500])
    if failures:
        print(f"\n{len(failures)} failures")
        return 1
    print("\nall cells green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
