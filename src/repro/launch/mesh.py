"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / laptop runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return _mk((data, model), ("data", "model"))


def dp_size(mesh) -> int:
    s = mesh.shape
    return s.get("data", 1) * s.get("pod", 1)
