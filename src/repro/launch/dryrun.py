import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioning succeeds),
  * the program fits (memory_analysis),
  * and yields the roofline inputs (cost_analysis + collective bytes
    parsed from the compiled HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape decode_32k --multi-pod
Writes a JSON blob per cell under results/dryrun/.
"""
import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, get_config
from repro.distributed.sharding import (
    ShardingRules,
    spec_avals,
    spec_shardings,
)
from repro.launch.mesh import dp_size, make_production_mesh
from repro.models import model as M
from repro.train.trainstep import make_prefill_step, make_serve_step, make_state_specs, make_train_step

# long_500k applicability: sub-quadratic archs only (DESIGN.md §5)
LONG_OK = {"zamba2-7b", "mamba2-780m", "mixtral-8x7b"}


def shape_adjusted_config(cfg: ModelConfig, shape_name: str, baseline: bool = False) -> ModelConfig:
    """Per-shape config tweaks (documented in DESIGN.md)."""
    if shape_name == "long_500k" and cfg.family == "hybrid":
        # shared attention block runs a sliding window in the 500k shape
        cfg = dataclasses.replace(cfg, sliding_window=4096)
    if baseline:
        # the paper-faithful pre-hillclimb configuration (§Perf):
        # uniform FSDP+TP sharding, auto microbatching, chunked attention
        # at 4k, naive-SPMD MoE dispatch
        cfg = dataclasses.replace(
            cfg, sharding_profile="2d", microbatch_seqs=0,
            attn_full_max=2048, moe_shard_map=False,
        )
    return cfg


def cell_supported(arch: str, shape_name: str):
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch not in LONG_OK:
        return False, "full-attention arch: long_500k would be quadratic/unbounded-KV (skip per assignment)"
    del cfg
    return True, ""


# ---------------------------------------------------------------------------
# Dry-run of one cell
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, rules: ShardingRules = None, verbose=True, save_hlo=None, baseline=False):
    ok, why = cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod, "skipped": why}

    shape = SHAPES[shape_name]
    cfg = shape_adjusted_config(get_config(arch), shape_name, baseline=baseline)
    rules = rules or ShardingRules.for_profile(cfg.sharding_profile)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_size(mesh)
    if cfg.sharding_profile == "dp":
        # both axes carry batch in the dp profile
        dp = dp * mesh.shape.get("model", 1)
        dp = min(dp, shape.global_batch)
    t0 = time.time()

    from repro.distributed.sharding import use_rules

    with jax.sharding.set_mesh(mesh), use_rules(rules):
        if shape.kind == "train":
            state_specs = make_state_specs(cfg)
            state_avals = spec_avals(state_specs)
            state_sh = spec_shardings(state_specs, mesh, rules)
            in_specs = M.input_specs(cfg, shape)
            in_avals = spec_avals(in_specs)
            in_sh = spec_shardings(in_specs, mesh, rules)
            step, info = make_train_step(cfg, shape, dp)
            jf = jax.jit(
                step,
                in_shardings=(state_sh, in_sh),
                donate_argnums=(0,),
            )
            lowered = jf.lower(state_avals, in_avals)
        elif shape.kind == "prefill":
            pspecs = M.param_specs(cfg)
            # serving runs bf16 weights (no optimizer state on the machine)
            p_avals = spec_avals(pspecs, dtype_override=cfg.dtype)
            p_sh = spec_shardings(pspecs, mesh, rules)
            in_specs = M.input_specs(cfg, shape)
            in_avals = spec_avals(in_specs)
            in_sh = spec_shardings(in_specs, mesh, rules)
            step = make_prefill_step(cfg)
            jf = jax.jit(step, in_shardings=(p_sh, in_sh))
            lowered = jf.lower(p_avals, in_avals)
            info = {}
        else:  # decode
            pspecs = M.param_specs(cfg)
            p_avals = spec_avals(pspecs, dtype_override=cfg.dtype)
            p_sh = spec_shardings(pspecs, mesh, rules)
            in_specs = M.input_specs(cfg, shape)
            in_avals = spec_avals(in_specs)
            in_sh = spec_shardings(in_specs, mesh, rules)
            step = make_serve_step(cfg)
            jf = jax.jit(
                step,
                in_shardings=(p_sh, in_sh["cache"], in_sh["tokens"], in_sh["pos"]),
                donate_argnums=(1,),
            )
            lowered = jf.lower(
                p_avals, in_avals["cache"], in_avals["tokens"], in_avals["pos"]
            )
            info = {}

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if save_hlo:
        import gzip

        os.makedirs(os.path.dirname(save_hlo) or ".", exist_ok=True)
        with gzip.open(save_hlo, "wt") as f:
            f.write(hlo)
    # loop-expanded (trip-count aware) totals; see hlo_analysis.py
    from repro.launch.hlo_analysis import analyze, scores_chain_bytes

    stats = analyze(hlo)
    coll_bytes, coll_detail = stats.coll_bytes, stats.coll_detail
    # flash-kernel projection input: HBM bytes the Pallas attention
    # kernel keeps in VMEM (the materialised S^2 softmax chain)
    chunk = cfg.attn_chunk if shape.seq_len > 8192 else None
    attn_chain = (
        scores_chain_bytes(hlo, shape.seq_len, chunk)
        if not cfg.is_attention_free
        else 0.0
    )

    mem_d = {
        k: int(getattr(mem, k, 0) or 0)
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        )
    }
    n_chips = 512 if multi_pod else 256
    total_p, active_p = cfg.param_count()
    res = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "n_chips": n_chips,
        # raw XLA numbers (loop bodies counted ONCE — see hlo_analysis.py)
        "xla_flops_per_device": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        # loop-expanded per-device totals (roofline inputs)
        "flops_per_device": stats.flops,
        "bytes_per_device": stats.bytes,
        "attn_chain_bytes_per_device": attn_chain,
        "collective_bytes_per_device": coll_bytes,
        "collective_detail": coll_detail,
        "bytes_detail": dict(
            sorted((stats.bytes_detail or {}).items(), key=lambda kv: -kv[1])[:12]
        ),
        "memory": mem_d,
        "params_total": total_p,
        "params_active": active_p,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        **info,
    }
    if verbose:
        print(json.dumps({k: v for k, v in res.items() if k not in ("collective_detail",)}, indent=2))
        print("memory_analysis:", mem)
        print("cost_analysis flops:", cost.get("flops"), "bytes:", cost.get("bytes accessed"))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", default=None, help="gzip the compiled HLO here")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful pre-hillclimb configuration")
    args = ap.parse_args()

    res = run_cell(args.arch, args.shape, args.multi_pod, save_hlo=args.save_hlo,
                   baseline=args.baseline)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
    if res.get("skipped"):
        print(f"SKIP {args.arch} x {args.shape}: {res['skipped']}")
        return 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
