"""Paper-pipeline entrypoint: run the adaptive ingestion loop.

  PYTHONPATH=src python -m repro.launch.ingest --ticks 300 --cpu-max 0.55
  PYTHONPATH=src python -m repro.launch.ingest --uncontrolled   # Fig 7 mode
  PYTHONPATH=src python -m repro.launch.ingest --shards 4       # scale-out

Built on the composable API (`repro.api.PipelineBuilder`); x64 is
enabled for exact 64-bit node identity (DESIGN.md §2)."""
import jax

jax.config.update("jax_enable_x64", True)

import argparse

import numpy as np

from repro.api import PipelineBuilder
from repro.configs.paper_ingest import IngestConfig
from repro.ingest.sources import BurstyTweetSource


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=300)
    ap.add_argument("--cpu-max", type=float, default=0.55)
    ap.add_argument("--uncontrolled", action="store_true")
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--dict-compress", action="store_true",
                    help="GraphZip dictionary compression (repro.compress)")
    ap.add_argument("--dict-capacity", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=60.0)
    ap.add_argument("--burst", type=float, default=5.0)
    ap.add_argument("--shards", type=int, default=1)
    args = ap.parse_args(argv)
    if args.shards < 1:
        ap.error("--shards must be >= 1")
    if args.shards > 1 and args.uncontrolled:
        ap.error("--shards requires the controlled pipeline "
                 "(drop --uncontrolled)")

    cfg = IngestConfig(cpu_max=args.cpu_max, mean_rate=args.rate,
                       burst_multiplier=args.burst)
    src = BurstyTweetSource(seed=args.seed, mean_rate=args.rate,
                            burst_multiplier=args.burst)
    b = (PipelineBuilder(cfg)
         .with_source(src)
         .uncontrolled(args.uncontrolled)
         .compressed(not args.no_compress))
    if args.dict_compress:
        b = b.with_compression(capacity=args.dict_capacity)
    if args.shards > 1:
        b = b.sharded(args.shards).spill_dir("/tmp/repro_spill_shards")
    pipe = b.build()
    rep = pipe.run(max_ticks=args.ticks)

    if args.shards > 1:
        print(f"mode=sharded x{args.shards} compress={not args.no_compress}")
        print(f"records={rep.total_records} instructions={rep.total_instructions} "
              f"raw={rep.raw_instructions}")
        for i, (sr, hwm) in enumerate(zip(rep.shards, rep.max_buffered)):
            mu = sr.samples["mu"]
            print(f"shard {i}: records={sr.total_records} "
                  f"mu_mean={mu.mean():.3f} mu_max={mu.max():.3f} "
                  f"buffer_hwm={hwm}")
        print(f"compression: mean={rep.mean_compression:.3f} "
              f"spills={rep.spill_events} drains={rep.drain_events}")
        print(f"store: {int(pipe.store.n_nodes)} nodes, "
              f"{int(pipe.store.n_edges)} edges")
        if args.dict_compress:
            print(f"dict: {b.dictionary_stage.stats()}")
        return rep

    mu = rep.samples["mu"]
    print(f"mode={'uncontrolled' if args.uncontrolled else 'controlled'} "
          f"compress={not args.no_compress}")
    print(f"records={rep.total_records} instructions={rep.total_instructions} "
          f"raw={rep.raw_instructions}")
    print(f"mu: mean={mu.mean():.3f} p95={np.percentile(mu,95):.3f} "
          f"max={mu.max():.3f} pinned(>0.95)={float((mu>0.95).mean()):.3f}")
    print(f"delay: mean={rep.samples['delay_s'].mean():.2f}s "
          f"max={rep.samples['delay_s'].max():.2f}s")
    print(f"compression: mean={rep.mean_compression:.3f} "
          f"spills={rep.spill_events} drains={rep.drain_events}")
    print(f"store: {int(pipe.store.n_nodes)} nodes, "
          f"{int(pipe.store.n_edges)} edges")
    if args.dict_compress:
        print(f"dict: {b.dictionary_stage.stats()}")
    return rep


if __name__ == "__main__":
    main()
