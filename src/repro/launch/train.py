"""End-to-end training driver: bursty social stream -> adaptive-buffer
ingestion -> packed LM batches -> (pjit) train loop with checkpointing
and fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --batch 4 --seq 128

On the container this runs reduced configs on CPU; on a pod the same
driver runs the production mesh (--mesh pod).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import SHAPES, ShapeSpec, get_config, smoke_config
from repro.data.pipeline import stream_batches
from repro.distributed.fault import FaultTolerantRunner
from repro.ingest.sources import BurstyTweetSource
from repro.launch.mesh import dp_size, make_dev_mesh
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig
from repro.train.trainstep import init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    cfg = dataclasses.replace(cfg, microbatch_seqs=max(1, args.batch // 2))

    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    mesh = make_dev_mesh()
    dp = dp_size(mesh)
    oc = OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)

    ckpt = CheckpointManager(args.ckpt_dir)
    state = init_state(cfg, jax.random.key(0))
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start = ckpt.latest_step()
        print(f"resumed from step {start}")

    src = BurstyTweetSource(seed=0, mean_rate=400.0)
    batches = stream_batches(src.ticks(), cfg.vocab_size, args.seq, args.batch)

    def make_step(dp_now):
        step, info = make_train_step(cfg, shape, dp_now, oc)
        print(f"microbatching: {info}")
        return jax.jit(step, donate_argnums=0)

    schedule = {}
    if args.inject_failure_at >= 0:
        schedule[args.inject_failure_at] = "crash"
    runner = FaultTolerantRunner(
        ckpt,
        make_step,
        state_template=lambda: init_state(cfg, jax.random.key(0)),
        dp_size=dp,
        ckpt_every=args.ckpt_every,
        fail_schedule=schedule,
    )

    t0 = time.time()
    state, hist = runner.run(state, batches, start_step=start, max_steps=args.steps)
    wall = time.time() - t0
    losses = [h["loss"] for h in hist]
    print(f"steps={len(hist)} wall={wall:.1f}s loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    for e in runner.events:
        print(f"  fault-event step={e.step} {e.kind}: {e.detail}")
    ckpt.save(args.steps, state, blocking=True)
    print(f"final checkpoint at step {args.steps} in {args.ckpt_dir}")
    return losses


if __name__ == "__main__":
    main()
