"""Query-engine entrypoint: ingest a stream, then query the graph.

  PYTHONPATH=src python -m repro.launch.query                  # ingest->query
  PYTHONPATH=src python -m repro.launch.query --mode live      # query-while-ingesting
  PYTHONPATH=src python -m repro.launch.query --dryrun         # CI smoke

Ingests a simulated burst through the composable pipeline with the
ingestion-time sketch enabled (`SketchStage` after the filter, plus a
commit-consistent `QuerySink` around the store sink), then compacts
the store into a CSR snapshot and runs the exact engine ops — degree
distribution, top-k heavy nodes, k-hop expansion, triangle count —
printing sketch estimates next to exact answers.  In `--mode live`
the sketch's heavy-hitter answers stream to stdout *during* ingestion
via the MetricsHub "sketch" events.

x64 is enabled for exact 64-bit node identity (as in launch.ingest).
"""
import jax

jax.config.update("jax_enable_x64", True)

import argparse
import time

import numpy as np

from repro.api import PipelineBuilder, GraphStoreSink
from repro.configs.paper_ingest import IngestConfig
from repro.ingest.sources import BurstyTweetSource


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--rate", type=float, default=60.0)
    ap.add_argument("--burst", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=["snapshot", "live"], default="snapshot",
                    help="snapshot: ingest then query; live: print sketch "
                         "answers during ingestion, then query")
    ap.add_argument("--depth", type=int, default=4, help="sketch depth D")
    ap.add_argument("--width", type=int, default=512, help="sketch width W")
    ap.add_argument("--node-cap", type=int, default=1 << 12)
    ap.add_argument("--edge-cap", type=int, default=1 << 14)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--hops", type=int, default=2)
    ap.add_argument("--query-every", type=int, default=20,
                    help="live mode: emit sketch answers every N commits")
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny end-to-end run (CI smoke)")
    args = ap.parse_args(argv)
    if args.dryrun:
        args.ticks = min(args.ticks, 25)
        args.node_cap, args.edge_cap = 1 << 11, 1 << 12
        args.width = 256

    from repro.query import (
        SketchStage, degree_distribution, edge_lookup,
        k_hop, top_k_degree, triangle_count,
    )

    cfg = IngestConfig(mean_rate=args.rate, burst_multiplier=args.burst,
                       store_nodes=args.node_cap, store_edges=args.edge_cap)
    src = BurstyTweetSource(seed=args.seed, mean_rate=args.rate,
                            burst_multiplier=args.burst)
    sketch_stage = SketchStage(depth=args.depth, width=args.width)
    b = (PipelineBuilder(cfg)
         .with_source(src)
         .with_sink(GraphStoreSink(node_cap=args.node_cap,
                                   edge_cap=args.edge_cap))
         .with_sketch(sketch_stage)
         .with_query_sink(depth=args.depth, width=args.width,
                          answer_every=args.query_every, top_k=5,
                          exact_topk=3 if args.mode == "live" else 0))
    if args.mode == "live":
        def on_sketch(ev):
            if ev.kind == "sketch":
                pairs = list(zip(ev.payload["hh_keys"], ev.payload["hh_counts"]))
                exact = ""
                if "exact_degrees" in ev.payload:
                    exact = " exact-deg: " + " ".join(
                        f"{k:#x}:{d}" for k, d in zip(ev.payload["exact_keys"],
                                                      ev.payload["exact_degrees"])
                        if k)
                print(f"[t={ev.t:7.1f}] live sketch: commits={ev.payload['commits']} "
                      f"absorbed={ev.payload['absorbed']} top: "
                      + " ".join(f"{k:#x}:{c}" for k, c in pairs if k) + exact)
        b = b.on_event(on_sketch)
    pipe = b.build()

    rep = pipe.run(max_ticks=args.ticks)
    store = pipe.store
    print(f"ingested: {rep.total_records} records -> "
          f"{int(store.n_nodes)} nodes, {int(store.n_edges)} edges "
          f"({rep.total_instructions} instructions)")

    # ---- snapshot + exact queries (incrementally maintained CSR) ----
    qsink0 = pipe.sink  # QuerySink
    t0 = time.perf_counter()
    snap = jax.block_until_ready(qsink0.snapshot())
    build_ms = (time.perf_counter() - t0) * 1e3
    m = qsink0.maintainer
    print(f"snapshot: {int(snap.n_nodes)} nodes, {int(snap.n_edges)} edges, "
          f"served in {build_ms:.1f} ms "
          f"(maintenance: {m.full_builds} full builds, "
          f"{m.delta_applies} delta applies)")
    dangling = int(store.n_edges) - int(snap.n_edges)
    if dangling:
        print(f"  ({dangling} edges dropped: endpoint node inserts failed — "
              f"node table at {int(store.n_nodes)}/{args.node_cap} load; "
              f"raise --node-cap)")

    hist = np.asarray(degree_distribution(snap, num_bins=16))
    print("degree distribution (bins 0..14, 15+):", hist.tolist())

    keys, degs = top_k_degree(snap, args.topk)
    keys, degs = np.asarray(keys), np.asarray(degs)
    qsink = pipe.sink  # QuerySink (commit-consistent sketch)
    sk_deg = sketch_stage.degree(keys)
    qs_deg = qsink.degree(keys)
    print(f"top-{args.topk} by degree (exact | sketch@filter | sketch@commit):")
    for k, d, s1, s2 in zip(keys, degs, sk_deg, qs_deg):
        if k:
            print(f"  node {int(k):#018x}  degree={int(d):5d}  "
                  f"sketch={int(s1):5d}  commit-sketch={int(s2):5d}")
    hh_k, hh_c = qsink.heavy_hitters(args.topk)
    overlap = len(set(hh_k[hh_k != 0].tolist()) & set(keys[keys != 0].tolist()))
    print(f"sketch heavy-hitter overlap with exact top-{args.topk}: "
          f"{overlap}/{args.topk} (additive error bound "
          f"{qsink.error_bound():.1f})")

    seed_key = keys[:1]
    n_reach = [int(np.asarray(k_hop(snap, seed_key, hops=h)).sum())
               for h in range(1, args.hops + 1)]
    print(f"k-hop from heaviest node: " +
          " ".join(f"{h+1}-hop={n}" for h, n in enumerate(n_reach)))

    if args.node_cap <= 4096:
        tri = int(triangle_count(snap))
        print(f"triangles: {tri}")

    # spot-check: sketch edge weights vs exact lookups on real edges
    live = np.asarray(snap.edge_row) < snap.node_cap
    nk = np.asarray(snap.node_key)
    take = np.flatnonzero(live)[:8]
    s_keys = nk[np.asarray(snap.edge_row)[take]]
    d_keys = nk[np.asarray(snap.edge_col)[take]]
    exact_w = np.asarray(edge_lookup(snap, s_keys, d_keys))
    est_w = qsink.edge_weight(s_keys, d_keys)
    print("edge-weight spot checks (exact vs sketch):",
          list(zip(exact_w.tolist(), est_w.tolist())))
    if args.dryrun:
        ok = (est_w >= exact_w).all() and int(snap.n_edges) > 0
        print(f"dryrun {'ok' if ok else 'FAILED'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
