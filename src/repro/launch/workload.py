"""Workload-harness entrypoint: score the controller under a scenario.

  PYTHONPATH=src python -m repro.launch.workload --list
  PYTHONPATH=src python -m repro.launch.workload --scenario flash_crowd
  PYTHONPATH=src python -m repro.launch.workload --scenario spam_storm \
      --shards 4 --sketch-control --json report.json
  PYTHONPATH=src python -m repro.launch.workload --scenario diurnal --dryrun

Drives the composable pipeline through a registry scenario via the
closed-loop harness (`repro.workloads.run_scenario`) and prints the
structured report: sustained throughput, spill/drop counts, the
Algorithm-2 buffer-mode transition timeline, and table-pressure
throttles.  `--dryrun` is the CI smoke: a small-capacity short run
that exits nonzero if the harness produces no records or the report
fails to serialise.  x64 is enabled for exact 64-bit node identity
(as in launch.ingest).
"""
import jax

jax.config.update("jax_enable_x64", True)

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="flash_crowd")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--ticks", type=int, default=None,
                    help="override the scenario's suggested run length")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--speed", type=float, default=0.5,
                    help="simulated consumer speed (0.5 = paper's half-"
                         "capacity engine)")
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="scale the scenario's base rate")
    ap.add_argument("--sketch-control", action="store_true",
                    help="sketch-guided control: feed live heavy-hitter "
                         "signals into the Algorithm-2 controller")
    ap.add_argument("--dict-compress", action="store_true",
                    help="GraphZip dictionary compression: rewrite "
                         "recurring mined patterns into references and "
                         "commit through the pattern-aware path")
    ap.add_argument("--dict-capacity", type=int, default=4096,
                    help="pattern-dictionary capacity (entries)")
    ap.add_argument("--node-cap", type=int, default=None)
    ap.add_argument("--edge-cap", type=int, default=None)
    ap.add_argument("--max-transitions", type=int, default=12,
                    help="timeline rows to print")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable Chrome trace of the "
                         "run here (enables span telemetry + the "
                         "controller audit trail; see launch.telemetry "
                         "for the full summary view)")
    ap.add_argument("--json", default=None, help="write the report dict here")
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny end-to-end run (CI smoke)")
    args = ap.parse_args(argv)

    from repro.workloads import list_scenarios, run_scenario

    if args.list:
        for s in list_scenarios():
            print(f"{s.name:18s} {s.description}")
        return 0

    if args.dryrun:
        args.ticks = min(args.ticks or 60, 60)
        args.node_cap = args.node_cap or 1 << 12
        args.edge_cap = args.edge_cap or 1 << 14

    rep = run_scenario(
        args.scenario,
        ticks=args.ticks,
        seed=args.seed,
        shards=args.shards,
        speed=args.speed,
        rate_scale=args.rate_scale,
        sketch_guided=args.sketch_control,
        dict_compress=args.dict_compress,
        dict_capacity=args.dict_capacity,
        node_cap=args.node_cap,
        edge_cap=args.edge_cap,
        trace=args.trace_out,
    )

    print(rep.summary())
    if rep.transitions:
        shown = rep.transitions[: args.max_transitions]
        print(f"buffer-mode timeline (first {len(shown)} of "
              f"{rep.n_transitions} transitions):")
        for tr in shown:
            shard = f" shard={tr['shard']}" if rep.shards > 1 else ""
            print(f"  t={tr['t']:7.1f}{shard}  {tr['from']} -> {tr['to']}")
    else:
        print("buffer-mode timeline: no transitions (controller stayed in "
              "one mode)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep.to_dict(), f, indent=2)
        print(f"(wrote report to {args.json})")

    if args.trace_out:
        print(f"(wrote Chrome trace to {args.trace_out} — load in "
              f"ui.perfetto.dev or chrome://tracing)")

    if args.dryrun:
        ok = rep.total_records > 0 and bool(json.dumps(rep.to_dict()))
        print(f"dryrun {'ok' if ok else 'FAILED'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
