"""Telemetry entrypoint: run a scenario with full span telemetry on
and print the observability view of the run.

  PYTHONPATH=src python -m repro.launch.telemetry --scenario flash_crowd
  PYTHONPATH=src python -m repro.launch.telemetry --scenario spam_storm \
      --shards 4 --trace-out trace.json --jsonl-out spans.jsonl
  PYTHONPATH=src python -m repro.launch.telemetry --dryrun --trace-out t.json

Where `launch.workload` prints the controller score (throughput, mode
timeline), this prints what the pipeline spent its time on: the
per-stage latency table (p50/p95/p99 from the fixed log-bucket
histograms), counters, and the controller-decision audit timeline
with the full PerfMon input vector per decision.  `--trace-out`
writes a Chrome `trace_event` file loadable in ui.perfetto.dev with
one timeline track per shard; `--jsonl-out` the flat JSONL sink;
`--tsv` a machine-readable per-stage summary on stdout.

`--dryrun` is the CI smoke: a small short run that re-parses the
emitted Chrome trace and exits nonzero unless it is valid and carries
at least one span for every core instrumented stage.  x64 is enabled
for exact 64-bit node identity (as in launch.ingest).
"""
import jax

jax.config.update("jax_enable_x64", True)

import argparse
import sys

# Core stages the dryrun insists on seeing in the trace: one per
# instrumented layer (loop, filter, controller, transform, commit).
DRYRUN_REQUIRED_STAGES = (
    "tick", "filter", "decide", "transform.dedup", "commit.upsert",
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="flash_crowd")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--speed", type=float, default=0.5)
    ap.add_argument("--sketch-control", action="store_true")
    ap.add_argument("--dict-compress", action="store_true")
    ap.add_argument("--node-cap", type=int, default=None)
    ap.add_argument("--edge-cap", type=int, default=None)
    ap.add_argument("--max-decisions", type=int, default=20,
                    help="audit-timeline rows to print")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event file here "
                         "(Perfetto-loadable)")
    ap.add_argument("--jsonl-out", default=None,
                    help="write the flat JSONL span/audit sink here")
    ap.add_argument("--tsv", action="store_true",
                    help="print the machine-readable per-stage TSV "
                         "instead of the text summary")
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny end-to-end run + trace validation "
                         "(CI smoke)")
    args = ap.parse_args(argv)

    from repro.telemetry import (
        TelemetryRegistry,
        text_summary,
        summary_tsv,
        validate_chrome_trace,
    )
    from repro.workloads import run_scenario

    if args.dryrun:
        args.ticks = min(args.ticks or 60, 60)
        args.node_cap = args.node_cap or 1 << 12
        args.edge_cap = args.edge_cap or 1 << 14

    reg = TelemetryRegistry()
    rep = run_scenario(
        args.scenario,
        ticks=args.ticks,
        seed=args.seed,
        shards=args.shards,
        speed=args.speed,
        sketch_guided=args.sketch_control,
        dict_compress=args.dict_compress,
        node_cap=args.node_cap,
        edge_cap=args.edge_cap,
        telemetry=reg,
        trace=args.trace_out,
        trace_jsonl=args.jsonl_out,
    )

    print(rep.summary())
    print()
    if args.tsv:
        print(summary_tsv(reg))
    else:
        print(text_summary(reg, max_decisions=args.max_decisions))
    if reg.events_dropped:
        # also on stderr so the truncation survives `--tsv | cut`-style
        # post-processing of stdout
        print(f"WARNING: {reg.events_dropped} span events dropped past "
              f"max_events={reg.max_events}; trace/JSONL span lists are "
              f"truncated (histograms and counters stay exact)",
              file=sys.stderr)
    if args.trace_out:
        print(f"(wrote Chrome trace to {args.trace_out} — load in "
              f"ui.perfetto.dev or chrome://tracing)")
    if args.jsonl_out:
        print(f"(wrote JSONL sink to {args.jsonl_out})")

    if args.dryrun:
        ok = rep.total_records > 0 and len(reg.audit) > 0
        msg = "records+audit present" if ok else \
            "no records or empty audit trail"
        if ok and args.trace_out:
            ok, msg = validate_chrome_trace(
                args.trace_out, require_stages=DRYRUN_REQUIRED_STAGES)
        print(f"dryrun {'ok' if ok else 'FAILED'}: {msg}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
