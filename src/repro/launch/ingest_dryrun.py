import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run of the PAPER'S OWN pipeline: the distributed
graph-store ingest step (hash-owner all_to_all + local dedup/MERGE) is
lowered and compiled against the production meshes, exactly like the
LM cells.

  PYTHONPATH=src python -m repro.launch.ingest_dryrun
  PYTHONPATH=src python -m repro.launch.ingest_dryrun --multi-pod
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.graphstore.store import GraphStore, init_store, make_distributed_ingest
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--node-cap", type=int, default=1 << 22)  # 4M nodes
    ap.add_argument("--edge-cap", type=int, default=1 << 23)
    ap.add_argument("--batch", type=int, default=1 << 18)  # 256k edges/commit
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n_chips = 512 if args.multi_pod else 256

    with jax.sharding.set_mesh(mesh):
        fn = make_distributed_ingest(mesh)
        kd = jnp.uint32
        store_avals = jax.eval_shape(
            lambda: init_store(args.node_cap, args.edge_cap, key_dtype=kd)
        )
        edge_avals = [
            jax.ShapeDtypeStruct((args.batch,), kd),
            jax.ShapeDtypeStruct((args.batch,), kd),
            jax.ShapeDtypeStruct((args.batch,), jnp.int32),
            jax.ShapeDtypeStruct((args.batch,), jnp.bool_),
        ]
        jf = jax.jit(fn, donate_argnums=(0,))
        lowered = jf.lower(store_avals, *edge_avals)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    stats = analyze(compiled.as_text())
    res = {
        "mesh": "2x16x16" if args.multi_pod else "16x16",
        "batch_edges": args.batch,
        "bytes_per_device": stats.bytes,
        "collective_bytes_per_device": stats.coll_bytes,
        "collective_detail": stats.coll_detail,
        "memory": {
            "argument_size_in_bytes": int(mem.argument_size_in_bytes),
            "temp_size_in_bytes": int(mem.temp_size_in_bytes),
        },
        # throughput bound: ingest is sort+probe (memory-bound);
        # edges/s/chip = batch / (bytes/hbm_bw)
        "mem_s_per_commit": stats.bytes / 819e9,
        "coll_s_per_commit": stats.coll_bytes / 50e9,
    }
    bound = max(res["mem_s_per_commit"], res["coll_s_per_commit"])
    res["edges_per_s_fleet"] = args.batch / bound if bound else 0.0
    print(json.dumps({k: v for k, v in res.items() if k != "collective_detail"}, indent=2))
    print("memory_analysis:", mem)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
