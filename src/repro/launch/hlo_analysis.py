"""Loop-aware analysis of compiled HLO text.

XLA's HloCostAnalysis (and compiled.cost_analysis()) visits every
computation ONCE — a `lax.scan` over 126 layers contributes its body's
FLOPs/bytes/collectives a single time.  For the roofline we need the
*executed* totals, so we parse the compiled HLO text, recover each while
loop's trip count from its condition computation, and expand
(flops, bytes, collective-bytes) recursively: total(comp) =
direct(comp) + sum_while trip * total(body).

This is validated against an analytic jaxpr-level matmul-FLOP counter
(repro.launch.jaxpr_flops) in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u16|u32|s16|s8|u8|pred|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]"
)


def shape_bytes(s: str) -> int:
    """Sum bytes over every tensor shape literal appearing in `s`."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n


# --------------------------------------------------------------------------
# HLO text -> computations
# --------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def split_computations(text: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    lines = text.splitlines()
    name = None
    buf: List[str] = []
    for ln in lines:
        stripped = ln.strip()
        m = _COMP_HDR.match(ln) if not ln.startswith(" ") else None
        if m and not stripped.startswith("//"):
            if name is not None:
                comps[name] = "\n".join(buf)
            name = m.group(1)
            buf = []
        elif stripped.startswith("}"):
            if name is not None:
                comps[name] = "\n".join(buf)
                name = None
                buf = []
        elif name is not None:
            buf.append(ln)
    if name is not None:
        comps[name] = "\n".join(buf)
    return comps


# result shape may be a tuple containing layouts and /*index=N*/ comments;
# the op name is the first bare `word(` after the `=`
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$",
    re.MULTILINE,
)

_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALL_TO_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_DOT_DNUMS_RE = re.compile(
    r"lhs_contracting_dims=\{([0-9,]*)\}.*?rhs_contracting_dims=\{([0-9,]*)\}"
)
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Optional[dict] = None
    bytes_detail: Optional[dict] = None  # op kind -> bytes (loop-expanded)


def _first_shape(s: str) -> Tuple[str, str]:
    m = _SHAPE_RE.search(s)
    return (m.group(1), m.group(2)) if m else ("f32", "")


def _parse_operands(rest: str) -> List[str]:
    """Operand names from the text following the opening paren."""
    depth = 1
    out = []
    cur = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    args = "".join(cur)
    for tok in args.split(","):
        tok = tok.strip()
        m = re.match(r"%?([\w.\-]+)", tok)
        if m and not _SHAPE_RE.match(tok):
            out.append(m.group(1))
    return out


def analyze(text: str, entry: Optional[str] = None) -> CompStats:
    comps = split_computations(text)
    if not comps:
        return CompStats()
    # shape env per computation: op name -> full result-shape string
    shape_env: Dict[str, Dict[str, str]] = {}
    ops: Dict[str, List[tuple]] = {}
    for cname, body in comps.items():
        env: Dict[str, str] = {}
        lst: List[tuple] = []
        for m in _OP_RE.finditer(body):
            name, shape_s, op, rest = m.group(1), m.group(2), m.group(3), m.group(4)
            env[name] = shape_s
            line_end = body.find("\n", m.end())
            full_line = body[m.start(): line_end if line_end > 0 else len(body)]
            lst.append((name, shape_s, op, rest, full_line))
        shape_env[cname] = env
        ops[cname] = lst

    trip_memo: Dict[str, int] = {}

    def cond_trip(cond_name: str) -> int:
        if cond_name in trip_memo:
            return trip_memo[cond_name]
        body = comps.get(cond_name, "")
        consts = [int(x) for x in _CONST_CMP_RE.findall(body)]
        trip = max(consts) if consts else 1
        trip_memo[cond_name] = max(trip, 1)
        return trip_memo[cond_name]

    memo: Dict[str, CompStats] = {}

    def comp_stats(cname: str) -> CompStats:
        if cname in memo:
            return memo[cname]
        st = CompStats(coll_detail={}, bytes_detail={})
        memo[cname] = st  # break cycles
        env = shape_env.get(cname, {})
        for (name, shape_s, op, rest, line) in ops.get(cname, []):
            if op == "while":
                bm = _WHILE_BODY_RE.search(line)
                cm = _WHILE_COND_RE.search(line)
                if bm:
                    sub = comp_stats(bm.group(1))
                    trip = cond_trip(cm.group(1)) if cm else 1
                    st.flops += trip * sub.flops
                    st.bytes += trip * sub.bytes
                    st.coll_bytes += trip * sub.coll_bytes
                    for k, v in (sub.coll_detail or {}).items():
                        d = st.coll_detail.setdefault(k, {"count": 0, "bytes": 0.0})
                        d["count"] += trip * v["count"]
                        d["bytes"] += trip * v["bytes"]
                    for k, v in (sub.bytes_detail or {}).items():
                        st.bytes_detail[k] = st.bytes_detail.get(k, 0.0) + trip * v
                continue
            if op in ("call", "fusion", "reduce", "sort", "map", "conditional", "custom-call"):
                tm = _CALL_TO_RE.search(line)
                if tm and op in ("call",):
                    sub = comp_stats(tm.group(1))
                    st.flops += sub.flops
                    st.bytes += sub.bytes
                    st.coll_bytes += sub.coll_bytes
                    for k, v in (sub.coll_detail or {}).items():
                        d = st.coll_detail.setdefault(k, {"count": 0, "bytes": 0.0})
                        d["count"] += v["count"]
                        d["bytes"] += v["bytes"]
                    for k, v in (sub.bytes_detail or {}).items():
                        st.bytes_detail[k] = st.bytes_detail.get(k, 0.0) + v
                    continue
            if op == "dot":
                st.flops += _dot_flops(shape_s, rest, line, env)
                b = _io_bytes(shape_s, rest, env)
                st.bytes += b
                st.bytes_detail["dot"] = st.bytes_detail.get("dot", 0.0) + b
                continue
            if op == "convolution":
                # rare here (stub frontends); approximate as io bytes only
                st.bytes += _io_bytes(shape_s, rest, env)
                continue
            if op in COLLECTIVES or any(op == c + "-start" for c in COLLECTIVES):
                pass  # fall through to the collectives branch below
            elif op not in ("parameter", "constant", "get-tuple-element", "tuple",
                            "bitcast", "after-all", "partition-id", "replica-id",
                            "iota", "rng-bit-generator", "all-gather-done",
                            "all-reduce-done", "collective-permute-done"):
                # traffic model: operands + result of every surviving op
                # (matches XLA's bytes-accessed convention, loop-expanded)
                b = _io_bytes(shape_s, rest, env)
                # in-place update ops (cache writes, MoE scatter): XLA
                # aliases the donated buffer, so the big operand and the
                # big result are the SAME memory and only the touched
                # slice moves.  Count io minus 2x the aliased buffer.
                is_dus = op in ("dynamic-update-slice", "scatter") or (
                    op == "fusion" and re.search(
                        r'op_name="[^"]*(dynamic_update_slice|scatter)', line)
                )
                if is_dus:
                    sizes = sorted(
                        (shape_bytes(env[o]) for o in _parse_operands(rest) if o in env),
                        reverse=True,
                    )
                    if sizes:
                        b = max(b - shape_bytes(shape_s) - sizes[0], 2.0 * (sizes[1] if len(sizes) > 1 else 0))
                st.bytes += b
                if op == "fusion":
                    # small dots get fused on the CPU backend; count their
                    # FLOPs from the fusion's called computation (io bytes
                    # stay at the fusion boundary)
                    fm = _CALLS_RE.search(line)
                    if fm:
                        sub = comp_stats(fm.group(1))
                        st.flops += sub.flops
                key = op
                if op == "fusion":
                    tag = ""
                    # CPU-backend layout artifacts first (fusion NAME)
                    if re.match(r"%?(copy|bitcast|transpose)", name) or "bitcast_fusion" in name:
                        tag = ":transpose"
                    else:
                        mm = re.search(r'metadata=\{op_name="([^"]*)"', line)
                        if mm:
                            nm = mm.group(1)
                            for marker in ("transpose", "softmax", "logsumexp", "exp", "add", "mul",
                                            "dot_general", "reduce", "dynamic_update_slice", "cumsum",
                                            "scatter", "gather", "convert", "tanh", "erf", "rsqrt"):
                                if marker in nm:
                                    tag = ":" + marker
                                    break
                    key = op + tag
                st.bytes_detail[key] = st.bytes_detail.get(key, 0.0) + b
                continue
            if op in COLLECTIVES or any(op == c + "-start" for c in COLLECTIVES):
                base = op.replace("-start", "")
                nbytes = shape_bytes(shape_s)
                gm = _GROUPS_RE.search(line)
                g = len(gm.group(1).split(",")) if gm else 2
                if base == "all-reduce":
                    wire = 2.0 * (g - 1) / g * nbytes
                elif base == "all-gather":
                    wire = (g - 1) / g * nbytes
                elif base == "reduce-scatter":
                    wire = (g - 1) / g * nbytes
                elif base == "all-to-all":
                    wire = (g - 1) / g * nbytes
                else:
                    wire = float(nbytes)
                st.coll_bytes += wire
                d = st.coll_detail.setdefault(base, {"count": 0, "bytes": 0.0})
                d["count"] += 1
                d["bytes"] += wire
                b = _io_bytes(shape_s, rest, env)
                st.bytes += b
                st.bytes_detail[base] = st.bytes_detail.get(base, 0.0) + b
                continue
        return st

    def _io_bytes(shape_s: str, rest: str, env: Dict[str, str]) -> float:
        b = float(shape_bytes(shape_s))
        for opnd in _parse_operands(rest):
            if opnd in env:
                b += shape_bytes(env[opnd])
        return b

    def _dot_flops(shape_s: str, rest: str, line: str, env: Dict[str, str]) -> float:
        # result elements * 2 * contraction size
        dt, dims = _first_shape(shape_s)
        out_elems = shape_elems(dims)
        m = _DOT_DNUMS_RE.search(line)
        contract = 1
        operands = _parse_operands(rest)
        if m and operands:
            lhs_dims_s = env.get(operands[0], "")
            lm = _SHAPE_RE.search(lhs_dims_s)
            if lm:
                lhs_dims = [int(x) for x in lm.group(2).split(",") if x]
                for ci in m.group(1).split(","):
                    if ci:
                        contract *= lhs_dims[int(ci)]
        return 2.0 * out_elems * contract

    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        entry_name = m.group(1) if m else max(comps, key=lambda c: len(comps[c]))
    return comp_stats(entry_name)


def scores_chain_bytes(text: str, seq_len: int, chunk: int = None) -> float:
    """Loop-expanded io bytes of every op that touches an attention-score
    -shaped tensor (*, S, S) or (*, S, kv-chunk).

    This is the HBM traffic a flash-attention kernel keeps in VMEM on
    the TPU target: the dry-run's XLA graph materialises the softmax
    chain, the Pallas kernel (repro.kernels.flash_attention) does not.
    Used for the 'kernelized' roofline projection (EXPERIMENTS.md)."""
    dims = [str(seq_len)]
    if chunk:
        dims.append(str(chunk))
    alts = "|".join(dims)
    pat = re.compile(
        rf"\[[0-9,]*{seq_len},(?:{alts})\]|\[[0-9,]*(?:{alts}),{seq_len}\]"
    )
    total = 0.0
    for b, m, comp, op, meta, shapes_sig in _top_ops_iter(text):
        if pat.search(shapes_sig):
            total += b
    return total


def _top_ops_iter(text: str):
    comps = split_computations(text)
    shape_env = {}
    for cname, body in comps.items():
        env = {}
        for m in _OP_RE.finditer(body):
            env[m.group(1)] = m.group(2)
        shape_env[cname] = env
    mult = {c: 0 for c in comps}
    m0 = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    entry = m0.group(1) if m0 else None
    trip_cache = {}

    def cond_trip(cn):
        if cn not in trip_cache:
            consts = [int(x) for x in _CONST_CMP_RE.findall(comps.get(cn, ""))]
            trip_cache[cn] = max(consts) if consts else 1
        return trip_cache[cn]

    def walk(cname, m):
        if mult.get(cname, 0) >= m:
            return
        mult[cname] = m
        for ln in comps.get(cname, "").splitlines():
            if " while(" in ln:
                bm = _WHILE_BODY_RE.search(ln)
                cm = _WHILE_COND_RE.search(ln)
                if bm:
                    walk(bm.group(1), m * (cond_trip(cm.group(1)) if cm else 1))
            elif "to_apply=" in ln or "calls=" in ln:
                tm = _CALL_TO_RE.search(ln) or _CALLS_RE.search(ln)
                if tm:
                    walk(tm.group(1), m)

    if entry:
        walk(entry, 1)
    for cname, body in comps.items():
        m = mult.get(cname, 0)
        if m == 0:
            continue
        env = shape_env[cname]
        for om in _OP_RE.finditer(body):
            name, shape_s, op, rest = om.group(1), om.group(2), om.group(3), om.group(4)
            if op in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast", "while"):
                continue
            b = shape_bytes(shape_s)
            ops_sig = [shape_s]
            for opnd in _parse_operands(rest):
                if opnd in env:
                    b += shape_bytes(env[opnd])
                    ops_sig.append(env[opnd])
            line_end = body.find("\n", om.end())
            line = body[om.start(): line_end if line_end > 0 else len(body)]
            meta = re.search(r'op_name="([^"]*)"', line)
            yield (b * m, m, cname, op, (meta.group(1) if meta else name),
                   " ".join(ops_sig))


def top_ops(text: str, k: int = 25):
    """Per-op loop-expanded byte contributors (profiling aid for §Perf).

    Returns [(bytes, trip_multiplier, computation, op_line_prefix)]."""
    comps = split_computations(text)
    shape_env = {}
    for cname, body in comps.items():
        env = {}
        for m in _OP_RE.finditer(body):
            env[m.group(1)] = m.group(2)
        shape_env[cname] = env

    # per-computation loop multiplier: product of enclosing while trips
    mult = {c: 0 for c in comps}
    m0 = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    entry = m0.group(1) if m0 else None
    trip_cache = {}

    def cond_trip(cn):
        if cn not in trip_cache:
            consts = [int(x) for x in _CONST_CMP_RE.findall(comps.get(cn, ""))]
            trip_cache[cn] = max(consts) if consts else 1
        return trip_cache[cn]

    def walk(cname, m):
        if mult.get(cname, 0) >= m:
            return
        mult[cname] = m
        for ln in comps.get(cname, "").splitlines():
            if " while(" in ln:
                bm = _WHILE_BODY_RE.search(ln)
                cm = _WHILE_COND_RE.search(ln)
                if bm:
                    walk(bm.group(1), m * (cond_trip(cm.group(1)) if cm else 1))
            elif "to_apply=" in ln or "calls=" in ln:
                tm = _CALL_TO_RE.search(ln) or _CALLS_RE.search(ln)
                if tm:
                    walk(tm.group(1), m)

    if entry:
        walk(entry, 1)

    rows = []
    for cname, body in comps.items():
        m = mult.get(cname, 0)
        if m == 0:
            continue
        env = shape_env[cname]
        for om in _OP_RE.finditer(body):
            name, shape_s, op, rest = om.group(1), om.group(2), om.group(3), om.group(4)
            if op in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast"):
                continue
            b = shape_bytes(shape_s)
            for opnd in _parse_operands(rest):
                if opnd in env:
                    b += shape_bytes(env[opnd])
            line_end = body.find("\n", om.end())
            line = body[om.start(): line_end if line_end > 0 else len(body)]
            meta = re.search(r'op_name="([^"]*)"', line)
            rows.append((b * m, m, cname, op, (meta.group(1) if meta else name)[:110]))
    rows.sort(reverse=True)
    return rows[:k]

