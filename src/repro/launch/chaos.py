"""Chaos harness: kill/resume bit-exactness and no-batch-lost checks.

  PYTHONPATH=src python -m repro.launch.chaos --dryrun
  PYTHONPATH=src python -m repro.launch.chaos --scenario flash_crowd \
      --ticks 120 --crash-at 60 --fail-from 30 --fail-for 15

Three runs of the same (scenario, seed), all executing the SAME fault
schedule (repro.resilience.FaultPlan):

  1. reference   — uninterrupted, crash removed (`plan.without_crash()`)
  2. chaos       — checkpoints every N ticks, killed at `--crash-at`
                   (`PipelineKilled` raised mid-run)
  3. resume      — restores the latest checkpoint, runs the remaining
                   ticks with the crash-free plan

and then verifies the resilience contract:

  * BIT-EXACT: resumed store and CSR snapshot digests equal the
    reference run's (everything downstream of (scenario, seed) is
    counter-deterministic, and the checkpoint captured all of it);
  * NO BATCH LOST: `archived_total == retries_replayed +
    archive_remaining` — every failed/diverted batch is either
    replayed into the store or still accounted for in the archive;
  * NO HOT LOOP: commit failures during the outage stay logarithmic
    in the outage length (the capped-exponential backoff gate held),
    far under the one-failure-per-tick a gateless retry would burn.

`--dryrun` shrinks everything to CI size and exits nonzero on any
violated invariant.  x64 on for exact 64-bit node identity.
"""
import jax

jax.config.update("jax_enable_x64", True)

import argparse
import json
import math
import os
import shutil
import tempfile


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="flash_crowd")
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=60,
                    help="kill the pipeline after this tick")
    ap.add_argument("--checkpoint-every", type=int, default=16)
    ap.add_argument("--fail-from", type=float, default=30.0,
                    help="simulated time the store outage starts")
    ap.add_argument("--fail-for", type=float, default=15.0,
                    help="outage duration in simulated seconds")
    ap.add_argument("--node-cap", type=int, default=None)
    ap.add_argument("--edge-cap", type=int, default=None)
    ap.add_argument("--dir", default=None,
                    help="working directory (checkpoints + spill); "
                         "a temp dir is created and removed by default")
    ap.add_argument("--json", default=None, help="write the verdict here")
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny end-to-end run (CI smoke)")
    args = ap.parse_args(argv)

    from repro.resilience import FaultPlan, PipelineKilled, RetryPolicy
    from repro.workloads import run_scenario

    if args.dryrun:
        args.ticks = min(args.ticks, 48)
        args.crash_at = min(args.crash_at, args.ticks // 2)
        args.checkpoint_every = min(args.checkpoint_every, 8)
        args.fail_from = min(args.fail_from, 10.0)
        args.fail_for = min(args.fail_for, 8.0)
        args.node_cap = args.node_cap or 1 << 12
        args.edge_cap = args.edge_cap or 1 << 14

    plan = FaultPlan(
        fail_times=((args.fail_from, args.fail_from + args.fail_for),),
        crash_at_tick=args.crash_at,
    )
    policy = RetryPolicy()

    work = args.dir or tempfile.mkdtemp(prefix="repro_chaos_")
    ckpt_dir = os.path.join(work, "ckpt")
    common = dict(ticks=args.ticks, seed=args.seed,
                  node_cap=args.node_cap, edge_cap=args.edge_cap,
                  retry=policy, checkpoint_every=args.checkpoint_every)

    print(f"[1/3] reference: {args.scenario} x{args.ticks} ticks, outage "
          f"t=[{args.fail_from}, {args.fail_from + args.fail_for})")
    ref = run_scenario(args.scenario, fault_plan=plan.without_crash(),
                       spill_dir=os.path.join(work, "spill_ref"), **common)

    print(f"[2/3] chaos: same run, checkpoint every "
          f"{args.checkpoint_every}, kill at tick {args.crash_at}")
    killed_at = None
    try:
        run_scenario(args.scenario, fault_plan=plan,
                     checkpoint_dir=ckpt_dir,
                     spill_dir=os.path.join(work, "spill_chaos"), **common)
    except PipelineKilled as pk:
        killed_at = pk.tick
    if killed_at is None:
        print("FAIL: crash_at_tick never fired")
        return 1

    print(f"[3/3] resume: killed at tick {killed_at}, restoring latest "
          f"checkpoint from {ckpt_dir}")
    res = run_scenario(args.scenario, fault_plan=plan.without_crash(),
                       checkpoint_dir=ckpt_dir, resume=True,
                       spill_dir=os.path.join(work, "spill_chaos"), **common)

    # ---- verdict --------------------------------------------------------
    checks = {}
    checks["bit_exact_store"] = res.store_digest == ref.store_digest
    checks["bit_exact_snapshot"] = res.snapshot_digest == ref.snapshot_digest
    checks["records_equal"] = res.total_records == ref.total_records
    checks["no_batch_lost"] = (
        res.archived_total == res.retries_replayed + res.archive_remaining)
    # backoff held: failures stay logarithmic in the outage length.  A
    # gateless retry fails ~once per tick (~fail_for failures plus the
    # pool drain); the capped-exponential gate allows degrade_after
    # probes, then one per gate opening — O(log2(W/base)).
    allowed = (3  # default degrade_after
               + 2 * (math.log2(max(args.fail_for, 1.0)
                                / policy.base_s) + 2))
    checks["backoff_not_hot"] = 0 < res.commit_failures <= allowed
    checks["resumed_mid_run"] = 0 < res.resumed_from_tick <= killed_at

    verdict = {
        "killed_at": killed_at,
        "resumed_from": res.resumed_from_tick,
        "ref": {"records": ref.total_records,
                "store_digest": ref.store_digest,
                "snapshot_digest": ref.snapshot_digest,
                "commit_failures": ref.commit_failures,
                "replayed": ref.retries_replayed},
        "resumed": {"records": res.total_records,
                    "store_digest": res.store_digest,
                    "snapshot_digest": res.snapshot_digest,
                    "commit_failures": res.commit_failures,
                    "replayed": res.retries_replayed,
                    "archived_total": res.archived_total,
                    "archive_remaining": res.archive_remaining,
                    "pool_overflows": res.pool_overflows,
                    "degraded_events": res.degraded_events,
                    "checkpoints_saved": res.checkpoints_saved},
        "max_failures_allowed": allowed,
        "checks": checks,
        "ok": all(checks.values()),
    }

    for name, ok in checks.items():
        print(f"  {'PASS' if ok else 'FAIL'}  {name}")
    print(f"store: {res.store_digest[:16]}... vs {ref.store_digest[:16]}... "
          f"| replayed={res.retries_replayed} "
          f"archive_remaining={res.archive_remaining} "
          f"failures={res.commit_failures} (allowed {allowed:.1f})")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(verdict, f, indent=2)
        print(f"(wrote verdict to {args.json})")
    if args.dir is None:
        shutil.rmtree(work, ignore_errors=True)

    print(f"chaos {'ok' if verdict['ok'] else 'FAILED'}")
    return 0 if verdict["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
