"""Batched serving driver: prefill a batch of prompts, then decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.tokenizer import HashTokenizer
from repro.distributed.sharding import init_params
from repro.models import model as M
from repro.train.trainstep import make_serve_step


def pad_cache_to(cache, total_len: int):
    """Grow prefill caches (length=prompt) to the serving horizon."""
    def f(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 5:
            pad = total_len - x.shape[2]
            if pad > 0:
                return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return x
    return jax.tree_util.tree_map_with_path(f, cache)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)

    tok = HashTokenizer(cfg.vocab_size)
    prompts = [f"user{i} says politics election vote #topic{i%3}" for i in range(args.batch)]
    tokens = tok.encode_batch(prompts, args.prompt_len)
    params = init_params(M.param_specs(cfg), jax.random.key(0), dtype_override=cfg.dtype)

    batch = {"tokens": jnp.asarray(tokens)}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    logits, cache = M.prefill(params, cfg, batch)
    total = args.prompt_len + (cfg.num_patches or 0) + args.gen
    cache = pad_cache_to(cache, total)
    t_prefill = time.time() - t0

    serve = jax.jit(make_serve_step(cfg), donate_argnums=1)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out_tokens = [np.asarray(next_tok)]
    t0 = time.time()
    pos0 = args.prompt_len + (cfg.num_patches or 0)
    for i in range(args.gen - 1):
        next_tok, cache = serve(params, cache, next_tok, jnp.int32(pos0 + i))
        out_tokens.append(np.asarray(next_tok))
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.prompt_len} toks x {args.batch} seqs: {t_prefill*1e3:.1f} ms")
    print(f"decode  {args.gen-1} steps: {t_decode*1e3:.1f} ms  ({tps:.1f} tok/s)")
    print("generated ids[0][:8]:", gen[0][:8].tolist())
    return gen


if __name__ == "__main__":
    main()
