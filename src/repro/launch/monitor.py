"""Health-monitor entrypoint: run a scenario under the online judge,
or gate the repo's perf trajectory.

  PYTHONPATH=src python -m repro.launch.monitor --scenario flash_crowd
  PYTHONPATH=src python -m repro.launch.monitor --scenario spam_storm \
      --shards 4 --live --prom-out metrics.prom --report-out monitor.json
  PYTHONPATH=src python -m repro.launch.monitor --dryrun
  PYTHONPATH=src python -m repro.launch.monitor regression --baseline 0
  PYTHONPATH=src python -m repro.launch.monitor regression \
      --inject commit_ms_mean --inject-factor 2.0   # gate self-test

`run` (the default command) drives a registry scenario with telemetry
+ the `repro.monitor.HealthMonitor` attached and prints the monitor
verdict: detector onsets with ticks, per-SLO budget/burn accounting,
and the controller decision-quality score.  `--live` repaints a
terminal dashboard every `--refresh` ticks while the run is in
flight; `--prom-out` writes Prometheus text exposition and
`--report-out` the JSON verdict (the CI artifact).  `--dryrun` is the
CI smoke: a small flash_crowd run that exits nonzero unless the burst
produced at least one health event and the SLO summary is populated.

`regression` is the automated perf gate: diff a candidate run of
BENCH_ingest.json (default: latest) against a baseline run (default:
run 0) with noise-tolerant thresholds and exit nonzero on regression.
`--inject METRIC` multiplies that candidate metric by
`--inject-factor` before judgment — the synthetic-regression path CI
uses to prove the gate actually trips.  x64 is enabled for exact
64-bit node identity (as in launch.ingest) — but only under
``python -m``: importing this module (tests drive `main` directly)
must not flip global jax config for the rest of the process.
"""
import argparse
import json
import sys


def _run(args) -> int:
    from repro.monitor import (
        HealthMonitor,
        render_dashboard,
        text_report,
        write_prometheus,
    )
    from repro.telemetry import TelemetryRegistry
    from repro.workloads import run_scenario

    if args.dryrun:
        args.ticks = min(args.ticks or 60, 60)
        args.node_cap = args.node_cap or 1 << 12
        args.edge_cap = args.edge_cap or 1 << 14

    def _frame(mon, tick, values):
        if not args.live or tick % args.refresh:
            return
        out = render_dashboard(mon)
        if sys.stdout.isatty():
            sys.stdout.write("\x1b[2J\x1b[H" + out + "\n")
        else:
            sys.stdout.write(out + "\n\n")
        sys.stdout.flush()

    reg = TelemetryRegistry()
    mon = HealthMonitor(on_tick=_frame)
    rep = run_scenario(
        args.scenario,
        ticks=args.ticks,
        seed=args.seed,
        shards=args.shards,
        speed=args.speed,
        sketch_guided=args.sketch_control,
        dict_compress=args.dict_compress,
        node_cap=args.node_cap,
        edge_cap=args.edge_cap,
        telemetry=reg,
        monitor=mon,
    )

    print(rep.summary())
    print()
    print(text_report(mon))

    if args.report_out:
        payload = {"scenario": args.scenario, "seed": args.seed,
                   "shards": args.shards, **mon.report()}
        with open(args.report_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"(wrote monitor report to {args.report_out})")
    if args.prom_out:
        write_prometheus(args.prom_out, monitor=mon, registry=reg)
        print(f"(wrote Prometheus exposition to {args.prom_out})")

    if args.dryrun:
        mrep = mon.report()
        checks = {
            "records": rep.total_records > 0,
            "burst health event": any(
                e["series"] == "rate" and e["phase"] == "onset"
                for e in mrep["health_events"]),
            "slo summary populated": len(mrep["slo"]) > 0
            and all("budget_consumed" in s for s in mrep["slo"].values()),
            "quality scored": mrep["quality"].get("decisions", 0) > 0,
            "report serialises": bool(json.dumps(mrep)),
        }
        failed = [name for name, ok in checks.items() if not ok]
        print(f"dryrun {'ok' if not failed else 'FAILED'}"
              + (f": missing {', '.join(failed)}" if failed else ""))
        return 0 if not failed else 1
    return 0


def _regression(args) -> int:
    from repro.monitor import format_verdict, gate

    mutate = None
    if args.inject:
        metric, factor = args.inject, args.inject_factor

        def mutate(m):
            if metric not in m:
                raise SystemExit(
                    f"--inject {metric}: metric not present in the "
                    f"candidate run (have: {', '.join(sorted(m)) or 'none'})")
            m[metric] *= factor
        print(f"(injecting synthetic regression: {metric} x{factor})")

    try:
        verdict = gate(args.bench, baseline=args.baseline,
                       candidate=args.candidate, mutate=mutate)
    except (OSError, ValueError, IndexError) as e:
        print(f"perf gate: cannot run: {e}", file=sys.stderr)
        return 2
    print(format_verdict(verdict))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(verdict, f, indent=2)
        print(f"(wrote gate verdict to {args.json})")
    return 0 if verdict["ok"] else 1


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="online health monitoring + the perf-regression gate")
    ap.add_argument("command", nargs="?", default="run",
                    choices=("run", "regression"),
                    help="run a monitored scenario (default) or gate "
                         "BENCH_ingest.json")
    # run options
    ap.add_argument("--scenario", default="flash_crowd")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--speed", type=float, default=0.5)
    ap.add_argument("--sketch-control", action="store_true")
    ap.add_argument("--dict-compress", action="store_true")
    ap.add_argument("--node-cap", type=int, default=None)
    ap.add_argument("--edge-cap", type=int, default=None)
    ap.add_argument("--live", action="store_true",
                    help="repaint the terminal dashboard during the run")
    ap.add_argument("--refresh", type=int, default=10,
                    help="dashboard repaint period in ticks (with --live)")
    ap.add_argument("--report-out", default=None,
                    help="write the JSON monitor verdict here (CI artifact)")
    ap.add_argument("--prom-out", default=None,
                    help="write Prometheus text exposition here")
    ap.add_argument("--dryrun", action="store_true",
                    help="small flash_crowd run + verdict checks (CI smoke)")
    # regression options
    ap.add_argument("--bench", default="BENCH_ingest.json",
                    help="perf-trajectory file (merge-appended runs)")
    ap.add_argument("--baseline", type=int, default=0,
                    help="baseline run index (default 0, the oldest)")
    ap.add_argument("--candidate", type=int, default=-1,
                    help="candidate run index (default -1, the latest)")
    ap.add_argument("--inject", default=None, metavar="METRIC",
                    help="multiply this candidate metric by "
                         "--inject-factor before judging (gate self-test)")
    ap.add_argument("--inject-factor", type=float, default=2.0)
    ap.add_argument("--json", default=None,
                    help="(regression) write the gate verdict dict here")
    args = ap.parse_args(argv)

    if args.command == "regression":
        return _regression(args)
    return _run(args)


if __name__ == "__main__":
    import jax

    jax.config.update("jax_enable_x64", True)
    raise SystemExit(main())
