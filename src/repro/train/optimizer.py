"""AdamW with global-norm clipping and cosine schedule — built here, not
imported (no optax dependency).  Optimizer state shares the parameter
tree structure so it inherits parameter sharding (ZeRO: m/v are sharded
exactly like the FSDP params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = oc.lr * step / max(oc.warmup_steps, 1)
    prog = jnp.clip(
        (step - oc.warmup_steps) / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0
    )
    cos = oc.lr * (oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


# ---------------------------------------------------------------------------
# int8 moment quantisation (8-bit Adam): per-trailing-row symmetric scales.
# Row-wise (last axis) scales keep the scale tensor sharded exactly like the
# parameter minus its last dim — no cross-shard blocks.
# ---------------------------------------------------------------------------


def quant_rowwise(x: jax.Array):
    ax = -1 if x.ndim else None
    scale = jnp.max(jnp.abs(x), axis=ax, keepdims=x.ndim > 0) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequant_rowwise(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_opt_state(params, state_dtype: str = "float32") -> Dict[str, Any]:
    if state_dtype == "int8":
        def zq(p):
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "s": jnp.zeros(p.shape[:-1] + (1,) if p.ndim else (), jnp.float32),
            }

        return {
            "m": jax.tree.map(zq, params),
            "v": jax.tree.map(zq, params),
            "step": jnp.zeros((), jnp.int32),
        }
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(oc: OptConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(oc, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))
    sf32 = step.astype(jnp.float32)
    bc1 = 1.0 - oc.b1 ** sf32
    bc2 = 1.0 - oc.b2 ** sf32

    def upd_flat(p, g, mf, vf, decay: bool):
        g = g.astype(jnp.float32) * scale
        mf = oc.b1 * mf + (1 - oc.b1) * g
        vf = oc.b2 * vf + (1 - oc.b2) * jnp.square(g)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        pf = p.astype(jnp.float32)
        if decay:  # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * pf
        return (pf - lr * delta).astype(p.dtype), mf, vf

    def upd(p, g, m, v):
        # NOTE: a lax.map-chunked variant over the layer dim was tried to
        # shrink fp32 transients and REFUTED: the loop bufferisation cost
        # +13 GiB instead (EXPERIMENTS.md §Perf l4). Keep the flat form.
        decay = p.ndim >= 2
        if isinstance(m, dict):  # 8-bit Adam: dequant -> update -> requant
            mf = dequant_rowwise(m["q"], m["s"])
            vf = jnp.abs(dequant_rowwise(v["q"], v["s"]))  # v >= 0
            np_, mf, vf = upd_flat(p, g, mf, vf, decay)
            mq, ms = quant_rowwise(mf)
            vq, vs = quant_rowwise(vf)
            return np_, {"q": mq, "s": ms}, {"q": vq, "s": vs}
        sdt = m.dtype  # fp32 / bf16 moments
        np_, mf, vf = upd_flat(p, g, m.astype(jnp.float32), v.astype(jnp.float32), decay)
        return np_, mf.astype(sdt), vf.astype(sdt)

    is_qleaf = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "s"}
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_qleaf)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_qleaf)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
