"""Step-atomic sharded checkpointing with background writes.

Layout:
  <dir>/step_<N>/
    manifest.json        # step, leaf index, shapes/dtypes, mesh shape
    <leafkey>.npy        # one file per state leaf
    _COMMITTED           # written last: restore ignores torn checkpoints

Checkpoints are mesh-agnostic (leaves stored unsharded), so restore can
re-shard onto a *different* mesh — that is what makes elastic re-mesh
after a node failure possible (distributed/fault.py).  A background
thread does the writes; `wait()` joins before the next save (bounded
staleness of one step, standard async-checkpoint posture).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False, extra: Optional[Dict] = None):
        """Snapshot to host then write in the background (step-atomic)."""
        self.wait()
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        host = [(_leaf_key(p), np.asarray(jax.device_get(v))) for p, v in leaves]

        def write():
            d = os.path.join(self.dir, f"step_{step:08d}")
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": [], "extra": extra or {}}
            for key, arr in host:
                fn = key.replace("/", "_") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"].append(
                    {"key": key, "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(d):
                shutil.rmtree(d)
            os.rename(tmp, d)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "_COMMITTED")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Any:
        """Restore into `template`'s treedef; optionally re-shard onto a
        (possibly different) mesh via `shardings`."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        files = {l["key"]: l["file"] for l in manifest["leaves"]}
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, tmpl in paths:
            key = _leaf_key(p)
            arr = np.load(os.path.join(d, files[key]))
            leaves.append(arr)
        flat_sh = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
        out = []
        for arr, sh in zip(leaves, flat_sh):
            out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
