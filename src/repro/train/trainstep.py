"""Microbatched train step and serving steps.

`make_train_step(cfg, shape, dp)` returns a pure function
    train_step(state, batch) -> (new_state, metrics)
where the global batch is reshaped to (accum, micro_global, ...) and a
`lax.scan` accumulates fp32 gradients — activation memory is bounded by
one microbatch regardless of global batch size.  Gradient compression
(int8 / top-k with error feedback) hooks in between accumulation and the
optimizer; see repro.distributed.grad_compression.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import shard
from repro.models import model as M
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def make_state_specs(cfg: ModelConfig):
    """ParamSpec tree of the full train state (params + adam m/v + step)."""
    from repro.distributed.sharding import ParamSpec

    ps = M.param_specs(cfg)

    def pdt(p):
        return ParamSpec(p.shape, p.logical, init=p.init, scale=p.scale, dtype=cfg.param_dtype)

    def sdt(p):
        if cfg.opt_state_dtype == "int8":  # 8-bit Adam: q + row scales
            return {
                "q": ParamSpec(p.shape, p.logical, init="zeros", dtype="int8"),
                "s": ParamSpec(
                    (p.shape[:-1] + (1,)) if p.shape else (),
                    (p.logical[:-1] + (None,)) if p.shape else (),
                    init="zeros", dtype="float32",
                ),
            }
        return ParamSpec(p.shape, p.logical, init="zeros", dtype=cfg.opt_state_dtype)

    leaf = lambda x: isinstance(x, ParamSpec)
    return {
        "params": jax.tree.map(pdt, ps, is_leaf=leaf),
        "opt": {
            "m": jax.tree.map(sdt, ps, is_leaf=leaf),
            "v": jax.tree.map(sdt, ps, is_leaf=leaf),
            "step": ParamSpec((), (), init="zeros", dtype="int32"),
        },
    }


def init_state(cfg: ModelConfig, key):
    from repro.distributed.sharding import init_params

    params = init_params(M.param_specs(cfg), key, dtype_override=cfg.param_dtype)
    return {"params": params, "opt": init_opt_state(params, cfg.opt_state_dtype)}


def _split_microbatches(batch: Dict, accum: int):
    def rs(x):
        y = x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
        # keep the microbatch dim data-sharded; the one-time reshard of the
        # (tiny, int32) token arrays is negligible
        return shard(y, (None, "batch") + (None,) * (y.ndim - 2))

    return jax.tree.map(rs, batch)


def make_train_step(
    cfg: ModelConfig,
    shape: ShapeSpec,
    dp: int,
    oc: Optional[OptConfig] = None,
    grad_compressor=None,
):
    oc = oc or OptConfig()
    mb = cfg.auto_microbatch(shape, dp)
    per_dp = max(1, shape.global_batch // dp)
    accum = max(1, per_dp // mb)

    def train_step(state, batch):
        params = state["params"]
        mbs = _split_microbatches(batch, accum)

        def gfn(p, microbatch):
            (loss, metrics), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(
                p, cfg, microbatch
            )
            return grads, loss, metrics

        gdt = jnp.dtype(cfg.grad_accum_dtype)

        def body(carry, microbatch):
            acc_g, acc_loss = carry
            grads, loss, _ = gfn(params, microbatch)
            acc_g = jax.tree.map(lambda a, g: a + g.astype(gdt), acc_g, grads)
            return (acc_g, acc_loss + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / accum, grads)
        loss = loss_sum / accum

        if grad_compressor is not None:
            grads = grad_compressor(grads)

        new_params, new_opt, om = adamw_update(oc, params, grads, state["opt"])
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step, {"accum": accum, "microbatch": mb}


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: new token for every sequence in the batch."""

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = M.decode_step(params, cfg, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step
