"""`repro.monitor` — online health monitoring over the ingest->query
path (ISSUE 9): the layer that turns PR-7 telemetry into verdicts.

  * `detectors` — streaming EWMA z-score + Page–Hinkley change-point
    detection over per-tick series, emitting `HealthEvent`s with
    onset/clear semantics (a flash-crowd onset is detected and
    timestamped during the run, not found in a post-hoc log grep).
  * `slo` — declarative SLO specs with error budgets and multi-window
    burn-rate alerts, evaluated every tick.
  * `quality` — controller decision-quality scoring from the audit
    trail: predicted-vs-realized error, regret vs a do-nothing
    baseline, one controller score per run.
  * `monitor` — `HealthMonitor`, the standing evaluator wired into a
    pipeline via `PipelineBuilder.with_monitor()` (or
    `run_scenario(..., monitor=True)`).
  * `export` — Prometheus text exposition + the live terminal
    dashboard.
  * `regression` — the automated perf gate over BENCH_ingest.json.

Quickstart::

    from repro.monitor import HealthMonitor
    mon = HealthMonitor()
    pipe = (PipelineBuilder(cfg).with_source(src)
            .with_monitor(mon).build())
    pipe.run(max_ticks=300)
    print(mon.report()["controller_score"], mon.burst_onset_tick())

CLI: ``python -m repro.launch.monitor --scenario flash_crowd`` and
``python -m repro.launch.monitor regression --baseline 0``.
"""
from repro.monitor.detectors import (
    DEFAULT_SERIES,
    DetectorBank,
    EwmaDetector,
    HealthEvent,
    PageHinkley,
    SeriesSpec,
)
from repro.monitor.export import (
    prometheus_text,
    render_dashboard,
    text_report,
    write_prometheus,
)
from repro.monitor.monitor import SERIES_KEYS, HealthMonitor
from repro.monitor.quality import per_action_scores, score_record, score_trail
from repro.monitor.regression import (
    METRICS,
    MetricSpec,
    compare_runs,
    extract_metrics,
    format_verdict,
    gate,
    load_runs,
)
from repro.monitor.slo import SLOSpec, SLOTracker, default_slos

__all__ = [
    "DEFAULT_SERIES",
    "DetectorBank",
    "EwmaDetector",
    "HealthEvent",
    "HealthMonitor",
    "METRICS",
    "MetricSpec",
    "PageHinkley",
    "SERIES_KEYS",
    "SLOSpec",
    "SLOTracker",
    "SeriesSpec",
    "compare_runs",
    "default_slos",
    "extract_metrics",
    "format_verdict",
    "gate",
    "load_runs",
    "per_action_scores",
    "prometheus_text",
    "render_dashboard",
    "score_record",
    "score_trail",
    "text_report",
    "write_prometheus",
]
