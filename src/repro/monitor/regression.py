"""Automated perf-regression gate over BENCH_ingest.json.

The benchmark harness merge-appends one run entry per `--json`
invocation, so the file IS the repo's perf trajectory.  This module
turns it into a gate: extract a flat metric vector from a run entry,
compare a candidate run against a baseline run with **noise-tolerant
thresholds** (relative tolerance per metric class plus an absolute
floor, so a 2 ms -> 3 ms flutter on a tiny metric does not fail the
build), and report pass/fail per metric.  `repro.launch.monitor
regression` wraps this with a nonzero exit on regression — the CI
perf gate.

Metric classes:

  * lower-is-better (latencies, drops, overhead): regress when
    candidate > baseline * (1 + tol) and candidate - baseline > floor.
  * higher-is-better (throughputs, scores): regress when
    candidate < baseline * (1 - tol) and baseline - candidate > floor.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional, Tuple

# default relative tolerance: wall-clock benches on shared CI hosts
# are noisy; 35% headroom holds the gate to real regressions (a 2x
# injected slowdown still trips it with 3x margin)
DEFAULT_TOL = 0.35


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One gated metric: where it lives in a run entry and how to
    judge movement."""

    name: str
    path: Tuple              # keys into run["benches"], traversed safely
    higher_better: bool = False
    tol: float = DEFAULT_TOL
    floor: float = 0.0       # ignore absolute moves smaller than this


def _dig(obj, path: Tuple):
    for k in path:
        if isinstance(obj, dict):
            obj = obj.get(k)
        elif isinstance(obj, (list, tuple)) and isinstance(k, int) \
                and -len(obj) <= k < len(obj):
            obj = obj[k]
        else:
            return None
        if obj is None:
            return None
    return obj


METRICS: Tuple[MetricSpec, ...] = (
    MetricSpec("commit_ms_mean",
               ("ingest_trajectory", "derived", "commit_ms_mean"),
               floor=2.0),
    MetricSpec("dropped_total",
               ("ingest_trajectory", "derived", "dropped_total"),
               tol=0.5, floor=256.0),
    MetricSpec("probe_rounds_max",
               ("ingest_trajectory", "derived", "probe_rounds_max"),
               tol=0.5, floor=8.0),
    MetricSpec("store_ingest_us_per_commit",
               ("store_ingest", "rows", 0, "us_per_commit"),
               floor=200.0),
    MetricSpec("workload_max_records_per_stream_s",
               ("workload_scenarios", "derived", "max_records_per_stream_s"),
               higher_better=True, floor=5.0),
    MetricSpec("telemetry_overhead_pct",
               ("telemetry_overhead", "derived", "overhead_pct"),
               tol=1.0, floor=3.0),
    MetricSpec("monitor_overhead_pct",
               ("monitor_overhead", "derived", "overhead_pct"),
               tol=1.0, floor=3.0),
    MetricSpec("controller_score",
               ("monitor_overhead", "derived", "controller_score"),
               higher_better=True, tol=0.15, floor=0.05),
    # freshness SLIs (repro.lineage): lag values are stream-time and
    # deterministic per seed, so the tolerances guard real semantic
    # drift (a batch routed through a slower path), not host noise
    MetricSpec("queryable_lag_ms_p99",
               ("lineage_freshness", "derived", "queryable_lag_ms_p99"),
               tol=0.5, floor=1000.0),
    MetricSpec("ingest_lag_ms_p50",
               ("lineage_freshness", "derived", "ingest_lag_ms_p50"),
               tol=0.5, floor=500.0),
    MetricSpec("lineage_overhead_pct",
               ("lineage_overhead", "derived", "overhead_pct"),
               tol=1.0, floor=3.0),
)


def extract_metrics(run_entry: Dict,
                    metrics: Tuple[MetricSpec, ...] = METRICS
                    ) -> Dict[str, float]:
    """Flat {metric: value} for one run entry; absent benches are
    skipped (older runs predate newer benches)."""
    benches = run_entry.get("benches", run_entry)
    out: Dict[str, float] = {}
    for m in metrics:
        v = _dig(benches, m.path)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[m.name] = float(v)
    return out


def judge(spec: MetricSpec, base: float, cand: float) -> Dict:
    """One metric verdict: regressed / improved / stable."""
    if spec.higher_better:
        delta = base - cand   # positive = got worse
        regressed = cand < base * (1.0 - spec.tol) and delta > spec.floor
        improved = cand > base * (1.0 + spec.tol) and -delta > spec.floor
    else:
        delta = cand - base
        regressed = cand > base * (1.0 + spec.tol) and delta > spec.floor
        improved = cand < base * (1.0 - spec.tol) and -delta > spec.floor
    ratio = cand / base if base else float("inf") if cand else 1.0
    return {
        "metric": spec.name,
        "baseline": base,
        "candidate": cand,
        "ratio": round(ratio, 4),
        "tol": spec.tol,
        "higher_better": spec.higher_better,
        "verdict": ("regressed" if regressed
                    else "improved" if improved else "stable"),
    }


def load_runs(path: str) -> List[Dict]:
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict) and isinstance(data.get("runs"), list):
        return data["runs"]
    if isinstance(data, dict) and data:
        return [{"run": 0, "benches": data}]  # legacy single-run file
    raise ValueError(f"{path}: no runs found")


def compare_runs(baseline: Dict, candidate: Dict,
                 metrics: Tuple[MetricSpec, ...] = METRICS,
                 mutate: Optional[Callable[[Dict[str, float]], None]] = None
                 ) -> Dict:
    """Gate verdict comparing two run entries.  `mutate` (tests /
    --inject) edits the candidate metric vector before judgment —
    how the gate's own alarm path is exercised in CI."""
    base_m = extract_metrics(baseline, metrics)
    cand_m = extract_metrics(candidate, metrics)
    if mutate is not None:
        mutate(cand_m)
    spec_by_name = {m.name: m for m in metrics}
    rows = [judge(spec_by_name[name], base_m[name], cand_m[name])
            for name in sorted(set(base_m) & set(cand_m))]
    regressed = [r for r in rows if r["verdict"] == "regressed"]
    return {
        "baseline_run": baseline.get("run"),
        "candidate_run": candidate.get("run"),
        "compared": len(rows),
        "skipped": sorted((set(base_m) ^ set(cand_m))
                          | (set(spec_by_name) - set(base_m) - set(cand_m))),
        "rows": rows,
        "regressions": [r["metric"] for r in regressed],
        "ok": not regressed,
    }


def gate(bench_path: str, baseline: int = 0, candidate: int = -1,
         metrics: Tuple[MetricSpec, ...] = METRICS,
         mutate: Optional[Callable] = None) -> Dict:
    """Load BENCH_ingest.json and compare run `candidate` (default:
    latest) against run `baseline` (default: 0, the committed seed)."""
    runs = load_runs(bench_path)
    if not runs:
        raise ValueError(f"{bench_path}: empty trajectory")
    n = len(runs)

    def _idx(i: int) -> int:
        i = i if i >= 0 else n + i
        if not 0 <= i < n:
            raise IndexError(f"run index {i} out of range (have {n})")
        return i

    bi, ci = _idx(baseline), _idx(candidate)
    verdict = compare_runs(runs[bi], runs[ci], metrics, mutate=mutate)
    verdict["bench_path"] = os.path.abspath(bench_path)
    verdict["runs_in_trajectory"] = n
    return verdict


def format_verdict(v: Dict) -> str:
    out = [f"perf gate: run {v['candidate_run']} vs baseline run "
           f"{v['baseline_run']} ({v['compared']} metrics, "
           f"{len(v['skipped'])} skipped)"]
    for r in v["rows"]:
        mark = {"regressed": "FAIL", "improved": "gain",
                "stable": " ok "}[r["verdict"]]
        arrow = "^" if r["higher_better"] else "v"
        out.append(
            f"  [{mark}] {r['metric']:<36} {r['baseline']:>12.3f} -> "
            f"{r['candidate']:>12.3f}  (x{r['ratio']:.2f}, "
            f"tol {r['tol']:.0%} {arrow})")
    if v["skipped"]:
        out.append(f"  (skipped, not in both runs: "
                   f"{', '.join(v['skipped'])})")
    out.append("verdict: " + ("OK — no perf regression" if v["ok"] else
                              f"REGRESSED: {', '.join(v['regressions'])}"))
    return "\n".join(out)
