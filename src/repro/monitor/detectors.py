"""Streaming anomaly / change-point detection over per-tick series.

The paper's premise (§III) is that ingestion only survives bursty
social streams when the system *judges* its own signals online —
data rate, data content, machine resources — instead of grepping
logs after the database has fallen over.  This module is that judge:
two classic O(1)-state sequential detectors run side by side on each
tapped series and emit typed `HealthEvent`s with **onset/clear**
semantics, so a flash-crowd onset is detected and timestamped while
the run is still in flight.

  * `EwmaDetector` — exponentially weighted mean/variance with a
    z-score alarm and hysteresis (`z_on`/`z_off`, consecutive-tick
    confirmation) so a single noisy tick neither fires nor clears an
    alert.
  * `PageHinkley` — the Page–Hinkley cumulative-deviation test on the
    *normalized* residual (z-score), so one lambda works across series
    of wildly different scales (records/tick vs. milliseconds vs.
    queue depths).  Detects sustained level shifts the EWMA z-score
    adapts past.

Both are **counter-deterministic**: pure arithmetic on the values they
are fed, no wall clock, no RNG — the same per-tick series always
yields the same events, which is what makes the detector fixtures in
tests/test_monitor.py exact.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HealthEvent:
    """One detector verdict boundary: an alert turning on or off."""

    series: str      # which per-tick series ("rate", "commit_ms", ...)
    detector: str    # "ewma" | "page_hinkley"
    phase: str       # "onset" | "clear"
    tick: int        # tick index the boundary was detected at
    t: float         # stream time of that tick
    value: float     # the observed value that crossed
    score: float     # z-score (ewma) or PH statistic at the boundary
    threshold: float  # the limit it crossed

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        arrow = "!" if self.phase == "onset" else "ok"
        return (f"[{arrow}] t={self.t:.1f} tick={self.tick} "
                f"{self.series}/{self.detector} {self.phase} "
                f"value={self.value:.3g} score={self.score:.2f} "
                f"(limit {self.threshold:.2f})")


class EwmaDetector:
    """EWMA z-score anomaly detector with onset/clear hysteresis.

    State is five floats and three small ints; `update` is O(1).  The
    alarm arms after `warmup` samples, fires when |z| >= `z_on` for
    `k_on` consecutive ticks (one-sided when `direction` is +1/-1),
    and clears when |z| <= `z_off` for `k_off` consecutive ticks —
    the EWMA keeps adapting throughout, so a decaying burst clears on
    its own once the baseline catches up.
    """

    def __init__(self, alpha: float = 0.15, z_on: float = 4.0,
                 z_off: float = 1.5, warmup: int = 8,
                 k_on: int = 1, k_off: int = 3,
                 direction: int = 0, min_std: float = 1e-9):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = float(alpha)
        self.z_on = float(z_on)
        self.z_off = float(z_off)
        self.warmup = int(warmup)
        self.k_on = max(1, int(k_on))
        self.k_off = max(1, int(k_off))
        self.direction = int(direction)  # 0 = two-sided
        self.min_std = float(min_std)
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.z = 0.0
        self.active = False
        self._on_streak = 0
        self._off_streak = 0

    def _signed(self, z: float) -> float:
        """The alarm-relevant magnitude of z given the direction."""
        if self.direction > 0:
            return z
        if self.direction < 0:
            return -z
        return abs(z)

    def update(self, x: float) -> Optional[str]:
        """Feed one sample; returns "onset", "clear", or None."""
        x = float(x)
        if self.n == 0:
            self.mean, self.var = x, 0.0
            self.n = 1
            self.z = 0.0
            return None
        std = math.sqrt(max(self.var, 0.0))
        self.z = (x - self.mean) / max(std, self.min_std) \
            if self.n >= self.warmup else 0.0
        # EWMA mean/variance (West's recurrence), bias-corrected: the
        # effective weight is 1/n until n exceeds 1/alpha, so the
        # first post-warmup z-scores use a converged scale instead of
        # one still climbing from zero
        a = max(self.alpha, 1.0 / self.n)
        d = x - self.mean
        self.mean += a * d
        self.var = (1.0 - a) * (self.var + a * d * d)
        self.n += 1

        s = self._signed(self.z)
        if not self.active:
            self._on_streak = self._on_streak + 1 if s >= self.z_on else 0
            if self._on_streak >= self.k_on:
                self.active = True
                self._on_streak = 0
                self._off_streak = 0
                return "onset"
        else:
            self._off_streak = self._off_streak + 1 if s <= self.z_off else 0
            if self._off_streak >= self.k_off:
                self.active = False
                self._off_streak = 0
                return "clear"
        return None


class PageHinkley:
    """Page–Hinkley change-point test on the normalized residual.

    Classic PH accumulates `sum(x_i - mean_i - delta)` and alarms when
    the accumulator rises `lam` above its running minimum; here the
    residual is first scaled by a slowly adapting mean absolute
    deviation, so `delta` and `lam` are in z-units and one setting
    covers every series the monitor taps.  After an onset the
    accumulator resets and the detector holds `active` until the
    normalized residual stays below `z_off` for `k_off` ticks (the
    clear boundary), then resumes hunting.
    """

    def __init__(self, delta: float = 0.5, lam: float = 6.0,
                 alpha: float = 0.05, warmup: int = 8,
                 z_off: float = 1.0, k_off: int = 3,
                 direction: int = 1, min_scale: float = 1e-9):
        self.delta = float(delta)
        self.lam = float(lam)
        self.alpha = float(alpha)
        self.warmup = int(warmup)
        self.z_off = float(z_off)
        self.k_off = max(1, int(k_off))
        self.direction = 1 if direction >= 0 else -1
        self.min_scale = float(min_scale)
        self.mean = 0.0
        self.scale = 0.0   # EWMA of |residual|
        self.n = 0
        self.cum = 0.0
        self.cum_min = 0.0
        self.stat = 0.0    # cum - cum_min (the alarm statistic)
        self.z = 0.0
        self.active = False
        self._off_streak = 0

    def update(self, x: float) -> Optional[str]:
        x = float(x)
        if self.n == 0:
            self.mean = x
            self.n = 1
            return None
        resid = (x - self.mean) * self.direction
        self.z = resid / max(self.scale, self.min_scale) \
            if self.n >= self.warmup else 0.0
        # bias-corrected adaptation (weight 1/n until n > 1/alpha):
        # without it the scale estimate is still climbing from zero
        # right after warmup and inflates every residual into a false
        # change-point
        a = max(self.alpha, 1.0 / self.n)
        self.mean += a * (x - self.mean)
        self.scale += a * (abs(resid) - self.scale)
        self.n += 1
        if self.n <= self.warmup:
            return None

        if not self.active:
            self.cum += self.z - self.delta
            self.cum_min = min(self.cum_min, self.cum)
            self.stat = self.cum - self.cum_min
            if self.stat > self.lam:
                self.active = True
                self.cum = self.cum_min = 0.0
                self._off_streak = 0
                return "onset"
        else:
            self._off_streak = self._off_streak + 1 \
                if self.z <= self.z_off else 0
            if self._off_streak >= self.k_off:
                self.active = False
                self._off_streak = 0
                return "clear"
        return None


@dataclasses.dataclass(frozen=True)
class SeriesSpec:
    """Detector configuration for one tapped per-tick series."""

    name: str
    direction: int = 1        # +1 watch increases, -1 decreases, 0 both
    ewma_alpha: float = 0.15
    z_on: float = 4.0
    z_off: float = 1.5
    warmup: int = 8
    k_on: int = 1
    k_off: int = 3
    ph_delta: float = 0.5
    ph_lambda: float = 6.0


# the default bank: the signals Algorithm 2 itself watches, plus the
# store-side ones PR 3/6 surfaced (drops, spill backlog, dict hits)
DEFAULT_SERIES: Tuple[SeriesSpec, ...] = (
    SeriesSpec("rate", direction=1),                 # kept records/tick
    SeriesSpec("commit_ms", direction=1, z_on=5.0),  # mean commit latency
    SeriesSpec("drops", direction=1, z_on=3.0),      # lost inserts/tick
    SeriesSpec("spill_depth", direction=1, z_on=3.0),  # disk backlog
    SeriesSpec("mu", direction=1, z_on=4.0),         # consumer occupancy
    SeriesSpec("dict_hit", direction=-1),            # compressibility drop
    SeriesSpec("queryable_lag_ms", direction=1, z_on=4.0),  # freshness
    # (repro.lineage: query-surface staleness spike — only fed on
    # lineage-tracked runs, absent values are skipped)
)


class DetectorBank:
    """One EWMA + one Page–Hinkley detector per tapped series.

    `observe(tick, t, values)` feeds every series present in `values`
    (None/absent values are skipped — e.g. `commit_ms` on a tick with
    no commit) and returns the `HealthEvent` boundaries that fired.
    All events are also accumulated on `.events`.
    """

    def __init__(self, specs: Sequence[SeriesSpec] = DEFAULT_SERIES):
        self.specs = {s.name: s for s in specs}
        self._ewma: Dict[str, EwmaDetector] = {}
        self._ph: Dict[str, PageHinkley] = {}
        for s in specs:
            self._ewma[s.name] = EwmaDetector(
                alpha=s.ewma_alpha, z_on=s.z_on, z_off=s.z_off,
                warmup=s.warmup, k_on=s.k_on, k_off=s.k_off,
                direction=s.direction)
            self._ph[s.name] = PageHinkley(
                delta=s.ph_delta, lam=s.ph_lambda, warmup=s.warmup,
                k_off=s.k_off, direction=s.direction if s.direction else 1)
        self.events: List[HealthEvent] = []

    def observe(self, tick: int, t: float,
                values: Dict[str, Optional[float]]) -> List[HealthEvent]:
        fired: List[HealthEvent] = []
        for name, spec in self.specs.items():
            v = values.get(name)
            if v is None:
                continue
            ew = self._ewma[name]
            phase = ew.update(v)
            if phase is not None:
                fired.append(HealthEvent(
                    series=name, detector="ewma", phase=phase, tick=tick,
                    t=t, value=float(v), score=float(ew.z),
                    threshold=ew.z_on if phase == "onset" else ew.z_off))
            ph = self._ph[name]
            phase = ph.update(v)
            if phase is not None:
                fired.append(HealthEvent(
                    series=name, detector="page_hinkley", phase=phase,
                    tick=tick, t=t, value=float(v),
                    score=float(ph.stat if phase == "onset" else ph.z),
                    threshold=ph.lam if phase == "onset" else ph.z_off))
        self.events.extend(fired)
        return fired

    # ---- post-run queries ----
    def onsets(self, series: Optional[str] = None) -> List[HealthEvent]:
        return [e for e in self.events if e.phase == "onset"
                and (series is None or e.series == series)]

    def first_onset_tick(self, series: str) -> int:
        """Earliest onset tick for `series` from either detector
        (-1 when the series never alerted)."""
        ticks = [e.tick for e in self.onsets(series)]
        return min(ticks) if ticks else -1

    def active_alerts(self) -> List[str]:
        """Series currently in alert, as "series/detector" labels."""
        out = [f"{n}/ewma" for n, d in self._ewma.items() if d.active]
        out += [f"{n}/page_hinkley" for n, d in self._ph.items() if d.active]
        return sorted(out)
