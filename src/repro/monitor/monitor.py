"""`HealthMonitor` — the online judge over the ingest->query path.

Subscribes to the pipeline's `MetricsHub` (so it sees every loop
event the moment it is emitted — single-shard or the sharded fleet
through the aggregate hub) and taps the `TelemetryRegistry`'s
cumulative histograms through a `SeriesTap` for exact per-tick
latency deltas.  Each tick boundary it assembles one row of per-tick
series and feeds:

  * the `DetectorBank` (EWMA z-score + Page–Hinkley) -> `HealthEvent`
    onset/clear boundaries, so a flash-crowd onset is *detected and
    timestamped* during the run;
  * the `SLOTracker` -> error-budget accounting + multi-window
    burn-rate alerts;

and at `finish()` scores the controller audit trail
(`repro.monitor.quality`) so every Algorithm-2 decision carries a
quality verdict and the run gets one **controller score**.

Wiring is one call each way::

    mon = HealthMonitor()
    pipe = (PipelineBuilder(cfg).with_source(src)
            .with_monitor(mon).build())    # implies with_telemetry
    pipe.run(max_ticks=300)
    mon.finish()
    print(mon.report()["controller_score"])

or `run_scenario(..., monitor=True)` which also lands the verdicts in
the `WorkloadReport`.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Sequence

from repro.monitor.detectors import (
    DEFAULT_SERIES,
    DetectorBank,
    HealthEvent,
    SeriesSpec,
)
from repro.monitor.quality import per_action_scores, score_trail
from repro.monitor.slo import SLOSpec, SLOTracker, default_slos

# per-tick series the monitor assembles (detector specs and SLO
# metrics both draw from these keys)
SERIES_KEYS = ("rate", "raw", "pushed", "drops", "commits",
               "commit_failures", "commit_ms", "commit_p99_ms", "mu",
               "spill_depth", "dict_hit", "ticks_since_checkpoint",
               "ingest_lag_ms", "queryable_lag_ms")


class HealthMonitor:
    """Standing health evaluation over one pipeline run."""

    def __init__(self,
                 series: Sequence[SeriesSpec] = DEFAULT_SERIES,
                 slos: Optional[Sequence[SLOSpec]] = None,
                 cpu_max: Optional[float] = None,
                 history: int = 512,
                 on_tick: Optional[Callable] = None):
        self.detectors = DetectorBank(series)
        self._slo_specs = list(slos) if slos is not None else None
        self.slo: Optional[SLOTracker] = \
            SLOTracker(self._slo_specs) if self._slo_specs else None
        self.cpu_max = cpu_max
        self.on_tick = on_tick
        self.tick = -1          # index of the tick being accumulated
        self.t = 0.0
        self.history: collections.deque = collections.deque(maxlen=history)
        self.last_values: Dict[str, Optional[float]] = {}
        self._acc: Optional[Dict] = None
        self._tap = None
        self._registry = None
        self._hub = None
        self._dict_seen = False
        self._checkpointing = False
        self._since_ckpt = 0
        self._finished = False
        self._quality: Dict = {}
        self._quality_by_action: Dict = {}

    # ------------------------------------------------------------------
    def bind(self, hub, cfg=None, checkpoint_every: int = 0
             ) -> "HealthMonitor":
        """Attach to a pipeline's `MetricsHub` (+ its telemetry
        registry).  `cfg` (an `IngestConfig`) seeds `cpu_max` and the
        default SLO set; `checkpoint_every` > 0 arms the
        checkpoint-cadence SLO."""
        from repro.telemetry.spans import SeriesTap

        self._hub = hub
        self._registry = hub.telemetry
        self._tap = SeriesTap(hub.telemetry)
        if cfg is not None and self.cpu_max is None:
            self.cpu_max = float(cfg.cpu_max)
        if self.slo is None:
            self.slo = SLOTracker(default_slos(
                cpu_max=self.cpu_max if self.cpu_max is not None else 0.55,
                theta2=float(getattr(cfg, "theta2", 0.25)),
                checkpoint_every=checkpoint_every))
        if checkpoint_every > 0:
            self._checkpointing = True
        hub.subscribe(self.on_event)
        return self

    # ------------------------------------------------------------------
    # event intake (MetricsHub hook)
    # ------------------------------------------------------------------
    def on_event(self, ev) -> None:
        k = ev.kind
        if k == "tick":
            # a new tick begins: judge the one that just completed
            self._finalize()
            self.tick += 1
            self.t = float(ev.t)
            self._acc = {
                "rate": float(ev.payload.get("kept", 0)),
                "raw": float(ev.payload.get("raw", 0)),
                "pushed": 0.0, "drops": 0.0, "commits": 0.0,
                "commit_failures": 0.0, "mu": [], "spill_depth": 0.0,
                "dict_hit": [],
            }
            return
        a = self._acc
        if a is None:
            return
        if k == "commit":
            a["commits"] += 1
            a["drops"] += float(ev.payload.get("dropped", 0))
            hr = ev.payload.get("dict_hit_rate")
            if hr is not None:
                if hr > 0.0 or ev.payload.get("refs", 0) > 0:
                    self._dict_seen = True
                a["dict_hit"].append(float(hr))
        elif k == "commit-failed":
            a["commit_failures"] += 1
        elif k == "push":
            a["pushed"] += float(ev.payload.get("records", 0))
        elif k == "sample":
            if "mu" in ev.payload:
                a["mu"].append(float(ev.payload["mu"]))
            a["spill_depth"] = max(a["spill_depth"],
                                   float(ev.payload.get("spill_depth", 0)))
        elif k == "watermark":
            # repro.lineage staleness, re-emitted at each tick boundary
            # (the tracker's hook runs after ours, so this lands in the
            # row we just opened)
            a["ingest_lag_ms"] = ev.payload.get("ingest_lag_ms")
            a["queryable_lag_ms"] = ev.payload.get("queryable_lag_ms")
        elif k == "checkpoint":
            self._checkpointing = True
            self._since_ckpt = 0
        elif k == "report":
            # run over: close out the final tick while the hub's state
            # is still live (finish() is idempotent on top of this)
            self._finalize()

    # ------------------------------------------------------------------
    def _finalize(self) -> None:
        """Close the accumulating tick: assemble the per-tick series
        row and feed the detectors and the SLO tracker."""
        a, self._acc = self._acc, None
        if a is None:
            return
        values: Dict[str, Optional[float]] = {
            "rate": a["rate"], "raw": a["raw"], "pushed": a["pushed"],
            "drops": a["drops"], "commits": a["commits"],
            "commit_failures": a["commit_failures"],
            "spill_depth": a["spill_depth"],
            "mu": sum(a["mu"]) / len(a["mu"]) if a["mu"] else None,
            "commit_ms": None, "commit_p99_ms": None,
            "dict_hit": None, "ticks_since_checkpoint": None,
            # None when no lineage tracker is wired: detectors and
            # SLOs skip None, so non-lineage runs are unchanged
            "ingest_lag_ms": a.get("ingest_lag_ms"),
            "queryable_lag_ms": a.get("queryable_lag_ms"),
        }
        if self._tap is not None:
            h = self._tap.hist_delta("commit.upsert")
            if h.count > 0:
                values["commit_ms"] = h.mean_ns / 1e6
                values["commit_p99_ms"] = h.percentile_ns(0.99) / 1e6
        if self._dict_seen and a["dict_hit"]:
            values["dict_hit"] = sum(a["dict_hit"]) / len(a["dict_hit"])
        if self._checkpointing:
            self._since_ckpt += 1
            values["ticks_since_checkpoint"] = float(self._since_ckpt)

        self.detectors.observe(self.tick, self.t, values)
        if self.slo is not None:
            self.slo.observe(self.tick, self.t, values)
        self.last_values = values
        self.history.append({"tick": self.tick, "t": self.t, **values})
        if self.on_tick is not None:
            self.on_tick(self, self.tick, values)

    # ------------------------------------------------------------------
    def finish(self) -> "HealthMonitor":
        """Close any open tick and score the controller audit trail.
        Idempotent; called by the harness after the run (or call it
        yourself after `pipe.run`)."""
        self._finalize()
        if not self._finished:
            audit = list(self._registry.audit) if self._registry is not None \
                else []
            cpu = self.cpu_max if self.cpu_max is not None else 0.55
            self._quality = score_trail(audit, cpu_max=cpu)
            self._quality_by_action = per_action_scores(audit)
            self._finished = True
        return self

    # ---- queries ------------------------------------------------------
    @property
    def events(self) -> List[HealthEvent]:
        return self.detectors.events

    @property
    def controller_score(self) -> float:
        return float(self._quality.get("controller_score", 1.0))

    def burst_onset_tick(self, series: str = "rate") -> int:
        return self.detectors.first_onset_tick(series)

    def active_alerts(self) -> List[str]:
        out = list(self.detectors.active_alerts())
        if self.slo is not None:
            out += [f"slo:{n}" for n in self.slo.active_alerts()]
        return out

    def report(self) -> Dict:
        """The JSON-safe monitor verdict for one run (the payload the
        CLI writes with --report-out and the harness folds into
        `WorkloadReport`)."""
        if not self._finished:
            self.finish()
        slo_summary = self.slo.summary() if self.slo is not None else {}
        onsets = {s: self.detectors.first_onset_tick(s)
                  for s in self.detectors.specs
                  if self.detectors.first_onset_tick(s) >= 0}
        return {
            "ticks": self.tick + 1,
            "health_events": [e.to_dict() for e in self.events],
            "n_health_events": len(self.events),
            "onsets": onsets,
            "burst_onset_tick": self.burst_onset_tick("rate"),
            "active_alerts": self.active_alerts(),
            "slo": slo_summary,
            "slo_breaches": self.slo.total_breaches()
            if self.slo is not None else 0,
            "slo_alerts": self.slo.total_alerts()
            if self.slo is not None else 0,
            "quality": dict(self._quality),
            "quality_by_action": dict(self._quality_by_action),
            "controller_score": self.controller_score,
            "series_last": dict(self.last_values),
        }
