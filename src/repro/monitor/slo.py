"""Declarative SLOs with error budgets and multi-window burn rates.

An `SLOSpec` states an objective over one per-tick metric ("p99
commit latency <= 150 ms", "drops <= 0", "pushed records >= 1") plus
an **error budget**: the fraction of ticks allowed to violate it over
the run.  `SLOTracker` evaluates every spec each tick — persistent,
incremental evaluation over the stream, the same shape as the
standing queries in Pacaci et al. — and maintains the SRE-style
**burn rate** over a short and a long sliding window:

    burn = breach fraction in window / budget

A burn of 1.0 means the budget is being consumed exactly at the
sustainable rate; the tracker raises a `burn alert` (onset/clear,
hysteresis-free — the window arithmetic is its own smoothing) when
BOTH windows exceed `burn_alert`, the standard multi-window guard
against both flapping (short window alone) and staleness (long window
alone).

Everything is counter-deterministic: deques of booleans and integer
arithmetic, no clocks.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One service-level objective over a per-tick metric."""

    name: str
    metric: str            # key into the monitor's per-tick values
    op: str                # "<=" or ">="
    target: float          # per-tick threshold
    budget: float = 0.05   # allowed breaching-tick fraction over the run
    short_window: int = 12
    long_window: int = 60
    burn_alert: float = 4.0
    description: str = ""

    def ok(self, value: float) -> bool:
        if self.op == "<=":
            return value <= self.target
        if self.op == ">=":
            return value >= self.target
        raise ValueError(f"SLOSpec.op must be <= or >=, got {self.op!r}")


def default_slos(cpu_max: float = 0.55, theta2: float = 0.25,
                 checkpoint_every: int = 0) -> List[SLOSpec]:
    """The stock objectives over the ingest->query path.

    `checkpoint_every` > 0 adds the checkpoint-cadence objective
    (repro.resilience); the metric is only fed on checkpointing runs,
    so the spec is inert otherwise.
    """
    slos = [
        SLOSpec("commit_p99", "commit_p99_ms", "<=", 150.0, budget=0.10,
                description="per-tick p99 commit latency stays under "
                            "150 ms (JIT warmup rides the budget)"),
        SLOSpec("no_drops", "drops", "<=", 0.0, budget=0.02,
                description="the store loses no inserts under pressure"),
        SLOSpec("throughput_floor", "pushed", ">=", 1.0, budget=0.35,
                description="the pipeline pushes data most ticks "
                            "(holds/throttles ride the budget)"),
        SLOSpec("mu_bounded", "mu", "<=", cpu_max * (1.0 + theta2),
                budget=0.10,
                description="consumer occupancy stays under the "
                            "Algorithm-2 escalation bound"),
        # the metric is only produced on lineage-tracked runs
        # (run_scenario(lineage=...)), so the spec is inert otherwise;
        # tighter windows than the latency SLOs — a stalled watermark
        # breaches consecutively, so a store outage should alert while
        # the outage is still in progress, not a long-window later
        SLOSpec("freshness", "queryable_lag_ms", "<=", 5000.0,
                budget=0.15, short_window=6, long_window=24,
                burn_alert=3.0,
                description="the graph queries see is never more than "
                            "5 s of stream time stale (queryable "
                            "watermark lag; buffering rides the budget)"),
    ]
    if checkpoint_every > 0:
        slos.append(SLOSpec(
            "checkpoint_cadence", "ticks_since_checkpoint", "<=",
            float(2 * checkpoint_every), budget=0.05,
            description="a resumable checkpoint is never more than "
                        "2 intervals stale"))
    return slos


class _SLOState:
    """Mutable tracking state for one spec (O(windows) memory)."""

    __slots__ = ("spec", "ticks", "breaches", "short", "long",
                 "max_burn_short", "max_burn_long", "alert_active",
                 "alerts", "first_breach_tick", "first_alert_tick")

    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self.ticks = 0
        self.breaches = 0
        self.short: collections.deque = collections.deque(
            maxlen=spec.short_window)
        self.long: collections.deque = collections.deque(
            maxlen=spec.long_window)
        self.max_burn_short = 0.0
        self.max_burn_long = 0.0
        self.alert_active = False
        self.alerts: List[Dict] = []
        self.first_breach_tick = -1
        self.first_alert_tick = -1

    def burn(self, win: collections.deque) -> float:
        if not win:
            return 0.0
        frac = sum(win) / len(win)
        return frac / max(self.spec.budget, 1e-9)


class SLOTracker:
    """Evaluate every spec each tick; summarize budgets per run."""

    def __init__(self, specs: Optional[Sequence[SLOSpec]] = None):
        self.specs = list(specs) if specs is not None else default_slos()
        self._st = {s.name: _SLOState(s) for s in self.specs}

    def observe(self, tick: int, t: float,
                values: Dict[str, Optional[float]]) -> List[Dict]:
        """Feed one tick of metrics; returns burn-alert boundaries
        fired this tick ([{slo, phase, tick, t, burn_short, burn_long}])."""
        fired: List[Dict] = []
        for st in self._st.values():
            spec = st.spec
            v = values.get(spec.metric)
            if v is None:
                continue  # metric not produced this tick: not evaluated
            bad = not spec.ok(float(v))
            st.ticks += 1
            if bad:
                st.breaches += 1
                if st.first_breach_tick < 0:
                    st.first_breach_tick = tick
            st.short.append(bad)
            st.long.append(bad)
            bs, bl = st.burn(st.short), st.burn(st.long)
            st.max_burn_short = max(st.max_burn_short, bs)
            st.max_burn_long = max(st.max_burn_long, bl)
            # multi-window alert: both windows must burn hot, and the
            # long window must have some history (avoid cold-start spikes)
            hot = (bs >= spec.burn_alert and bl >= spec.burn_alert
                   and len(st.long) >= spec.short_window)
            if hot != st.alert_active:
                st.alert_active = hot
                ev = {"slo": spec.name,
                      "phase": "onset" if hot else "clear",
                      "tick": tick, "t": float(t),
                      "burn_short": round(bs, 3), "burn_long": round(bl, 3)}
                st.alerts.append(ev)
                fired.append(ev)
                if hot and st.first_alert_tick < 0:
                    st.first_alert_tick = tick
        return fired

    # ---- queries ----
    def active_alerts(self) -> List[str]:
        return sorted(n for n, st in self._st.items() if st.alert_active)

    def total_breaches(self) -> int:
        return sum(st.breaches for st in self._st.values())

    def total_alerts(self) -> int:
        return sum(len([a for a in st.alerts if a["phase"] == "onset"])
                   for st in self._st.values())

    def summary(self) -> Dict[str, Dict]:
        """Per-SLO run summary: evaluated ticks, breaches, budget
        consumption, peak burn rates, alert boundaries."""
        out: Dict[str, Dict] = {}
        for name, st in self._st.items():
            spec = st.spec
            ratio = st.breaches / st.ticks if st.ticks else 0.0
            out[name] = {
                "metric": spec.metric,
                "objective": f"{spec.metric} {spec.op} {spec.target:g}",
                "budget": spec.budget,
                "ticks": st.ticks,
                "breaches": st.breaches,
                "breach_ratio": round(ratio, 4),
                "budget_consumed": round(ratio / max(spec.budget, 1e-9), 3),
                "max_burn_short": round(st.max_burn_short, 3),
                "max_burn_long": round(st.max_burn_long, 3),
                "first_breach_tick": st.first_breach_tick,
                "first_alert_tick": st.first_alert_tick,
                "alerts": list(st.alerts),
                "met": ratio <= spec.budget,
            }
        return out
