"""Monitor exposition: Prometheus text format + terminal dashboard.

  * `prometheus_text(monitor=..., registry=...)` — the standard
    Prometheus exposition format (text/plain; version 0.0.4): event
    counters, per-stage latency histograms (the fixed log-bucket
    state maps 1:1 onto cumulative `_bucket{le=...}` lines), monitor
    series gauges, SLO budget/burn gauges, health-event counters and
    the controller score.  Scrapeable by pointing any Prometheus
    file/textfile collector at the `--prom-out` file.
  * `render_dashboard(monitor, registry=...)` — the live terminal
    view the CLI repaints while a scenario runs: rolling per-stage
    latency table, latest per-tick series, SLO status with budget
    bars, and the active-alert list.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.telemetry.spans import NBUCKETS, TelemetryRegistry, bucket_upper_ns


def _esc(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"') \
        .replace("\n", "\\n")


def _fmt(v: float) -> str:
    # Prometheus wants plain decimals; ns->s conversions stay exact
    # enough at 9 digits
    return f"{float(v):.9g}"


def prometheus_text(monitor=None,
                    registry: Optional[TelemetryRegistry] = None,
                    lineage=None) -> str:
    """Render the run's state in Prometheus exposition format.
    `lineage` (a `repro.lineage.LineageTracker`) appends the
    watermark/freshness/conservation gauges."""
    lines: List[str] = []
    if registry is None and monitor is not None:
        registry = monitor._registry
    if registry is not None:
        root = registry._root
        lines.append("# HELP repro_events_total pipeline loop events by kind")
        lines.append("# TYPE repro_events_total counter")
        for name, n in sorted(root.counters.items()):
            lines.append(f'repro_events_total{{kind="{_esc(name)}"}} {n}')
        lines.append("# HELP repro_spans_dropped_total span events dropped "
                     "past max_events (histograms stay exact)")
        lines.append("# TYPE repro_spans_dropped_total counter")
        lines.append(f"repro_spans_dropped_total {root.events_dropped}")
        lines.append("# HELP repro_stage_latency_seconds per-stage span "
                     "latency (fixed log-bucket histogram, all shards)")
        lines.append("# TYPE repro_stage_latency_seconds histogram")
        for name in root.stage_names():
            h = root.aggregate(name)
            stage = _esc(name)
            acc = 0
            for i in range(NBUCKETS):
                if h.counts[i] == 0:
                    continue
                acc += h.counts[i]
                le = bucket_upper_ns(i) / 1e9
                lines.append(
                    f'repro_stage_latency_seconds_bucket{{stage="{stage}",'
                    f'le="{_fmt(le)}"}} {acc}')
            lines.append(
                f'repro_stage_latency_seconds_bucket{{stage="{stage}",'
                f'le="+Inf"}} {h.count}')
            lines.append(f'repro_stage_latency_seconds_sum{{stage="{stage}"}}'
                         f' {_fmt(h.sum_ns / 1e9)}')
            lines.append(f'repro_stage_latency_seconds_count'
                         f'{{stage="{stage}"}} {h.count}')

    if monitor is not None:
        lines.append("# HELP repro_monitor_series latest per-tick series "
                     "value observed by the health monitor")
        lines.append("# TYPE repro_monitor_series gauge")
        for name, v in sorted(monitor.last_values.items()):
            if v is not None:
                lines.append(
                    f'repro_monitor_series{{series="{_esc(name)}"}} '
                    f'{_fmt(v)}')
        lines.append("# HELP repro_health_events_total detector onset/clear "
                     "boundaries by series and phase")
        lines.append("# TYPE repro_health_events_total counter")
        by_key: Dict[tuple, int] = {}
        for e in monitor.events:
            by_key[(e.series, e.detector, e.phase)] = \
                by_key.get((e.series, e.detector, e.phase), 0) + 1
        for (series, det, phase), n in sorted(by_key.items()):
            lines.append(
                f'repro_health_events_total{{series="{_esc(series)}",'
                f'detector="{_esc(det)}",phase="{_esc(phase)}"}} {n}')
        if monitor.slo is not None:
            summ = monitor.slo.summary()
            lines.append("# HELP repro_slo_budget_consumed fraction of the "
                         "error budget burned (1.0 = budget exhausted)")
            lines.append("# TYPE repro_slo_budget_consumed gauge")
            for name, s in sorted(summ.items()):
                lines.append(f'repro_slo_budget_consumed{{slo="{_esc(name)}"}}'
                             f' {_fmt(s["budget_consumed"])}')
            lines.append("# HELP repro_slo_burn_rate_max peak burn rate "
                         "per window")
            lines.append("# TYPE repro_slo_burn_rate_max gauge")
            for name, s in sorted(summ.items()):
                for win in ("short", "long"):
                    lines.append(
                        f'repro_slo_burn_rate_max{{slo="{_esc(name)}",'
                        f'window="{win}"}} {_fmt(s[f"max_burn_{win}"])}')
            lines.append("# HELP repro_slo_breaches_total breaching ticks "
                         "per SLO")
            lines.append("# TYPE repro_slo_breaches_total counter")
            for name, s in sorted(summ.items()):
                lines.append(f'repro_slo_breaches_total{{slo="{_esc(name)}"}}'
                             f' {s["breaches"]}')
        lines.append("# HELP repro_controller_score per-run controller "
                     "decision-quality score in [0,1]")
        lines.append("# TYPE repro_controller_score gauge")
        lines.append(f"repro_controller_score {_fmt(monitor.controller_score)}")
    if lineage is not None:
        from repro.lineage import prometheus_lines

        lines.extend(prometheus_lines(lineage))
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, monitor=None,
                     registry: Optional[TelemetryRegistry] = None,
                     lineage=None) -> str:
    with open(path, "w") as f:
        f.write(prometheus_text(monitor=monitor, registry=registry,
                                lineage=lineage))
    return path


# ---------------------------------------------------------------------------
# terminal dashboard
# ---------------------------------------------------------------------------

def _bar(frac: float, width: int = 16) -> str:
    frac = max(0.0, min(1.0, frac))
    n = int(round(frac * width))
    return "#" * n + "-" * (width - n)


def render_dashboard(monitor, registry: Optional[TelemetryRegistry] = None,
                     top_stages: int = 8, max_alerts: int = 6) -> str:
    """One frame of the live health view (plain text, ~80 cols)."""
    if registry is None:
        registry = monitor._registry
    lv = monitor.last_values or {}
    out: List[str] = []

    def g(key, fmt="{:.1f}", none="   -"):
        v = lv.get(key)
        return none if v is None else fmt.format(v)

    out.append(f"== repro.monitor | tick {monitor.tick:>4} "
               f"t={monitor.t:7.1f}s ==")
    out.append(f"rate={g('rate'):>7}/t pushed={g('pushed'):>7} "
               f"drops={g('drops', '{:.0f}')} mu={g('mu', '{:.3f}')} "
               f"spill={g('spill_depth', '{:.0f}')} "
               f"commit_ms={g('commit_ms', '{:.2f}')} "
               f"p99={g('commit_p99_ms', '{:.2f}')}")

    if registry is not None and registry._root._hists:
        out.append("")
        out.append(f"{'stage':<22}{'count':>8}{'p50_ms':>9}{'p95_ms':>9}"
                   f"{'p99_ms':>9}{'total_s':>9}")
        summ = registry.summary()
        for name in sorted(summ, key=lambda n: -summ[n]["total_s"]
                           )[:top_stages]:
            st = summ[name]
            out.append(f"{name:<22}{st['count']:>8}{st['p50_ms']:>9.3f}"
                       f"{st['p95_ms']:>9.3f}{st['p99_ms']:>9.3f}"
                       f"{st['total_s']:>9.3f}")

    if monitor.slo is not None:
        out.append("")
        out.append(f"{'SLO':<20}{'objective':<28}{'budget':>18}"
                   f"{'burn s/l':>12}")
        for name, s in sorted(monitor.slo.summary().items()):
            consumed = s["budget_consumed"]
            flag = " " if s["met"] else "!"
            out.append(
                f"{flag}{name:<19}{s['objective']:<28}"
                f"[{_bar(consumed)}]{min(consumed, 9.99):>5.2f}"
                f"{s['max_burn_short']:>6.1f}/{s['max_burn_long']:<5.1f}")

    alerts = monitor.active_alerts()
    out.append("")
    if alerts:
        out.append(f"ACTIVE ALERTS ({len(alerts)}): "
                   + ", ".join(alerts[:max_alerts])
                   + (" ..." if len(alerts) > max_alerts else ""))
    else:
        out.append("active alerts: none")
    recent = monitor.events[-max_alerts:]
    for e in recent:
        out.append(f"  {e}")
    return "\n".join(out)


def text_report(monitor) -> str:
    """Post-run text verdict (the CLI's non-dashboard summary)."""
    rep = monitor.report()
    out = [f"== monitor verdict: {rep['ticks']} ticks, "
           f"{rep['n_health_events']} health events, "
           f"{rep['slo_breaches']} SLO-breaching ticks, "
           f"{rep['slo_alerts']} burn alerts =="]
    if rep["onsets"]:
        out.append("first onsets: " + ", ".join(
            f"{s}@tick{t}" for s, t in sorted(rep["onsets"].items())))
    for e in monitor.events:
        out.append(f"  {e}")
    if rep["slo"]:
        out.append("SLOs:")
        for name, s in sorted(rep["slo"].items()):
            mark = "ok " if s["met"] else "MISS"
            out.append(
                f"  [{mark}] {name}: {s['objective']} — "
                f"{s['breaches']}/{s['ticks']} breaching ticks "
                f"(budget {s['budget']:.0%}, consumed "
                f"{s['budget_consumed']:.2f}x), peak burn "
                f"{s['max_burn_short']:.1f}/{s['max_burn_long']:.1f}")
    q = rep["quality"]
    if q:
        out.append(
            f"controller score: {rep['controller_score']:.4f} over "
            f"{q.get('decisions', 0)} decisions "
            f"(mu err mean {q.get('mu_err_mean', 0):.4f}, regret total "
            f"{q.get('regret_total', 0):+.4f}, overload "
            f"{q.get('overload_decisions', 0)}, overcautious "
            f"{q.get('overcautious_decisions', 0)})")
    for action, s in sorted(rep.get("quality_by_action", {}).items()):
        out.append(f"  {action:<11} n={s['n']:<5} "
                   f"score_mean={s['score_mean']:.4f} "
                   f"min={s['score_min']:.4f}")
    return "\n".join(out)
