"""Controller decision-quality scoring from the audit trail.

PR 7's `AuditTrail` records what every Algorithm-2 decision saw
(the full PerfMon input vector), what it predicted (`mu_pred`,
`beta_e_pred`) and what then happened (`mu_real`, `beta_e_real`).
This module turns those records into judgments:

  * **prediction error** — |mu_pred - mu_real| per resolved decision:
    how good the paper's Eq. 4/5 occupancy model actually was online.
  * **decision cost** — the realized badness of the tick: occupancy
    past `cpu_max` (overload), plus a penalty for holding/throttling
    while the consumer demonstrably had headroom (overcaution).
  * **regret vs. do-nothing** — the controller's whole reason to
    exist is beating "always push".  `mu_pred` *is* the model's
    estimate of occupancy had the bucket been pushed, so for every
    hold/throttle the counterfactual push-cost is computable; regret
    is realized cost minus that baseline (negative = the controller
    beat do-nothing on this decision).
  * **per-decision score** in [0, 1] combining the above, attached to
    each `AuditRecord.quality`, and a per-run aggregate — the
    **controller score** that becomes a first-class `WorkloadReport`
    field and a BENCH_ingest.json trajectory column.
"""
from __future__ import annotations

from typing import Dict, List, Optional

# weights of the per-decision score: prediction error (z of cpu_max)
# and positive regret each subtract from a perfect 1.0
W_ERR = 1.0
W_REGRET = 1.0
# "demonstrable headroom": a hold/throttle is overcautious when the
# realized occupancy stayed under this fraction of cpu_max
HEADROOM_FRAC = 0.8


def _overload(mu: float, cpu_max: float) -> float:
    return max(0.0, mu - cpu_max) / max(cpu_max, 1e-9)


def score_record(rec, cpu_max: float = 0.55) -> Dict:
    """Score one `AuditRecord`; attaches and returns `rec.quality`.

    Unresolved records (a run ending mid-tick leaves the last decision
    open) are scored neutrally and flagged `resolved: False`.
    """
    held = rec.action in ("hold", "throttle")
    if rec.mu_real is None:
        q = {"resolved": False, "score": 1.0, "mu_abs_err": None,
             "cost": None, "baseline_cost": None, "regret": None,
             "overload": False, "overcautious": False}
        rec.quality = q
        return q

    mu_real = float(rec.mu_real)
    mu_pred = float(rec.mu_pred)
    err = abs(mu_pred - mu_real)

    over = _overload(mu_real, cpu_max)
    caution = 0.0
    if held and mu_real < HEADROOM_FRAC * cpu_max:
        caution = (HEADROOM_FRAC * cpu_max - mu_real) / max(cpu_max, 1e-9)
    cost = over + caution

    # do-nothing baseline: push this bucket regardless.  For pushes the
    # baseline IS the decision (regret only reflects anything the hold
    # machinery cost us: zero).  For holds/throttles the model's own
    # push prediction prices the counterfactual.
    baseline = _overload(mu_pred, cpu_max) if held else cost
    regret = cost - baseline

    score = max(0.0, min(1.0, 1.0 - W_ERR * err / max(cpu_max, 1e-9)
                         - W_REGRET * max(regret, 0.0)))
    q = {
        "resolved": True,
        "score": round(score, 4),
        "mu_abs_err": round(err, 4),
        "cost": round(cost, 4),
        "baseline_cost": round(baseline, 4),
        "regret": round(regret, 4),
        "overload": over > 0.0,
        "overcautious": caution > 0.0,
    }
    rec.quality = q
    return q


def score_trail(audit: List, cpu_max: float = 0.55) -> Dict:
    """Score every record in an audit trail and aggregate.

    Returns the per-run quality report: the mean per-decision score
    (the **controller score**), prediction-error stats, total/mean
    regret, and the overload/overcaution decision counts.  Safe on an
    empty trail (controller score 1.0: no decisions, no mistakes).
    """
    scores: List[float] = []
    errs: List[float] = []
    regrets: List[float] = []
    n_overload = n_overcautious = n_resolved = 0
    for rec in audit:
        q = score_record(rec, cpu_max)  # idempotent: pure f(record)
        scores.append(q["score"])
        if q["resolved"]:
            n_resolved += 1
            errs.append(q["mu_abs_err"])
            regrets.append(q["regret"])
            n_overload += bool(q["overload"])
            n_overcautious += bool(q["overcautious"])
    n = len(audit)
    mean = lambda xs: sum(xs) / len(xs) if xs else 0.0  # noqa: E731
    return {
        "decisions": n,
        "resolved": n_resolved,
        "controller_score": round(mean(scores), 4) if n else 1.0,
        "mu_err_mean": round(mean(errs), 4),
        "mu_err_max": round(max(errs), 4) if errs else 0.0,
        "regret_mean": round(mean(regrets), 4),
        "regret_total": round(sum(regrets), 4),
        "overload_decisions": n_overload,
        "overcautious_decisions": n_overcautious,
        "cpu_max": cpu_max,
    }


def per_action_scores(audit: List) -> Dict[str, Dict]:
    """Score breakdown by action kind (push/hold/throttle/drain+push);
    expects `score_trail` (or `score_record`) to have run first."""
    acc: Dict[str, List[float]] = {}
    for rec in audit:
        q = getattr(rec, "quality", None)
        if q is None or q["score"] is None:
            continue
        acc.setdefault(rec.action, []).append(q["score"])
    return {a: {"n": len(xs),
                "score_mean": round(sum(xs) / len(xs), 4),
                "score_min": round(min(xs), 4)}
            for a, xs in sorted(acc.items())}
