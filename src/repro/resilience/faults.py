"""Counter-deterministic fault injection for the ingest->query path.

A `FaultPlan` declares WHAT goes wrong — commit `ConnectionError`
bursts (by attempt index or by simulated time), slow-commit latency
spikes, and a crash-at-tick kill — as pure data, so the same plan
replayed against the same scenario produces byte-identical failure
sequences.  A `FaultInjector` executes the plan through the
`GraphIngestor.fail_hook` slot: it keeps the attempt counter (which
checkpoints alongside the ingestor, so a resumed run continues the
fault sequence exactly where the killed run left it).

`PipelineKilled` is raised by the checkpoint driver when the plan's
`crash_at_tick` fires — callers (the chaos harness) catch it, then
call `run_scenario(..., resume=True)` with the crash removed
(`plan.without_crash()`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple


class PipelineKilled(RuntimeError):
    """The fault plan killed the pipeline at `tick` (chaos testing)."""

    def __init__(self, tick: int):
        super().__init__(f"fault plan killed the pipeline at tick {tick}")
        self.tick = tick


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative fault schedule (all windows are half-open).

    fail_attempts : commit-attempt index windows ``(start, end)`` that
                    raise `ConnectionError` (index counts every commit
                    attempt the ingestor makes, including retries).
    fail_times    : simulated-time windows ``(t0, t1)`` during which
                    every commit fails — an outage of the store.
    slow_attempts : ``(start, end, seconds)`` windows that sleep before
                    the commit (latency spike; wall-clock only, never
                    touches control state).
    crash_at_tick : kill the pipeline after processing this tick
                    (honoured by the checkpoint driver, not the hook).
    """

    fail_attempts: Tuple[Tuple[int, int], ...] = ()
    fail_times: Tuple[Tuple[float, float], ...] = ()
    slow_attempts: Tuple[Tuple[int, int, float], ...] = ()
    crash_at_tick: Optional[int] = None

    def without_crash(self) -> "FaultPlan":
        """The same plan minus the kill — what a resumed run (and the
        uninterrupted reference run) must execute for bit-exactness."""
        return dataclasses.replace(self, crash_at_tick=None)


class FaultInjector:
    """`fail_hook`-shaped executor of a `FaultPlan`.

    `wants_now = True` tells the ingestor to pass the commit's
    simulated time so `fail_times` windows work; plain nullary hooks
    keep working unchanged.
    """

    wants_now = True

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.attempts = 0  # commit attempts observed so far

    def __call__(self, now: Optional[float] = None) -> bool:
        i = self.attempts
        self.attempts += 1
        for (s, e, d) in self.plan.slow_attempts:
            if s <= i < e:
                time.sleep(d)
                break
        for (s, e) in self.plan.fail_attempts:
            if s <= i < e:
                return True
        if now is not None:
            for (t0, t1) in self.plan.fail_times:
                if t0 <= now < t1:
                    return True
        return False

    # ---- checkpoint surface (rides in GraphIngestor.state()) ----
    def state(self) -> dict:
        return {"attempts": self.attempts}

    def restore_state(self, s: dict) -> None:
        self.attempts = int(s["attempts"])
