"""repro.resilience — checkpoint/resume, fault injection, commit retry.

Three pieces, composable through `PipelineBuilder` and `run_scenario`:

  * `PipelineCheckpointer` — step-atomic `_COMMITTED`-manifest
    snapshots of the FULL ingest state (store pytree, sketches,
    pattern dictionary, controller + spill contents, ingestor
    pool/archive, source cursor, loop scalars), background writes,
    keep-N GC; `run_scenario(..., resume=True)` replays bit-exactly.
  * `FaultPlan` / `FaultInjector` — counter-deterministic commit
    failures, latency spikes and crash-at-tick kills through
    `GraphIngestor.fail_hook`; `PipelineKilled` is the kill signal.
  * `RetryPolicy` — capped exponential backoff + deterministic jitter
    governing `retry_archive` and the ingestor's degraded mode.

CLI: ``python -m repro.launch.chaos`` (kill mid-flash_crowd, resume,
verify store/snapshot/accounting invariants).  See docs/API.md
"Resilience & fault tolerance".
"""
from repro.resilience.checkpoint import (
    PipelineCheckpointer,
    drive,
    pytree_digest,
)
from repro.resilience.faults import FaultInjector, FaultPlan, PipelineKilled
from repro.resilience.retry import RetryPolicy

__all__ = [
    "FaultInjector",
    "FaultPlan",
    "PipelineCheckpointer",
    "PipelineKilled",
    "RetryPolicy",
    "drive",
    "pytree_digest",
]
