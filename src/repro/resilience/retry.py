"""`RetryPolicy` — capped exponential backoff with deterministic jitter.

Governs `GraphIngestor.retry_archive` (and the degraded-mode push gate)
when the graph store's connection is down: attempt k waits
``base_s * factor**k`` seconds, capped at `cap_s`, with a +/-`jitter`
fractional perturbation derived from an integer hash of
``(seed, attempt)`` — NOT from a wall-clock RNG — so two runs of the
same scenario back off at byte-identical times and checkpoint/resume
replays the exact retry schedule (the counter-determinism contract of
`repro.workloads` extended to the failure path).
"""
from __future__ import annotations

import dataclasses
import math


def _hash01(x: int) -> float:
    """lowbias32-style avalanche of an integer to uniform [0, 1)."""
    x &= 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x7FEB352D) & 0xFFFFFFFF
    x ^= x >> 15
    x = (x * 0x846CA68B) & 0xFFFFFFFF
    x ^= x >> 16
    return x / 4294967296.0


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff: ``delay(k) ~ base_s * factor**k``.

    `jitter` is the +/- fraction applied deterministically per attempt
    (0 disables it); `seed` decorrelates the jitter streams of e.g.
    different shards retrying against one store.
    """

    base_s: float = 0.5
    factor: float = 2.0
    cap_s: float = 30.0
    jitter: float = 0.1
    seed: int = 0

    def __post_init__(self):
        if self.base_s <= 0 or self.factor < 1.0 or self.cap_s < self.base_s:
            raise ValueError("need base_s > 0, factor >= 1, cap_s >= base_s")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")

    def raw_delay(self, attempt: int) -> float:
        """Un-jittered schedule: monotone non-decreasing, capped."""
        if attempt < 0:
            raise ValueError("attempt must be >= 0")
        if self.factor == 1.0:
            return min(self.base_s, self.cap_s)
        # compare in log space: float ** raises OverflowError long
        # before the product could be min()-ed against the cap
        if attempt * math.log(self.factor) >= math.log(self.cap_s
                                                       / self.base_s):
            return self.cap_s
        return min(self.base_s * self.factor ** float(attempt), self.cap_s)

    def delay(self, attempt: int) -> float:
        """Jittered delay for consecutive-failure count `attempt`."""
        raw = self.raw_delay(attempt)
        if self.jitter == 0.0:
            return raw
        h = _hash01((self.seed * 0x9E3779B9 + attempt) & 0xFFFFFFFF)
        return raw * (1.0 + self.jitter * (2.0 * h - 1.0))
