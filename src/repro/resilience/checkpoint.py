"""`PipelineCheckpointer` — step-atomic snapshots of the full ingest state.

Layout (the `train/checkpoint.py` idiom, extended with a host blob):

  <dir>/step_<N>/
    manifest.json        # step, array-leaf index, shapes/dtypes, extra
    <component>.<leaf>.npy   # one file per device-array leaf
    host.pkl             # everything else: buffers, cursors, counters
    _COMMITTED           # written last: restore ignores torn checkpoints

Array components are the pipeline's device pytrees — the `GraphStore`,
the commit-consistent `GraphSketch`es, the `PatternDictionary` — saved
unsharded one `.npy` per leaf.  The host blob carries the rest through
each component's `state()`/`restore_state()` pair: the record buffer +
controller (PerfMon RLS models, spill-file CONTENTS), the consumer
backlog, the MetricsHub trace/counters, the ingestor pool/archive (and
archive spill contents), the source cursor, and the loop scalars.
Because every downstream value is counter-deterministic, restoring all
of it makes a resumed `run_scenario` bit-exact vs an uninterrupted run.

A background thread does the writes (capture is synchronous, so the
snapshot is consistent); `wait()` joins before the next save.  Keep-N
GC and `_COMMITTED`-gated discovery follow `train/checkpoint.py`.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Dict, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.resilience.faults import FaultPlan, PipelineKilled
from repro.telemetry.spans import NULL_REGISTRY


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _array_components(pipe) -> Dict[str, Any]:
    """Name -> device-pytree map of everything that snapshots as .npy
    leaves.  Mirrors the builder's wiring: the sink chain's store and
    sketch, plus any sketch/dictionary record stages."""
    out: Dict[str, Any] = {}
    sink = pipe.sink
    ingestor = getattr(sink, "ingestor", None)
    if ingestor is not None:
        out["store"] = ingestor.store
    sketch = getattr(sink, "sketch", None)
    if sketch is not None:
        out["sink_sketch"] = sketch
    for i, st in enumerate(getattr(pipe, "stages", ())):
        if hasattr(st, "sketch"):
            out[f"stage{i}_sketch"] = st.sketch
        if getattr(st, "dct", None) is not None:
            out[f"stage{i}_dict"] = st.dct
    return out


def _component_templates(pipe, saved_keys: Iterable[str]) -> Dict[str, Any]:
    """Like `_array_components`, but also materialises templates for
    components a FRESH pipeline builds lazily — the pattern dictionary
    is created on first rewrite, so a just-built resume pipeline has
    `dct=None` even though the checkpoint holds one."""
    comp = _array_components(pipe)
    for i, st in enumerate(getattr(pipe, "stages", ())):
        name = f"stage{i}_dict"
        if (name not in comp and hasattr(st, "capacity")
                and any(k.startswith(name + ".") for k in saved_keys)):
            from repro.compress.dictionary import init_dictionary

            comp[name] = init_dictionary(st.capacity)
    return comp


def _assign_components(pipe, restored: Dict[str, Any]) -> None:
    sink = pipe.sink
    ingestor = getattr(sink, "ingestor", None)
    if "store" in restored and ingestor is not None:
        ingestor.store = restored["store"]
    if "sink_sketch" in restored:
        sink.sketch = restored["sink_sketch"]
    for i, st in enumerate(getattr(pipe, "stages", ())):
        if f"stage{i}_sketch" in restored:
            st.sketch = restored[f"stage{i}_sketch"]
        if f"stage{i}_dict" in restored:
            st.dct = restored[f"stage{i}_dict"]


def pytree_digest(tree) -> str:
    """sha256 over every leaf's dtype/shape/bytes — the byte-identity
    witness the chaos harness compares between runs."""
    import hashlib

    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(jax.device_get(leaf))
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


class PipelineCheckpointer:
    """Periodic step-atomic pipeline snapshots (module docstring)."""

    def __init__(self, directory: str, keep: int = 3, every: int = 16,
                 telemetry=None):
        if every < 1:
            raise ValueError("checkpoint cadence `every` must be >= 1")
        self.dir = directory
        self.keep = keep
        self.every = every
        self.telemetry = telemetry or NULL_REGISTRY
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.saves = 0

    # ------------------------------------------------------------------
    def save(self, step: int, pipe, source=None, blocking: bool = False,
             extra: Optional[Dict] = None) -> None:
        """Capture synchronously (consistent cut), write in background."""
        self.wait()
        tel = self.telemetry
        with tel.span("checkpoint.capture"):
            host_arrays = []
            for name, tree in _array_components(pipe).items():
                for p, v in jax.tree_util.tree_flatten_with_path(tree)[0]:
                    host_arrays.append((
                        f"{name}.{_leaf_key(p)}",
                        np.asarray(jax.device_get(v)),
                    ))
            host_state: Dict[str, Any] = {"pipe": pipe.state()}
            if source is not None and hasattr(source, "state"):
                host_state["source"] = source.state()
            blob = pickle.dumps(host_state, protocol=pickle.HIGHEST_PROTOCOL)
        manifest_extra = dict(extra or {})

        def write():
            t0 = time.perf_counter()
            d = os.path.join(self.dir, f"step_{step:08d}")
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": [], "extra": manifest_extra,
                        "host": "host.pkl"}
            for key, arr in host_arrays:
                fn = key.replace("/", "_") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"].append(
                    {"key": key, "file": fn, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "host.pkl"), "wb") as f:
                f.write(blob)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(d):
                shutil.rmtree(d)
            os.rename(tmp, d)
            self._gc()
            tel.observe("checkpoint.write", time.perf_counter() - t0)

        self.saves += 1
        tel.count("checkpoint.saved")
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        for s in self.list_steps()[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self):
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "_COMMITTED")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def restore(self, pipe, source=None, step: Optional[int] = None,
                expect: Optional[Dict] = None) -> Dict:
        """Load the checkpoint into a freshly BUILT pipeline + source
        (same builder configuration as the saved run) and return the
        manifest.  `expect` entries are checked against the manifest's
        `extra` — a scenario/seed/shard mismatch is a hard error, not a
        silently wrong resume."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if expect:
            got = manifest.get("extra", {})
            bad = {k: (got.get(k), v) for k, v in expect.items()
                   if got.get(k) != v}
            if bad:
                raise ValueError(
                    f"checkpoint mismatch in {d}: "
                    + ", ".join(f"{k}: saved={s!r} expected={e!r}"
                                for k, (s, e) in bad.items()))
        tel = self.telemetry
        with tel.span("checkpoint.restore"):
            files = {l["key"]: l["file"] for l in manifest["leaves"]}
            comp = _component_templates(pipe, files.keys())
            restored: Dict[str, Any] = {}
            consumed = set()
            for name, tree in comp.items():
                paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
                leaves = []
                for p, _ in paths:
                    key = f"{name}.{_leaf_key(p)}"
                    if key not in files:
                        raise KeyError(
                            f"checkpoint {d} lacks leaf {key}: the resume "
                            f"pipeline is configured differently from the "
                            f"saved one")
                    leaves.append(jnp.asarray(
                        np.load(os.path.join(d, files[key]))))
                    consumed.add(key)
                restored[name] = jax.tree_util.tree_unflatten(treedef, leaves)
            orphans = set(files) - consumed
            if orphans:
                raise KeyError(
                    f"checkpoint {d} holds components the resume pipeline "
                    f"does not: {sorted(orphans)[:4]}...")
            _assign_components(pipe, restored)
            with open(os.path.join(d, manifest.get("host", "host.pkl")),
                      "rb") as f:
                host = pickle.load(f)
            pipe.restore_state(host["pipe"])
            if source is not None and "source" in host \
                    and hasattr(source, "restore_state"):
                source.restore_state(host["source"])
        return manifest


# ---------------------------------------------------------------------------
# tick driver: checkpoint cadence + crash-at-tick, wrapped around a source
# ---------------------------------------------------------------------------
def drive(source_ticks: Iterable, pipe, source=None,
          checkpointer: Optional[PipelineCheckpointer] = None,
          fault_plan: Optional[FaultPlan] = None, start_tick: int = 0,
          extra: Optional[Dict] = None) -> Iterator:
    """Wrap a tick iterator with periodic checkpoints and the plan's
    crash-at-tick kill.

    The post-yield code runs after the pipeline has FULLY processed the
    yielded tick and before the next one is pulled from the source, so
    a checkpoint's cursor is exact: resume replays from the next tick,
    never re-ingesting or skipping one.  `crash_at_tick` raises
    `PipelineKilled` after the kill tick is processed (a checkpoint due
    at the same tick is written first, durably).
    """
    crash_at = fault_plan.crash_at_tick if fault_plan is not None else None
    tick_no = start_tick
    for tick in source_ticks:
        yield tick
        tick_no += 1
        if checkpointer is not None and tick_no % checkpointer.every == 0:
            hub = getattr(pipe, "metrics", None)
            if hub is not None:
                hub.emit("checkpoint", float(tick_no), step=tick_no)
            checkpointer.save(tick_no, pipe, source, extra=extra)
        if crash_at is not None and tick_no >= crash_at:
            if checkpointer is not None:
                checkpointer.wait()
            raise PipelineKilled(tick_no)
