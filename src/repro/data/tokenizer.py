"""Offline-safe hash tokenizer (no external vocab files).

Word-level with byte fallback: each whitespace token hashes into a
fixed id range; rare-word collisions are acceptable for the synthetic
social stream.  Deterministic across processes (same FNV path as the
graph node ids)."""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

from repro.core.transform import hash_str

PAD, BOS, EOS, RESERVED = 0, 1, 2, 16


class HashTokenizer:
    def __init__(self, vocab_size: int):
        self.vocab_size = vocab_size
        self._range = vocab_size - RESERVED

    def encode(self, text: str, add_special: bool = True) -> List[int]:
        ids = [RESERVED + (hash_str(9, w) % self._range) for w in text.split()]
        if add_special:
            return [BOS] + ids + [EOS]
        return ids

    def encode_batch(self, texts: Iterable[str], seq_len: int) -> np.ndarray:
        out = np.full((len(list(texts)) if not isinstance(texts, list) else len(texts), seq_len), PAD, np.int32)
        texts = list(texts)
        out = np.full((len(texts), seq_len), PAD, np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t)[:seq_len]
            out[i, : len(ids)] = ids
        return out
