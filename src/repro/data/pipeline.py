"""Stream -> LM train-batch pipeline with double-buffered prefetch.

Connects the paper's ingestion pipeline to model training: records
flowing through the adaptive buffer are tokenized into packed LM
sequences on a background thread while the accelerator trains on the
previous batch.  Backpressure flows the other way: if the trainer lags,
the ingestion buffer absorbs it (and the Algorithm-2 controller sees it
as consumer load), so the same control law manages both the store and
the trainer as consumers.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.data.tokenizer import HashTokenizer, PAD


class StreamBatcher:
    """Packs stream records into (tokens, labels) LM batches."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int):
        self.tok = HashTokenizer(vocab_size)
        self.seq_len = seq_len
        self.batch_size = batch_size
        self._carry: list = []

    def _record_text(self, rec: dict) -> str:
        tags = " ".join(rec.get("hashtags", ()))
        ments = " ".join(rec.get("mentions", ()))
        return f"{rec.get('user','')} {rec.get('text','')} {tags} {ments}"

    def add_records(self, records) -> None:
        self._carry.extend(self.tok.encode(self._record_text(r)) for r in records)

    def ready(self) -> bool:
        return len(self._carry) >= self.batch_size

    def next_batch(self) -> Optional[dict]:
        """Greedy packing: each row concatenates whole records."""
        if not self.ready():
            return None
        rows = []
        while len(rows) < self.batch_size and self._carry:
            row: list = []
            while self._carry and len(row) + len(self._carry[0]) <= self.seq_len:
                row.extend(self._carry.pop(0))
            if not row:  # single record longer than seq_len: truncate
                row = self._carry.pop(0)[: self.seq_len]
            rows.append(row)
        if len(rows) < self.batch_size:
            return None
        tokens = np.full((self.batch_size, self.seq_len), PAD, np.int32)
        for i, row in enumerate(rows):
            tokens[i, : len(row)] = row
        labels = np.full_like(tokens, -1)
        labels[:, :-1] = tokens[:, 1:]
        labels[labels == PAD] = -1
        return {"tokens": tokens, "labels": labels}


class PrefetchIterator:
    """Double-buffered background prefetch (host-side pipelining)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, args=(it,), daemon=True)
        self._thread.start()

    def _fill(self, it):
        try:
            for x in it:
                self.q.put(x)
        finally:
            self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self.q.get()
        if x is self._done:
            raise StopIteration
        return x


def stream_batches(source_ticks, vocab_size: int, seq_len: int, batch_size: int,
                   max_batches: Optional[int] = None) -> Iterator[dict]:
    """records -> packed LM batches, double-buffered."""
    def gen():
        b = StreamBatcher(vocab_size, seq_len, batch_size)
        n = 0
        for tick in source_ticks:
            b.add_records(tick.records)
            while b.ready():
                batch = b.next_batch()
                if batch is None:
                    break
                yield batch
                n += 1
                if max_batches and n >= max_batches:
                    return

    return PrefetchIterator(gen())
