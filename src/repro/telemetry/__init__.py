"""`repro.telemetry` — spans, histograms, audit trail, exporters.

The observability layer for the ingest->query path (ISSUE 7): a
near-zero-overhead span/timer API over fixed log-bucket histograms
(`spans`), a structured controller audit trail recording every
Algorithm-2 decision with its full PerfMon input vector and its
realized outcome (`audit`), and exporters — Chrome ``trace_event``
(Perfetto), JSONL, text/TSV summary (`export`).

Quickstart::

    from repro.telemetry import TelemetryRegistry, write_chrome_trace
    reg = TelemetryRegistry()
    pipe = (PipelineBuilder(cfg).with_source(src)
            .with_telemetry(reg).build())
    pipe.run(max_ticks=300)
    print(reg.summary()["commit.upsert"])   # p50/p95/p99 etc.
    write_chrome_trace(reg, "trace.json")   # open in Perfetto

or in one shot via the harness / CLIs::

    run_scenario("flash_crowd", trace="trace.json")
    python -m repro.launch.telemetry --scenario flash_crowd \
        --trace-out trace.json
"""
from repro.telemetry.audit import INPUT_KEYS, AuditRecord, AuditTrail
from repro.telemetry.export import (
    chrome_trace,
    summary_tsv,
    text_summary,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from repro.telemetry.spans import (
    NBUCKETS,
    NULL_REGISTRY,
    NULL_SPAN,
    Histogram,
    Span,
    TelemetryRegistry,
    bucket_index,
    bucket_lower_ns,
    bucket_upper_ns,
)

__all__ = [
    "AuditRecord",
    "AuditTrail",
    "Histogram",
    "INPUT_KEYS",
    "NBUCKETS",
    "NULL_REGISTRY",
    "NULL_SPAN",
    "Span",
    "TelemetryRegistry",
    "bucket_index",
    "bucket_lower_ns",
    "bucket_upper_ns",
    "chrome_trace",
    "summary_tsv",
    "text_summary",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_jsonl",
]
