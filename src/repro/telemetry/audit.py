"""Controller audit trail: every Algorithm-2 decision, explainable.

The paper's controller reacts to *observed* data rate, data content
and machine resources (§III, Algorithm 2) — so a throttle that cannot
show its inputs is indistinguishable from a bug.  `AuditTrail` hooks
`BufferController.decide` and records, per decision:

  * the decision itself (action, reason, new beta) and the
    predictions it was based on (`beta_e_pred`, `mu_pred`, CPU slope);
  * the **full PerfMon input vector** at decision time: rate velocity
    + acceleration, last observed mu, windowed diversity rho, store
    table pressure, dropped inserts (captured *before* the pressure
    throttle consumes them), the sketch-concentration hint and the
    dictionary hit-rate hint, and the spill depth;
  * the **realized outcome** once the tick completes (`resolve`):
    measured mu and the actual effective buffer size, so
    predicted-vs-realized model error is queryable after a run.

Records append to the owning `TelemetryRegistry.audit` (bounded by
``max_audit``), tagged with the trail's shard, so one sharded run
yields one merged, time-ordered decision log.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.telemetry.spans import TelemetryRegistry


@dataclasses.dataclass
class AuditRecord:
    """One controller decision + its inputs and (later) its outcome."""

    seq: int                 # global order within the registry
    t: float                 # stream time of the decision
    ts_ns: int               # monotonic clock (aligns with span events)
    shard: int
    action: str              # push | hold | throttle | drain+push
    reason: str              # throttle cause: "" | "load" | "pressure"
    beta: int                # buffer size the decision set
    beta_e_pred: float       # predicted effective buffer (Eq. 2)
    mu_pred: float           # predicted consumer occupancy (Eq. 4/5)
    slope: float             # CPU slope s
    inputs: Dict[str, Optional[float]]  # full PerfMon vector (below)
    mu_real: Optional[float] = None     # measured mu after the tick
    beta_e_real: Optional[float] = None  # actual effective buffer pushed
    # decision-quality verdict (repro.monitor.quality.score_record):
    # score in [0,1], prediction error, regret vs do-nothing baseline
    quality: Optional[Dict] = None

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


# the PerfMon input-vector keys every record carries
INPUT_KEYS = ("rate", "accel", "mu", "rho", "pressure", "dropped_inserts",
              "sketch_rho", "dict_hit", "spill_depth")


class AuditTrail:
    """Per-controller recorder appending to a shared registry.

    `record` is called by `BufferController.decide` (when a trail is
    attached); `resolve` by the tick loop once the decision's outcome
    (measured mu, realized beta_e) is known.  Resolution applies to
    the most recent unresolved record of this trail — decisions and
    outcomes strictly alternate within one controller's tick loop."""

    def __init__(self, registry: TelemetryRegistry, shard: int = 0):
        self.registry = registry._root
        self.shard = int(shard)
        self._open: Optional[AuditRecord] = None

    def record(self, dec, perfmon, t: Optional[float],
               spill_depth: int, dropped: int) -> None:
        reg = self.registry
        if not reg.enabled or len(reg.audit) >= reg.max_audit:
            return
        vel, acc = perfmon.velocity()
        rho = float(np.mean(perfmon.rho_hist)) if perfmon.rho_hist else 1.0
        rec = AuditRecord(
            seq=len(reg.audit),
            t=float(t) if t is not None else 0.0,
            ts_ns=time.perf_counter_ns(),
            shard=self.shard,
            action=dec.action,
            reason=dec.reason,
            beta=int(dec.beta),
            beta_e_pred=float(dec.beta_e),
            mu_pred=float(dec.mu_exp),
            slope=float(dec.slope),
            inputs={
                "rate": float(vel),
                "accel": float(acc),
                "mu": float(perfmon.mu_hist[-1]) if perfmon.mu_hist else 0.0,
                "rho": rho,
                "pressure": float(perfmon.table_pressure),
                "dropped_inserts": int(dropped),
                "sketch_rho": None if perfmon.sketch_rho is None
                else float(perfmon.sketch_rho),
                "dict_hit": None if perfmon.dict_hit is None
                else float(perfmon.dict_hit),
                "spill_depth": int(spill_depth),
            },
        )
        reg.audit.append(rec)
        self._open = rec

    def resolve(self, mu: float, beta_e: float) -> None:
        rec = self._open
        if rec is None:
            return
        rec.mu_real = float(mu)
        rec.beta_e_real = float(beta_e)
        self._open = None
