"""Telemetry exporters: Chrome trace, JSONL, text/TSV summaries.

  * `chrome_trace` / `write_chrome_trace` — the Chrome ``trace_event``
    JSON format (open in Perfetto / ``chrome://tracing``): every span
    becomes a complete ``"ph": "X"`` event on its shard's track, and
    every controller audit decision an instant ``"ph": "i"`` event
    carrying the full PerfMon input vector in ``args``.
  * `write_jsonl` — a flat machine-readable trace sink: one JSON line
    per span event, audit record, per-stage histogram, and counter.
  * `text_summary` / `summary_tsv` — the one-shot human view
    (``python -m repro.launch.telemetry``): per-stage p50/p95/p99
    table plus the decision timeline.
  * `validate_chrome_trace` — the CI-smoke check: the emitted JSON
    parses and contains >=1 span per required stage.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.telemetry.spans import TelemetryRegistry


def _tid(shard: Optional[int]) -> int:
    # track 0 is the unsharded/main timeline; shard s gets track s+1
    return 0 if shard is None else int(shard) + 1


def chrome_trace(reg: TelemetryRegistry, meta: Optional[Dict] = None,
                 extra_events: Optional[List[Dict]] = None) -> Dict:
    """The registry as a Chrome `trace_event` object (Perfetto-loadable).
    `extra_events` are appended verbatim — e.g. `repro.lineage` flow
    events (``ph: s/t/f`` arrows) linking the spans a batch traversed."""
    root = reg._root
    t0 = root.t0_ns
    events: List[Dict] = []
    tracks = {_tid(s) for (_, s, _, _) in root.events}
    tracks |= {_tid(r.shard) for r in root.audit}
    for tid in sorted(tracks):
        events.append({
            "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
            "args": {"name": "main" if tid == 0 else f"shard{tid - 1}"},
        })
    for (name, shard, s0, s1) in root.events:
        events.append({
            "name": name, "cat": "span", "ph": "X", "pid": 0,
            "tid": _tid(shard),
            "ts": (s0 - t0) / 1e3,       # microseconds since run start
            "dur": max((s1 - s0) / 1e3, 0.001),
        })
    for rec in root.audit:
        events.append({
            "name": f"decision:{rec.action}"
                    + (f":{rec.reason}" if rec.reason else ""),
            "cat": "controller", "ph": "i", "s": "t", "pid": 0,
            "tid": _tid(rec.shard),
            "ts": (rec.ts_ns - t0) / 1e3,
            "args": {
                "beta": rec.beta, "beta_e_pred": rec.beta_e_pred,
                "mu_pred": rec.mu_pred, "slope": rec.slope,
                "mu_real": rec.mu_real, "beta_e_real": rec.beta_e_real,
                **{k: v for k, v in rec.inputs.items()},
            },
        })
    if extra_events:
        events.extend(extra_events)
    out = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "exporter": "repro.telemetry",
            "events_dropped": root.events_dropped,
            **(meta or {}),
        },
    }
    return out


def write_chrome_trace(reg: TelemetryRegistry, path: str,
                       meta: Optional[Dict] = None,
                       extra_events: Optional[List[Dict]] = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(reg, meta, extra_events=extra_events), f)
    return path


def validate_chrome_trace(trace, require_stages: Sequence[str] = ()
                          ) -> Tuple[bool, str]:
    """(ok, message): `trace` is a dict, a path, or a JSON string.
    Checks the trace_event shape and that every `require_stages` name
    appears in >=1 complete ("X") span event."""
    if isinstance(trace, str):
        try:
            if trace.lstrip().startswith("{"):
                trace = json.loads(trace)
            else:
                with open(trace) as f:
                    trace = json.load(f)
        except (OSError, ValueError) as e:
            return False, f"trace does not parse: {e!r}"
    if not isinstance(trace, dict) or not isinstance(
            trace.get("traceEvents"), list):
        return False, "missing traceEvents list"
    spans = [e for e in trace["traceEvents"]
             if isinstance(e, dict) and e.get("ph") == "X"]
    if not spans:
        return False, "no complete span events"
    for e in spans:
        if not all(k in e for k in ("name", "ts", "dur", "pid", "tid")):
            return False, f"malformed span event: {e}"
    seen = {e["name"] for e in spans}
    missing = [s for s in require_stages if s not in seen]
    if missing:
        return False, f"stages with no span events: {missing}"
    return True, f"{len(spans)} spans over {len(seen)} stages"


def write_jsonl(reg: TelemetryRegistry, path: str) -> str:
    """Flat JSONL trace sink: spans, audit records, histograms, counters."""
    root = reg._root
    t0 = root.t0_ns
    with open(path, "w") as f:
        # meta first so consumers can judge completeness before reading
        # the rest: a nonzero events_dropped means the span *list* is
        # truncated (histograms and counters below stay exact)
        f.write(json.dumps({
            "type": "meta", "exporter": "repro.telemetry",
            "events_dropped": root.events_dropped,
            "max_events": root.max_events,
            "spans": len(root.events), "audit_records": len(root.audit),
        }) + "\n")
        for (name, shard, s0, s1) in root.events:
            f.write(json.dumps({
                "type": "span", "name": name, "shard": shard,
                "t_us": (s0 - t0) / 1e3, "dur_us": (s1 - s0) / 1e3,
            }) + "\n")
        for rec in root.audit:
            d = rec.to_dict()
            # explicit resolution marker: records the run never resolved
            # (e.g. the loop stopped mid-tick) export with realized=null
            # rather than erroring or being skipped
            d["realized"] = None if rec.mu_real is None else \
                {"mu": rec.mu_real, "beta_e": rec.beta_e_real}
            f.write(json.dumps({"type": "audit", **d}) + "\n")
        for (name, shard), h in sorted(root._hists.items(),
                                       key=lambda kv: (kv[0][0],
                                                       kv[0][1] is not None,
                                                       kv[0][1] or 0)):
            f.write(json.dumps({"type": "histogram", "name": name,
                                "shard": shard, **h.stats()}) + "\n")
        for name, n in sorted(root.counters.items()):
            f.write(json.dumps({"type": "counter", "name": name,
                                "count": n}) + "\n")
    return path


# ---------------------------------------------------------------------------
# human-readable summaries
# ---------------------------------------------------------------------------

_COLS = ("count", "mean_ms", "p50_ms", "p95_ms", "p99_ms", "max_ms",
         "total_s")


def summary_tsv(reg: TelemetryRegistry) -> str:
    """Per-stage latency table (aggregated across shards) as TSV.
    A `#`-prefixed warning line trails the table when span events were
    dropped past max_events (the table itself stays exact)."""
    root = reg._root
    lines = ["stage\t" + "\t".join(_COLS)]
    for name, st in sorted(root.summary().items()):
        lines.append(name + "\t" + "\t".join(str(st[c]) for c in _COLS))
    if root.events_dropped:
        lines.append(f"# WARNING: {root.events_dropped} span events "
                     f"dropped past max_events={root.max_events} "
                     f"(histograms above stay exact)")
    return "\n".join(lines)


def text_summary(reg: TelemetryRegistry, max_decisions: int = 20) -> str:
    """Per-stage p50/p95/p99 table + counters + the decision timeline."""
    root = reg._root
    out = ["== per-stage latency (all shards) =="]
    summ = root.summary()
    if summ:
        w = max(len(n) for n in summ) + 2
        out.append(f"{'stage':<{w}}{'count':>8}{'mean_ms':>10}{'p50_ms':>10}"
                   f"{'p95_ms':>10}{'p99_ms':>10}{'total_s':>10}")
        for name in sorted(summ, key=lambda n: -summ[n]["total_s"]):
            st = summ[name]
            out.append(f"{name:<{w}}{st['count']:>8}{st['mean_ms']:>10.3f}"
                       f"{st['p50_ms']:>10.3f}{st['p95_ms']:>10.3f}"
                       f"{st['p99_ms']:>10.3f}{st['total_s']:>10.3f}")
    else:
        out.append("(no spans recorded — was telemetry enabled?)")
    if root.events_dropped:
        out.append(f"(!) {root.events_dropped} span events dropped past "
                   f"max_events={root.max_events} (histograms stay exact)")
    if root.counters:
        out.append("\n== event counters ==")
        out.append("  " + "  ".join(f"{k}={v}"
                                    for k, v in sorted(root.counters.items())))
    out.append(f"\n== controller decisions ({len(root.audit)} recorded) ==")
    interesting = [r for r in root.audit
                   if r.action in ("throttle", "drain+push") or r.reason]
    shown = (interesting or root.audit)[:max_decisions]
    for r in shown:
        rsn = f" reason={r.reason}" if r.reason else ""
        mu_r = "-" if r.mu_real is None else f"{r.mu_real:.3f}"
        # .get: records from hand-built or partially-restored trails may
        # not carry the full PerfMon input vector
        out.append(
            f"  t={r.t:8.1f} shard={r.shard} {r.action:<10}{rsn:<17}"
            f"beta={r.beta:<6} mu_pred={r.mu_pred:.3f} mu_real={mu_r} "
            f"rate={r.inputs.get('rate', 0.0):.1f} "
            f"rho={r.inputs.get('rho', 0.0):.3f} "
            f"pressure={r.inputs.get('pressure', 0.0):.3f} "
            f"spill={r.inputs.get('spill_depth', 0)}")
    if len(root.audit) > len(shown):
        out.append(f"  ... {len(root.audit) - len(shown)} more "
                   f"(JSONL/Chrome trace has all)")
    return "\n".join(out)
