"""Span/timer API + streaming-safe histograms (`TelemetryRegistry`).

The diagnostic substrate for the whole ingest->query path: every
instrumented stage wraps its hot section in ``registry.span("name")``
(context-manager or ``@registry.timed`` decorator form) and the
registry accumulates the durations into **fixed log-bucket
histograms** — 64 power-of-two latency buckets over integer
nanoseconds, so a run of any length costs O(1) memory per stage and
bucket assignment is *exact* integer math (``bit_length``), never a
float-log off-by-one at a boundary.

Overhead discipline:

  * disabled registry (``enabled=False``, the default everywhere a
    registry is merely threaded through): ``span()`` returns the one
    preallocated ``NULL_SPAN`` singleton — **no Span object is
    constructed**, no histogram touched, no event appended.  The whole
    per-call cost is one attribute read and one branch.
  * enabled registry: one ``time.perf_counter_ns`` pair per span, an
    O(1) histogram update, and one bounded event-list append (the
    Chrome-trace timeline; capped at ``max_events``, overflow counted
    in ``events_dropped`` — never an unbounded list).

Shard fan-out uses **child registries** (`child(shard)`): a child
shares the root's histogram/event/audit storage (spans it records are
tagged with its shard) but owns its *own* ``counters`` — so N
per-shard ``MetricsHub``s keep independent event counts while their
span timelines land in one trace.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Dict, List, Optional, Tuple

NBUCKETS = 64  # bucket i (i>=1) holds durations in [2^(i-1), 2^i) ns


def bucket_index(ns: int) -> int:
    """Exact log2 bucket for an integer-nanosecond duration.

    ``0 -> 0``; otherwise ``ns.bit_length()`` clipped to the last
    bucket: a duration of exactly ``2**k`` ns lands in bucket ``k+1``
    (the half-open bucket ``[2**k, 2**(k+1))``) — pure integer math,
    exact at every boundary."""
    if ns <= 0:
        return 0
    return min(ns.bit_length(), NBUCKETS - 1)


def bucket_lower_ns(i: int) -> int:
    """Inclusive lower bound of bucket `i` in ns (0 for bucket 0)."""
    return 0 if i <= 0 else 1 << (i - 1)


def bucket_upper_ns(i: int) -> int:
    """Exclusive upper bound of bucket `i` in ns."""
    return 1 if i <= 0 else 1 << i


class Histogram:
    """Fixed-size log-bucket latency histogram (streaming-safe).

    Exact ``count``/``sum``/``max`` plus 64 power-of-two buckets;
    percentiles are conservative (they report the matching bucket's
    upper bound, so p95 never under-reports)."""

    __slots__ = ("counts", "count", "sum_ns", "max_ns")

    def __init__(self):
        self.counts = [0] * NBUCKETS
        self.count = 0
        self.sum_ns = 0
        self.max_ns = 0

    def record_ns(self, ns: int) -> None:
        self.counts[bucket_index(ns)] += 1
        self.count += 1
        self.sum_ns += ns
        if ns > self.max_ns:
            self.max_ns = ns

    def merge(self, other: "Histogram") -> "Histogram":
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum_ns += other.sum_ns
        self.max_ns = max(self.max_ns, other.max_ns)
        return self

    def copy(self) -> "Histogram":
        h = Histogram()
        h.counts = list(self.counts)
        h.count = self.count
        h.sum_ns = self.sum_ns
        h.max_ns = self.max_ns
        return h

    def since(self, prev: "Histogram") -> "Histogram":
        """The delta histogram vs an earlier snapshot of this series
        (`prev` must be a previous cumulative state).  Bucket counts
        and count/sum subtract exactly; `max_ns` is the cumulative max
        (a true window max is not recoverable from snapshots) — the
        conservative-percentile property is preserved because the
        delta's percentile clamp still uses a max >= any window value."""
        h = Histogram()
        h.counts = [a - b for a, b in zip(self.counts, prev.counts)]
        h.count = self.count - prev.count
        h.sum_ns = self.sum_ns - prev.sum_ns
        h.max_ns = self.max_ns
        return h

    def percentile_ns(self, q: float) -> int:
        """Upper bound of the bucket holding the q-quantile (q in [0,1])."""
        if self.count == 0:
            return 0
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target and c:
                return min(bucket_upper_ns(i), self.max_ns) if i else 0
        return self.max_ns

    @property
    def mean_ns(self) -> float:
        return self.sum_ns / self.count if self.count else 0.0

    def stats(self) -> Dict[str, float]:
        ms = 1e-6
        return {
            "count": self.count,
            "mean_ms": round(self.mean_ns * ms, 6),
            "p50_ms": round(self.percentile_ns(0.50) * ms, 6),
            "p95_ms": round(self.percentile_ns(0.95) * ms, 6),
            "p99_ms": round(self.percentile_ns(0.99) * ms, 6),
            "max_ms": round(self.max_ns * ms, 6),
            "total_s": round(self.sum_ns * 1e-9, 6),
        }


class _NullSpan:
    """The disabled-path span: one preallocated, reusable no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Span:
    """An open timing span; records into the registry on ``__exit__``."""

    __slots__ = ("_reg", "name", "shard", "t0")

    def __init__(self, reg: "TelemetryRegistry", name: str,
                 shard: Optional[int]):
        self._reg = reg
        self.name = name
        self.shard = shard

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self._reg._finish(self.name, self.shard, self.t0,
                          time.perf_counter_ns())
        return False


class TelemetryRegistry:
    """Typed span/histogram/counter/audit store for one run.

    * ``span(name)`` / ``timed(name)`` — the timer API (gated: the
      disabled path allocates nothing).
    * ``observe(name, seconds)`` — record an externally measured
      duration (gated like spans).
    * ``counters`` — a plain ``collections.Counter`` that is ALWAYS
      live (MetricsHub event counts ride here even when span telemetry
      is off; incrementing a dict int is the pre-telemetry cost).
    * ``audit`` — the controller decision trail (`repro.telemetry.audit`
      appends; stored here so exporters see one object).
    * ``child(shard)`` — shard-tagged view sharing this registry's
      span/event/audit storage but owning its own ``counters``.
    """

    def __init__(self, enabled: bool = True, max_events: int = 200_000):
        self._root: "TelemetryRegistry" = self
        self._enabled = enabled
        self.shard: Optional[int] = None
        self.counters: collections.Counter = collections.Counter()
        self._hists: Dict[Tuple[str, Optional[int]], Histogram] = {}
        self.events: List[Tuple[str, Optional[int], int, int]] = []
        self.max_events = max_events
        self.events_dropped = 0
        self.audit: list = []  # AuditRecord list (repro.telemetry.audit)
        self.max_audit = max_events
        self.t0_ns = time.perf_counter_ns()

    # ---- enable state lives on the root (children mirror it) ----
    @property
    def enabled(self) -> bool:
        return self._root._enabled

    @enabled.setter
    def enabled(self, v: bool) -> None:
        self._root._enabled = bool(v)

    def child(self, shard: int) -> "TelemetryRegistry":
        c = TelemetryRegistry.__new__(TelemetryRegistry)
        c._root = self._root
        c.shard = shard
        c.counters = collections.Counter()
        return c

    # ---- span API ----
    def span(self, name: str, shard: Optional[int] = None):
        root = self._root
        if not root._enabled:
            return NULL_SPAN
        return Span(root, name, self.shard if shard is None else shard)

    def timed(self, name: str, shard: Optional[int] = None) -> Callable:
        """Decorator form: time every call of the wrapped function."""

        def deco(fn):
            import functools

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(name, shard=shard):
                    return fn(*a, **kw)

            return wrapper

        return deco

    def observe(self, name: str, seconds: float,
                shard: Optional[int] = None) -> None:
        root = self._root
        if not root._enabled:
            return
        ns = int(seconds * 1e9)
        t1 = time.perf_counter_ns()
        root._finish(name, self.shard if shard is None else shard,
                     t1 - ns, t1)

    def count(self, name: str, n: int = 1) -> None:
        if self._root._enabled:
            self.counters[name] += n

    # ---- storage (root only) ----
    def _finish(self, name: str, shard: Optional[int],
                t0: int, t1: int) -> None:
        key = (name, shard)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram()
        h.record_ns(t1 - t0)
        if len(self.events) < self.max_events:
            self.events.append((name, shard, t0, t1))
        else:
            self.events_dropped += 1

    def hist(self, name: str, shard: Optional[int] = None) -> Histogram:
        """The (name, shard) histogram (empty one if never recorded)."""
        return self._root._hists.get((name, shard)) or Histogram()

    # ---- aggregation ----
    def stage_names(self) -> List[str]:
        return sorted({n for (n, _) in self._root._hists})

    def shards(self) -> List[int]:
        return sorted({s for (_, s) in self._root._hists if s is not None})

    def aggregate(self, name: str) -> Histogram:
        """One histogram for `name` merged across all shards."""
        out = Histogram()
        for (n, _), h in self._root._hists.items():
            if n == name:
                out.merge(h)
        return out

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-stage stats aggregated across shards: count, mean,
        p50/p95/p99, max, total — the `WorkloadReport`/CLI payload."""
        return {n: self.aggregate(n).stats() for n in self.stage_names()}


class SeriesTap:
    """Incremental reader over a registry's cumulative state.

    The online-monitoring primitive (repro.monitor): histograms and
    counters accumulate for the whole run, but a standing detector
    needs *per-interval* values.  A tap remembers the last snapshot it
    took of each series and returns exact deltas:

        tap = SeriesTap(reg)
        ...                                   # one tick elapses
        d = tap.hist_delta("commit.upsert")   # this interval only
        d.count, d.mean_ns, d.percentile_ns(0.99)
        n = tap.counter_delta("commit")       # counter increments

    Deltas are exact integer subtraction on the fixed log-bucket
    state — O(NBUCKETS) per poll, no per-event cost, and polling never
    perturbs the registry.  Histogram reads aggregate across shards
    (the monitor watches the fleet, not one shard).
    """

    def __init__(self, registry: "TelemetryRegistry"):
        self.registry = registry._root
        self._hist_prev: Dict[str, Histogram] = {}
        self._counter_prev: Dict[str, int] = {}

    def hist_delta(self, name: str) -> Histogram:
        """Delta histogram for `name` (all shards) since the last poll."""
        cur = self.registry.aggregate(name)
        prev = self._hist_prev.get(name)
        self._hist_prev[name] = cur
        return cur if prev is None else cur.since(prev)

    def counter_delta(self, name: str) -> int:
        """Increment of `registry.counters[name]` since the last poll."""
        cur = int(self.registry.counters.get(name, 0))
        d = cur - self._counter_prev.get(name, 0)
        self._counter_prev[name] = cur
        return d


# The module-wide disabled registry: instrumented classes default
# their ``telemetry`` attribute to this so the hot path needs no None
# check.  Span/observe/count are all no-ops on it (``count`` is gated
# by `enabled`, so the shared singleton never accumulates state).
NULL_REGISTRY = TelemetryRegistry(enabled=False)
