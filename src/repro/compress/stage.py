"""Dictionary-compression pipeline stages (GraphZip rewrite path).

`DictionaryStage.rewrite` turns one dedup'd `EdgeTable` into a
`CompressedCommit`: the batch's dictionary hits become `(pattern_id,
bindings)` *references* — the binding is the cached (edge, src, dst)
store-slot triple — and the misses become a smaller residual
`EdgeTable` that takes the normal two-sweep commit.  Mining
(`repro.kernels.pattern_mine`) marks which residual edges belong to
frequent patterns; after the store confirms their slots,
`observe_commit` admits them to the dictionary so the NEXT occurrence
is a reference.

Bit-exactness: an edge's first-ever appearance is always a dictionary
miss (the dictionary only holds previously committed edges), so it is
inserted by the residual sweep exactly as the raw path would; present
keys never claim empty slots in `upsert_sweep`, so the scatter races
involve the same new-key set in both paths and every placement/count
lands identically — `tests/test_compress.py` asserts full store
equality against the uncompressed path.

`CompressedCommit` duck-types the `EdgeTable` surface the rest of the
system reads (`controlled_tick` metadata, `sketch_update` fields), so
sinks, sketches and the snapshot maintainer observe compressed commits
unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import dedup_with_counts, mix_keys
from repro.core.edge_table import EdgeTable
from repro.compress.dictionary import (
    PatternDictionary,
    dict_admit,
    dict_lookup,
    init_dictionary,
)

REF_MIN_CAP = 8  # smallest static reference-array capacity


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedCommit:
    """One batch rewritten as residual EdgeTable + pattern references.

    Reference arrays are (R,) at a static power-of-two capacity;
    `ref_eslot`/`ref_sslot`/`ref_dslot` are the dictionary's cached
    store slots (the reference bindings), `ref_pattern` the dictionary
    entry index (the pattern id).  Scalar metadata keeps the FULL
    batch's unique node/edge counts so controller signals (density,
    size, rho denominator) match the uncompressed path.
    """

    residual: EdgeTable
    res_admit: jax.Array    # (rcap,) bool — mined pattern members to admit
    res_psig: jax.Array     # (rcap,) key dtype — their pattern signatures
    ref_src: jax.Array      # (R,) key dtype
    ref_dst: jax.Array      # (R,) key dtype
    ref_etype: jax.Array    # (R,) int32
    ref_count: jax.Array    # (R,) int32 batch multiplicity
    ref_eslot: jax.Array    # (R,) int32 store edge slot (binding)
    ref_sslot: jax.Array    # (R,) int32 store src-node slot
    ref_dslot: jax.Array    # (R,) int32 store dst-node slot
    ref_pattern: jax.Array  # (R,) int32 dictionary entry (pattern id)
    ref_valid: jax.Array    # (R,) bool
    n_refs: jax.Array       # scalar int32
    n_raw: jax.Array        # scalar int32 full-batch raw instructions
    n_nodes_full: jax.Array  # scalar int32 full-batch unique nodes
    n_edges_full: jax.Array  # scalar int32 full-batch unique edges

    def tree_flatten(self):
        return (self.residual, self.res_admit, self.res_psig, self.ref_src,
                self.ref_dst, self.ref_etype, self.ref_count, self.ref_eslot,
                self.ref_sslot, self.ref_dslot, self.ref_pattern,
                self.ref_valid, self.n_refs, self.n_raw, self.n_nodes_full,
                self.n_edges_full), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ---- EdgeTable duck-type surface (sketch_update reads these) ----
    @property
    def src(self):
        return jnp.concatenate([self.residual.src, self.ref_src])

    @property
    def dst(self):
        return jnp.concatenate([self.residual.dst, self.ref_dst])

    @property
    def etype(self):
        return jnp.concatenate([self.residual.etype, self.ref_etype])

    @property
    def count(self):
        return jnp.concatenate([self.residual.count, self.ref_count])

    @property
    def edge_valid(self):
        return jnp.concatenate([self.residual.edge_valid, self.ref_valid])

    @property
    def node_ids(self):
        return jnp.concatenate([
            self.residual.node_ids,
            jnp.where(self.ref_valid, self.ref_src, 0),
            jnp.where(self.ref_valid, self.ref_dst, 0)])

    @property
    def node_valid(self):
        return jnp.concatenate([self.residual.node_valid,
                                self.ref_valid, self.ref_valid])

    # ---- table-level metadata (controlled_tick reads these) ----
    def density(self) -> jax.Array:
        v = jnp.maximum(self.n_nodes_full.astype(jnp.float32), 2.0)
        return 2.0 * self.n_edges_full.astype(jnp.float32) / (v * (v - 1.0))

    def size(self) -> jax.Array:
        return self.n_edges_full + self.n_nodes_full

    def compression_ratio(self) -> jax.Array:
        """Fig. 13 accounting with references: a reference costs ONE
        instruction (vs 1 edge + up to 2 node instructions raw)."""
        eff = (self.residual.n_nodes + self.residual.n_edges
               + self.n_refs).astype(jnp.float32)
        raw = jnp.maximum((3 * self.n_raw).astype(jnp.float32), 1.0)
        return eff / raw


def _empty_refs(kd, cap: int = REF_MIN_CAP):
    return dict(
        ref_src=jnp.zeros((cap,), kd), ref_dst=jnp.zeros((cap,), kd),
        ref_etype=jnp.zeros((cap,), jnp.int32),
        ref_count=jnp.zeros((cap,), jnp.int32),
        ref_eslot=jnp.full((cap,), -1, jnp.int32),
        ref_sslot=jnp.full((cap,), -1, jnp.int32),
        ref_dslot=jnp.full((cap,), -1, jnp.int32),
        ref_pattern=jnp.full((cap,), -1, jnp.int32),
        ref_valid=jnp.zeros((cap,), bool),
        n_refs=jnp.zeros((), jnp.int32),
    )


@partial(jax.jit, static_argnames=("rcap", "refcap"))
def _split(et: EdgeTable, hit, admit, psig, eslot, sslot, dslot, entry,
           rcap: int, refcap: int) -> CompressedCommit:
    """Compact dictionary hits into reference arrays and misses into a
    residual EdgeTable (static power-of-two capacities)."""
    keep = et.edge_valid & ~hit
    order = jnp.argsort(~keep)  # stable: kept edges first, in order
    sidx = order[:rcap]
    rvalid = keep[sidx]
    zed = lambda a: jnp.where(rvalid, a[sidx], 0)
    rsrc, rdst = zed(et.src), zed(et.dst)
    rety, rcnt = zed(et.etype), zed(et.count)
    ncomp = dedup_with_counts(jnp.concatenate([rsrc, rdst]),
                              jnp.concatenate([rvalid, rvalid]))
    nidx = lambda k: jnp.clip(
        jnp.searchsorted(ncomp.keys, k).astype(jnp.int32), 0, 2 * rcap - 1)
    residual = EdgeTable(
        src=rsrc, dst=rdst, etype=rety, count=rcnt, edge_valid=rvalid,
        node_ids=ncomp.keys, node_valid=ncomp.valid,
        src_node_idx=nidx(rsrc), dst_node_idx=nidx(rdst),
        n_edges=jnp.sum(rvalid.astype(jnp.int32)),
        n_nodes=ncomp.n_unique,
        n_raw=jnp.sum(jnp.where(rvalid, rcnt, 0)),
    )
    rorder = jnp.argsort(~hit)
    ridx = rorder[:refcap]
    refv = hit[ridx]
    gk = lambda a: jnp.where(refv, a[ridx], 0)
    gi = lambda a: jnp.where(refv, a[ridx], -1)
    return CompressedCommit(
        residual=residual,
        res_admit=admit[sidx] & rvalid,
        res_psig=jnp.where(rvalid, psig[sidx], 0),
        ref_src=gk(et.src), ref_dst=gk(et.dst),
        ref_etype=jnp.where(refv, et.etype[ridx], 0),
        ref_count=jnp.where(refv, et.count[ridx], 0),
        ref_eslot=gi(eslot), ref_sslot=gi(sslot), ref_dslot=gi(dslot),
        ref_pattern=gi(entry),
        ref_valid=refv,
        n_refs=jnp.sum(refv.astype(jnp.int32)),
        n_raw=et.n_raw,
        n_nodes_full=et.n_nodes,
        n_edges_full=et.n_edges,
    )


def _pow2(n: int, lo: int) -> int:
    return max(lo, 1 << int(np.ceil(np.log2(max(n, 1)))))


class DictionaryStage:
    """Stage-protocol owner of the pattern dictionary.

    As a record stage it is a pass-through observer (the heavy lifting
    happens at transform time via `rewrite`); `PipelineBuilder
    .with_compression()` wires it in and registers `observe_commit` on
    the sink's ingestor so admissions see confirmed store slots.
    """

    name = "dictionary"

    def __init__(self, capacity: int = 4096, star_min: int = 4,
                 hot_min: int = 2, ttl: int = 64,
                 use_kernel: Optional[bool] = None):
        from repro.telemetry.spans import NULL_REGISTRY

        self.capacity = int(capacity)
        self.star_min = int(star_min)
        self.hot_min = int(hot_min)
        self.ttl = int(ttl)
        self.use_kernel = use_kernel
        self.dct: Optional[PatternDictionary] = None
        self.ticks_seen = 0
        self.rewrites = 0
        self.refs_total = 0
        self.telemetry = NULL_REGISTRY

    # ---- Stage protocol ----
    def __call__(self, records: List[dict], ctx=None) -> List[dict]:
        self.ticks_seen += 1
        return records

    # ---- checkpoint surface (repro.resilience); the dictionary itself
    # snapshots as array leaves (lazily re-templated on restore) ----
    def state(self) -> dict:
        return {"ticks_seen": self.ticks_seen, "rewrites": self.rewrites,
                "refs_total": self.refs_total}

    def restore_state(self, s: dict) -> None:
        self.ticks_seen = int(s["ticks_seen"])
        self.rewrites = int(s["rewrites"])
        self.refs_total = int(s["refs_total"])

    # ---- rewrite path ----
    def _ensure(self, kd):
        if self.dct is None or self.dct.sig.dtype != kd:
            self.dct = init_dictionary(self.capacity, kd)

    def rewrite(self, et: EdgeTable) -> CompressedCommit:
        """Mine + dictionary lookup + split one dedup'd batch."""
        from repro.kernels import ops

        kd = et.src.dtype
        tel = self.telemetry
        self._ensure(kd)
        with tel.span("rewrite.mine"):
            fan_out, fan_in, flags, psig = ops.pattern_mine(
                et.src, et.dst, et.etype, et.count, et.edge_valid,
                self.star_min, self.hot_min, use_kernel=self.use_kernel)
        with tel.span("rewrite.lookup"):
            keys = mix_keys(et.src, et.dst, et.etype)
            self.dct, hit, eslot, sslot, dslot, entry = dict_lookup(
                self.dct, keys, et.edge_valid)
            n_ref = int(jnp.sum(hit.astype(jnp.int32)))
        admit = (flags != 0) & et.edge_valid & ~hit
        self.rewrites += 1
        self.refs_total += n_ref
        if n_ref == 0:
            # nothing referenced: the batch IS the residual
            return CompressedCommit(
                residual=et, res_admit=admit,
                res_psig=jnp.where(et.edge_valid, psig, 0),
                n_raw=et.n_raw, n_nodes_full=et.n_nodes,
                n_edges_full=et.n_edges, **_empty_refs(kd))
        cap = et.src.shape[0]
        n_valid = int(jnp.sum(et.edge_valid.astype(jnp.int32)))
        rcap = min(_pow2(max(n_valid - n_ref, 1), 64), cap)
        refcap = min(_pow2(n_ref, REF_MIN_CAP), cap)
        with tel.span("rewrite.split"):
            return _split(et, hit, admit, psig, eslot, sslot, dslot, entry,
                          rcap, refcap)

    # ---- commit feedback (ingestor.commit_hooks) ----
    def observe_commit(self, committed, stats) -> None:
        """Admit the just-committed batch's mined pattern members using
        the slots the commit confirmed (`nslot`/`eslot` commit stats)."""
        if self.dct is None or stats is None:
            return
        res = getattr(committed, "residual", None)
        admit_mask = getattr(committed, "res_admit", None)
        if res is None or admit_mask is None:
            return
        eslot = stats.get("eslot")
        nslot = stats.get("nslot")
        if eslot is None or nslot is None:
            return
        with self.telemetry.span("dict.admit"):
            sslot = nslot[res.src_node_idx]
            dslot = nslot[res.dst_node_idx]
            admit = admit_mask & (eslot >= 0) & (sslot >= 0) & (dslot >= 0)
            keys = mix_keys(res.src, res.dst, res.etype)
            self.dct = dict_admit(self.dct, keys, admit, eslot, sslot, dslot,
                                  committed.res_psig, ttl=self.ttl)

    # ---- observability ----
    def stats(self) -> dict:
        if self.dct is None:
            return {"entries": 0, "load": 0.0, "hit_rate": 0.0,
                    "evictions": 0, "rewrites": self.rewrites,
                    "refs_total": self.refs_total}
        return {
            "entries": int(self.dct.n_entries),
            "load": self.dct.load(),
            "hit_rate": self.dct.hit_rate(),
            "evictions": int(self.dct.evictions),
            "rewrites": self.rewrites,
            "refs_total": self.refs_total,
        }


class CompressingTransform:
    """Transform-protocol wrapper: inner encode, then dictionary
    rewrite.  The instruction count refs actually cost (one per
    reference) replaces the plain compressed count, which is how
    compressibility reaches the consumer model and the controller."""

    def __init__(self, inner, stage: DictionaryStage):
        self.inner = inner
        self.stage = stage
        self.name = f"{inner.name}+dict"

    # one registry drives both halves (builder sets .telemetry once)
    @property
    def telemetry(self):
        return self.stage.telemetry

    @telemetry.setter
    def telemetry(self, reg):
        self.stage.telemetry = reg
        if hasattr(self.inner, "telemetry"):
            self.inner.telemetry = reg

    def encode(self, records: List[dict]) -> Tuple[CompressedCommit, int, int]:
        et, _, raw_instr = self.inner.encode(records)
        cc = self.stage.rewrite(et)
        n_instr = (int(cc.residual.n_nodes) + int(cc.residual.n_edges)
                   + int(cc.n_refs))
        return cc, n_instr, raw_instr
