"""repro.compress — ingestion-time dictionary compression (GraphZip).

Cross-batch counterpart of the Algorithm-1 within-batch dedup: a
device-resident dictionary of frequently recurring edges (members of
mined star-burst / cascade-chain / hot-edge patterns) lets the
pipeline rewrite each batch into compact pattern *references* plus a
residual raw-edge tail.  References commit by direct scatter to their
cached store slots — zero probe rounds — so the redundant portion of a
bursty stream stops paying the hash-table toll every batch (GraphZip,
Packer & Holder, arXiv:1703.08614; the ROADMAP "ingestion-time
dictionary compression" item).

    pipe = (PipelineBuilder(cfg)
            .with_source(src)
            .with_compression()          # DictionaryStage + rewrite
            .build())

Pieces:
  * `repro.kernels.pattern_mine` — per-batch frequent-substructure
    miner (Pallas kernel + bit-exact jnp oracle),
  * `PatternDictionary` (`dictionary.py`) — fixed-capacity signature
    table + ref counts + LRU clock, counter-deterministic eviction,
  * `DictionaryStage` / `CompressingTransform` (`stage.py`) — the
    pipeline stages producing `CompressedCommit` batches,
  * `commit_compressed` (repro.graphstore.store) — the pattern-aware
    commit expanding references bit-exactly into the store.
"""
from repro.compress.dictionary import (
    DICT_PROBES,
    PatternDictionary,
    dict_admit,
    dict_lookup,
    init_dictionary,
)
from repro.compress.stage import (
    CompressedCommit,
    CompressingTransform,
    DictionaryStage,
)

__all__ = [
    "DICT_PROBES",
    "PatternDictionary",
    "dict_admit",
    "dict_lookup",
    "init_dictionary",
    "CompressedCommit",
    "CompressingTransform",
    "DictionaryStage",
]
