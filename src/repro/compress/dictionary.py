"""Device-resident pattern dictionary (GraphZip's frequent-pattern set).

A fixed-capacity open-addressing table over *edge signatures*: an
entry is one member edge of a mined pattern, keyed by its
`mix_keys(src, dst, etype)` signature, with the pattern signature that
admitted it (`psig`, lineage) and — the payload that makes references
cheap — the store slots the edge and its endpoints were committed to.
A later batch containing the same edge resolves it to a
`(pattern_id, bindings)` reference: the binding IS the cached slot
triple, so the commit path applies it by direct scatter instead of
re-probing three hash tables.

Lifecycle (all counter-deterministic — no wall clock, no RNG):
  * `dict_lookup`  per batch: probe every dedup'd edge key; hits bump
    `refcount` and stamp `clock` with the dictionary tick (LRU), the
    tick advances once per batch.
  * `dict_admit`   after a successful commit: insert the batch's
    pattern-member residual edges (slots now known) via the same
    fused `upsert_sweep` the store uses.
  * eviction       aging sweep inside `dict_admit`: once occupancy
    passes the high-water mark, entries idle for more than `ttl`
    ticks are cleared.  Clearing can break probe chains for entries
    inserted behind an evicted slot; those entries simply stop being
    found (a miss, never a wrong hit) and are re-admitted on their
    next commit — correctness never depends on a dictionary hit.

The dictionary survives across batches by construction and across
shards because `ShardedPipeline` shares ONE transform/sink per run —
a single dictionary observes every commit.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.upsert import probe_hash, upsert_sweep

DICT_PROBES = 16  # fixed probe budget (table never exceeds high water)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PatternDictionary:
    """Fixed-capacity signature table + payload + LRU bookkeeping."""

    sig: jax.Array       # (C,) key dtype; 0 = empty slot
    psig: jax.Array      # (C,) key dtype; mined pattern signature (lineage)
    eslot: jax.Array     # (C,) int32 cached store edge slot
    sslot: jax.Array     # (C,) int32 cached store slot of src node
    dslot: jax.Array     # (C,) int32 cached store slot of dst node
    refcount: jax.Array  # (C,) int32 lifetime reference hits
    clock: jax.Array     # (C,) int32 dictionary tick of last touch (LRU)
    tick: jax.Array      # scalar int32, advances once per lookup batch
    n_entries: jax.Array  # scalar int32 live entries
    hits: jax.Array      # scalar int32 cumulative reference hits
    misses: jax.Array    # scalar int32 cumulative lookup misses
    evictions: jax.Array  # scalar int32 cumulative aged-out entries

    def tree_flatten(self):
        # explicit field tuple, NOT dataclasses.astuple (see
        # CompressedBatch.tree_flatten for the recursion bug class)
        return (self.sig, self.psig, self.eslot, self.sslot, self.dslot,
                self.refcount, self.clock, self.tick, self.n_entries,
                self.hits, self.misses, self.evictions), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.sig.shape[0]

    def load(self) -> float:
        return int(self.n_entries) / max(self.capacity, 1)

    def hit_rate(self) -> float:
        total = int(self.hits) + int(self.misses)
        return int(self.hits) / max(total, 1)


def init_dictionary(capacity: int, key_dtype=None) -> PatternDictionary:
    from repro.core.compression import key_dtype as kd_fn

    kd = key_dtype or kd_fn()
    zk = lambda: jnp.zeros((capacity,), kd)
    z32 = lambda: jnp.zeros((capacity,), jnp.int32)
    zs = lambda: jnp.zeros((), jnp.int32)
    return PatternDictionary(
        sig=zk(), psig=zk(), eslot=z32(), sslot=z32(), dslot=z32(),
        refcount=z32(), clock=z32(), tick=zs(), n_entries=zs(),
        hits=zs(), misses=zs(), evictions=zs(),
    )


@jax.jit
def dict_lookup(d: PatternDictionary, keys: jax.Array, valid: jax.Array):
    """Read-mostly probe of unique batch keys (one tick of the clock).

    Returns (d', hit, eslot, sslot, dslot, entry) — per-key bool hit
    mask, the cached slot payload (-1 where missed) and the dictionary
    entry index (the reference's pattern id).  Probing stops at the
    first empty slot of a key's sequence, mirroring insert order —
    entries orphaned behind an evicted slot read as misses.
    """
    cap = d.sig.shape[0]
    n = keys.shape[0]

    def body(i, carry):
        slot, done = carry
        cand = probe_hash(keys, cap, jnp.full((n,), i, jnp.int32))
        cur = d.sig[cand]
        hit = (cur == keys) & ~done
        slot = jnp.where(hit, cand, slot)
        done = done | hit | (cur == 0)
        return slot, done

    slot, _ = jax.lax.fori_loop(
        0, DICT_PROBES, body, (jnp.full((n,), -1, jnp.int32), ~valid))
    hit = valid & (slot >= 0)
    tgt = jnp.where(hit, slot, cap)
    refcount = d.refcount.at[tgt].add(1, mode="drop")
    clock = d.clock.at[tgt].set(
        jnp.full((n,), 1, jnp.int32) * d.tick, mode="drop")
    d2 = dataclasses.replace(
        d, refcount=refcount, clock=clock, tick=d.tick + 1,
        hits=d.hits + jnp.sum(hit.astype(jnp.int32)),
        misses=d.misses + jnp.sum((valid & ~hit).astype(jnp.int32)))
    safe = jnp.clip(slot, 0, cap - 1)
    g = lambda a: jnp.where(hit, a[safe], -1)
    return d2, hit, g(d.eslot), g(d.sslot), g(d.dslot), slot


@partial(jax.jit, static_argnames=("ttl", "high_water"))
def dict_admit(d: PatternDictionary, keys: jax.Array, admit: jax.Array,
               eslot: jax.Array, sslot: jax.Array, dslot: jax.Array,
               psig: jax.Array, ttl: int = 64,
               high_water: float = 0.85) -> PatternDictionary:
    """Insert committed pattern-member edges (unique keys + payload).

    Runs the aging eviction first when occupancy is past the
    high-water mark: entries idle (no lookup hit, no re-admit) for
    more than `ttl` dictionary ticks are cleared.  Deterministic in
    the tick counter alone.  Then the store's own `upsert_sweep`
    places the admitted keys; already-present keys are refreshed, new
    keys take their payload.
    """
    cap = d.sig.shape[0]
    n = keys.shape[0]
    over = d.n_entries > jnp.int32(int(high_water * cap))
    stale = (d.sig != 0) & (d.clock + jnp.int32(ttl) < d.tick)
    evict = stale & over
    sig = jnp.where(evict, 0, d.sig)
    n_evicted = jnp.sum(evict.astype(jnp.int32))
    refcount = jnp.where(evict, 0, d.refcount)

    sig, slot, is_new = upsert_sweep(sig, keys, admit,
                                     jnp.asarray(DICT_PROBES, jnp.int32))
    placed = admit & (slot >= 0)
    new = is_new & admit
    tgt_new = jnp.where(new, slot, cap)
    tgt_placed = jnp.where(placed, slot, cap)
    tick_col = jnp.full((n,), 1, jnp.int32) * d.tick
    return dataclasses.replace(
        d,
        sig=sig,
        psig=d.psig.at[tgt_new].set(psig, mode="drop"),
        eslot=d.eslot.at[tgt_new].set(eslot, mode="drop"),
        sslot=d.sslot.at[tgt_new].set(sslot, mode="drop"),
        dslot=d.dslot.at[tgt_new].set(dslot, mode="drop"),
        refcount=refcount.at[tgt_new].set(jnp.ones((n,), jnp.int32),
                                          mode="drop"),
        clock=jnp.where(evict, 0, d.clock).at[tgt_placed].set(
            tick_col, mode="drop"),
        n_entries=d.n_entries - n_evicted + jnp.sum(new.astype(jnp.int32)),
        evictions=d.evictions + n_evicted,
    )
