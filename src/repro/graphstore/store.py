"""Device-resident property-graph store — the framework's "Neo4j".

Open-addressing hash tables in JAX arrays (linear probing, vectorised
over the batch; all shapes static).  The store ingests *compressed*
edge-table batches (Algorithm 3 GRAPHPUSH): MERGE semantics for nodes
(insert-if-absent, so ingesting the same node twice never duplicates),
CREATE-or-count for edges (duplicate edges accumulate `count`, the
paper's Alg. 1 line 20 semantics at store level).

The commit hot path is a *fused upsert* (repro.kernels.upsert):
lookup-or-insert in ONE probe sweep per table, and degree updates
reuse the node-upsert slots through the edge table's dedup index — the
whole commit runs exactly TWO probe loops (nodes + edges), down from
six in the seed (see `count_probe_loops`).  The probe budget is
adaptive: it doubles past 0.6 load factor and doubles again past 0.8
(ROADMAP "store probing robustness"); `dropped_inserts` in the commit
stats is the table-pressure signal the Algorithm-2 controller consumes
via the MetricsHub.

`ingest_step` also returns the number of *new* nodes — exactly the
bucket-diversity signal rho the buffer controller needs (§III-A), so
diversity costs nothing extra to compute — and a `CommitDelta` the
incremental snapshot maintainer (repro.query.snapshot.apply_delta)
merges into the CSR without a full recompaction.

The distributed variant shards both tables over the `data` mesh axis by
key ownership and exchanges entries with a single all_to_all — the
paper's "DBMS ingestion pool" mapped onto a TPU pod (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

MAX_PROBES = 32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphStore:
    node_keys: jax.Array  # (Ncap,) key dtype; 0 = empty
    node_count: jax.Array  # (Ncap,) int32  (times seen, a node property)
    node_degree: jax.Array  # (Ncap,) int32
    edge_keys: jax.Array  # (Ecap,)
    edge_src: jax.Array  # (Ecap,)
    edge_dst: jax.Array  # (Ecap,)
    edge_type: jax.Array  # (Ecap,) int32
    edge_count: jax.Array  # (Ecap,) int32
    n_nodes: jax.Array  # scalar int32
    n_edges: jax.Array  # scalar int32

    def tree_flatten(self):
        # shallow on purpose: astuple() recurses into tuple-subclass
        # leaves (e.g. the PartitionSpec pytree make_distributed_ingest
        # builds), silently downgrading them to plain tuples
        return tuple(getattr(self, f.name)
                     for f in dataclasses.fields(self)), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CommitDelta:
    """What one commit changed — the incremental-snapshot input.

    Node arrays are (2*cap,), edge arrays (cap,) at the edge-table
    capacity.  `*_placed` marks entries that reached the store (valid
    and not dropped by probing); `*_new` marks first insertions.
    `src_deg`/`dst_deg` mark the endpoints that received a +1 degree
    (endpoint present in the table and the edge newly created)."""

    node_ids: jax.Array
    node_placed: jax.Array
    node_new: jax.Array
    src: jax.Array
    dst: jax.Array
    etype: jax.Array
    count: jax.Array
    edge_placed: jax.Array
    edge_new: jax.Array
    src_deg: jax.Array
    dst_deg: jax.Array

    def tree_flatten(self):
        # shallow, like GraphStore: astuple() deep-copies and rebuilds
        # tuple-subclass leaves (PartitionSpec) as plain tuples
        return tuple(getattr(self, f.name)
                     for f in dataclasses.fields(self)), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_store(node_cap: int, edge_cap: int, key_dtype=None) -> GraphStore:
    from repro.core.compression import key_dtype as kd_fn

    kd = key_dtype or kd_fn()
    z32 = lambda c: jnp.zeros((c,), jnp.int32)
    zk = lambda c: jnp.zeros((c,), kd)
    return GraphStore(
        node_keys=zk(node_cap),
        node_count=z32(node_cap),
        node_degree=z32(node_cap),
        edge_keys=zk(edge_cap),
        edge_src=zk(edge_cap),
        edge_dst=zk(edge_cap),
        edge_type=z32(edge_cap),
        edge_count=z32(edge_cap),
        n_nodes=jnp.zeros((), jnp.int32),
        n_edges=jnp.zeros((), jnp.int32),
    )


def probe_budget(n_used: jax.Array, cap: int) -> jax.Array:
    """Adaptive probe rounds from the table load factor: MAX_PROBES
    below 0.6 load, x2 past 0.6, x4 past 0.8.  Monotone in load, so a
    key placed under an earlier (smaller) budget is always found again
    under the current one."""
    load = n_used.astype(jnp.float32) / jnp.float32(cap)
    mult = 1 + (load >= 0.6).astype(jnp.int32) + 2 * (load >= 0.8).astype(jnp.int32)
    return jnp.int32(MAX_PROBES) * mult


@jax.jit
def ingest_step(store: GraphStore, et) -> Tuple[GraphStore, dict]:
    """GRAPHPUSH (Algorithm 3): commit one compressed edge table.

    Two fused probe sweeps (nodes, edges); degree updates reuse the
    node slots via the edge table's dedup index.  Returns (store',
    stats) where stats carries the controller signals: new-node count
    (diversity rho numerator), sizes, the effective instruction count,
    the table-pressure signals (dropped_inserts, loads, probe budget),
    and the `CommitDelta` for incremental snapshot maintenance."""
    from repro.core.compression import mix_keys
    from repro.kernels import ops

    # NB masked lanes scatter to the out-of-range capacity index, which
    # mode="drop" discards; -1 would WRAP to the last slot and corrupt it.
    ncap = store.node_keys.shape[0]
    ecap = store.edge_keys.shape[0]
    n_probes_n = probe_budget(store.n_nodes, ncap)
    n_probes_e = probe_budget(store.n_edges, ecap)

    # ---- nodes: MERGE (one fused probe sweep) ----
    nk, nslot, n_isnew = ops.fused_upsert(
        store.node_keys, et.node_ids, et.node_valid, n_probes_n)
    node_placed = et.node_valid & (nslot >= 0)
    is_new = n_isnew & et.node_valid
    node_count = store.node_count.at[jnp.where(node_placed, nslot, ncap)].add(
        1, mode="drop"
    )
    n_new_nodes = jnp.sum(is_new.astype(jnp.int32))
    dropped_nodes = jnp.sum((et.node_valid & ~node_placed).astype(jnp.int32))

    # ---- edges: CREATE-or-count (one fused probe sweep) ----
    ekey = mix_keys(et.src, et.dst, et.etype)
    ek, eslot, e_isnew = ops.fused_upsert(
        store.edge_keys, ekey, et.edge_valid, n_probes_e)
    edge_placed = et.edge_valid & (eslot >= 0)
    e_new = e_isnew & et.edge_valid
    edge_src = store.edge_src.at[jnp.where(e_new, eslot, ecap)].set(et.src, mode="drop")
    edge_dst = store.edge_dst.at[jnp.where(e_new, eslot, ecap)].set(et.dst, mode="drop")
    edge_type = store.edge_type.at[jnp.where(e_new, eslot, ecap)].set(et.etype, mode="drop")
    edge_count = store.edge_count.at[jnp.where(edge_placed, eslot, ecap)].add(
        et.count, mode="drop")
    n_new_edges = jnp.sum(e_new.astype(jnp.int32))
    dropped_edges = jnp.sum((et.edge_valid & ~edge_placed).astype(jnp.int32))

    # ---- degree update (both endpoints of new edges) — NO re-probing:
    # the dedup index maps each endpoint to its already-upserted slot
    sslot = nslot[et.src_node_idx]
    dslot = nslot[et.dst_node_idx]
    src_deg = e_new & (sslot >= 0)
    dst_deg = e_new & (dslot >= 0)
    node_degree = store.node_degree.at[jnp.where(src_deg, sslot, ncap)].add(1, mode="drop")
    node_degree = node_degree.at[jnp.where(dst_deg, dslot, ncap)].add(1, mode="drop")

    new_store = GraphStore(
        node_keys=nk,
        node_count=node_count,
        node_degree=node_degree,
        edge_keys=ek,
        edge_src=edge_src,
        edge_dst=edge_dst,
        edge_type=edge_type,
        edge_count=edge_count,
        n_nodes=store.n_nodes + n_new_nodes,
        n_edges=store.n_edges + n_new_edges,
    )
    stats = {
        "new_nodes": n_new_nodes,
        "new_edges": n_new_edges,
        "batch_nodes": jnp.sum(et.node_valid.astype(jnp.int32)),
        "batch_edges": jnp.sum(et.edge_valid.astype(jnp.int32)),
        "instructions": n_new_nodes + jnp.sum(et.edge_valid.astype(jnp.int32)),
        "store_nodes": new_store.n_nodes,
        "store_edges": new_store.n_edges,
        # table-pressure signals (MetricsHub -> Algorithm-2 controller)
        "dropped_nodes": dropped_nodes,
        "dropped_edges": dropped_edges,
        "dropped_inserts": dropped_nodes + dropped_edges,
        "probe_rounds": jnp.maximum(n_probes_n, n_probes_e),
        "node_load": new_store.n_nodes.astype(jnp.float32) / jnp.float32(ncap),
        "edge_load": new_store.n_edges.astype(jnp.float32) / jnp.float32(ecap),
        # per-entry store slots (-1 = dropped): the dictionary-
        # compression stage caches these as reference bindings
        # (repro.compress); popped before cross-shard reduction like
        # the delta below
        "nslot": jnp.where(node_placed, nslot, -1),
        "eslot": jnp.where(edge_placed, eslot, -1),
        # incremental snapshot maintenance input
        "delta": CommitDelta(
            node_ids=et.node_ids,
            node_placed=node_placed,
            node_new=is_new,
            src=et.src,
            dst=et.dst,
            etype=et.etype,
            count=et.count,
            edge_placed=edge_placed,
            edge_new=e_new,
            src_deg=src_deg,
            dst_deg=dst_deg,
        ),
    }
    return new_store, stats


@jax.jit
def commit_compressed(store: GraphStore, cc) -> Tuple[GraphStore, dict]:
    """Pattern-aware GRAPHPUSH for a `repro.compress.CompressedCommit`.

    The residual edge table takes the normal two-sweep `ingest_step`;
    dictionary references then land by DIRECT scatter to their cached
    store slots — zero probe rounds per reference.  Referenced edges
    are by construction already present (their slots were cached at a
    previous successful commit and slots are never freed), so the
    result is bit-identical to committing the full raw batch: counts
    accumulate on the same slots, no degrees change (refs are never
    new edges), and each unique batch node still gets exactly one
    `node_count` increment (reference-only endpoints are counted here,
    deduplicated against the residual's node set).

    Stats keep the raw-path keys with FULL-batch semantics (so rho,
    instruction accounting and pressure signals stay comparable) plus
    `dict_refs` / `dict_hit_rate`, and the `CommitDelta` carries the
    reference edges as placed-not-new entries so incremental snapshots
    (repro.query.snapshot.apply_delta) stay exact.
    """
    store1, s = ingest_step(store, cc.residual)
    ncap = store1.node_keys.shape[0]
    ecap = store1.edge_keys.shape[0]

    # ---- reference edges: count accumulation on cached slots ----
    rv = cc.ref_valid & (cc.ref_eslot >= 0)
    edge_count = store1.edge_count.at[jnp.where(rv, cc.ref_eslot, ecap)].add(
        cc.ref_count, mode="drop")
    n_refs = jnp.sum(rv.astype(jnp.int32))

    # ---- reference-only endpoints: one node_count +1 per unique
    # batch node, exactly like the raw path ----
    res_nodes = cc.residual.node_ids  # sorted unique, sentinel tail
    nn = res_nodes.shape[0]

    def in_residual(keys):
        pos = jnp.clip(jnp.searchsorted(res_nodes, keys).astype(jnp.int32),
                       0, nn - 1)
        return res_nodes[pos] == keys

    ref_keys = jnp.concatenate([cc.ref_src, cc.ref_dst])
    ref_slots = jnp.concatenate([cc.ref_sslot, cc.ref_dslot])
    cand = (jnp.concatenate([rv, rv]) & (ref_slots >= 0)
            & ~in_residual(ref_keys))
    m = ref_keys.shape[0]
    lane = jnp.arange(m, dtype=jnp.int32)
    # first occurrence per slot: endpoints shared by several refs (or
    # by both sides of one) must still count once
    first = jnp.full((ncap,), m, jnp.int32).at[
        jnp.where(cand, ref_slots, ncap)].min(lane, mode="drop")
    nmask = cand & (first[jnp.clip(ref_slots, 0, ncap - 1)] == lane)
    node_count = store1.node_count.at[jnp.where(nmask, ref_slots, ncap)].add(
        1, mode="drop")
    n_ref_nodes = jnp.sum(nmask.astype(jnp.int32))

    d = s["delta"]
    zb = jnp.zeros_like(rv)
    comb = CommitDelta(
        node_ids=jnp.concatenate([d.node_ids, ref_keys]),
        node_placed=jnp.concatenate([d.node_placed, nmask]),
        node_new=jnp.concatenate([d.node_new, jnp.zeros_like(nmask)]),
        src=jnp.concatenate([d.src, cc.ref_src]),
        dst=jnp.concatenate([d.dst, cc.ref_dst]),
        etype=jnp.concatenate([d.etype, cc.ref_etype]),
        count=jnp.concatenate([d.count, cc.ref_count]),
        edge_placed=jnp.concatenate([d.edge_placed, rv]),
        edge_new=jnp.concatenate([d.edge_new, zb]),
        src_deg=jnp.concatenate([d.src_deg, zb]),
        dst_deg=jnp.concatenate([d.dst_deg, zb]),
    )

    batch_edges = s["batch_edges"] + n_refs
    stats = dict(s)
    stats.update(
        batch_nodes=s["batch_nodes"] + n_ref_nodes,
        batch_edges=batch_edges,
        instructions=s["new_nodes"] + batch_edges,
        dict_refs=n_refs,
        dict_hit_rate=(n_refs.astype(jnp.float32)
                       / jnp.maximum(batch_edges.astype(jnp.float32), 1.0)),
        delta=comb,
    )
    new_store = dataclasses.replace(
        store1, edge_count=edge_count, node_count=node_count)
    return new_store, stats


def count_probe_loops(et) -> int:
    """Structural perf contract: number of sequential probe loops
    (while/scan eqns) in one compiled commit — 2 since the fused
    upsert (6 in the seed's lookup-then-insert commit).  Benchmarks
    and tests report/assert this."""
    kd = et.node_ids.dtype
    store = init_store(et.node_ids.shape[0], et.src.shape[0], key_dtype=kd)
    jaxpr = jax.make_jaxpr(ingest_step)(store, et)

    def count(jp) -> int:
        total = 0
        for eqn in jp.eqns:
            if eqn.primitive.name in ("while", "scan"):
                total += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None:
                        total += count(inner)
        return total

    return count(jaxpr.jaxpr)


# ---------------------------------------------------------------------------
# Distributed ingest: shard by key ownership over the `data` axis
# ---------------------------------------------------------------------------

# stats keys reduced by max instead of sum across shards (budgets and
# load factors are per-table properties, not additive counts)
_STATS_MAX_KEYS = ("probe_rounds", "node_load", "edge_load")


def make_distributed_ingest(mesh):
    """shard_map ingest over the `data` axis: each shard owns the keys
    with hash % D == rank; one all_to_all routes every edge to its
    owner shard, then the local path (dedup + fused-upsert commit)
    runs unchanged — sharded and local commits share the one
    `ingest_step` implementation.

    This is the paper's ingestion-pool architecture mapped onto a pod
    (DESIGN.md §2): the Bolt connector pool becomes the data-axis
    shards, the commit becomes a compiled collective exchange.  The
    `model` (and `pod`) axes replicate the ingest — on a real fleet
    they run the training/serving consumers fed by this store."""
    from jax.sharding import PartitionSpec as P

    D = mesh.shape["data"]
    other_axes = tuple(a for a in mesh.axis_names if a != "data")

    def local_ingest(store, src, dst, etype, valid):
        # src/dst/etype/valid: (n_local,) this shard's raw slice
        own = (src % jnp.asarray(D, src.dtype)).astype(jnp.int32)
        order = jnp.argsort(own)
        srcs, dsts, ets, vals, owns = (
            src[order], dst[order], etype[order], valid[order], own[order]
        )
        n = src.shape[0]
        per = n // D
        # capacity-partitioned exchange: slot i of shard r goes to shard
        # i//per; entries landing in a foreign slice are dropped (rare:
        # hashing balances owners), mirroring the paper's bounded pool
        slot_owner = jnp.arange(n) // per
        keep = vals & (owns == slot_owner)

        def ex(x):
            return jax.lax.all_to_all(x.reshape(D, per), "data", 0, 0, tiled=True).reshape(-1)

        from repro.core.edge_table import build_edge_table

        et = build_edge_table(ex(srcs), ex(dsts), ex(ets), ex(keep))
        # n_nodes/n_edges are GLOBAL (replicated) but the tables here
        # are the per-shard slices: scale the counters down so the
        # adaptive probe budget and load stats see the local fill
        local_store = dataclasses.replace(
            store,
            n_nodes=store.n_nodes // jnp.int32(D),
            n_edges=store.n_edges // jnp.int32(D),
        )
        new_store, stats = ingest_step(local_store, et)
        # the CommitDelta and slot arrays stay shard-local (they index
        # shard tables)
        stats.pop("delta", None)
        stats.pop("nslot", None)
        stats.pop("eslot", None)
        stats = {
            k: (jax.lax.pmax(v, "data") if k in _STATS_MAX_KEYS
                else jax.lax.psum(v, "data"))
            for k, v in stats.items()
        }
        # store-level counters are global (replicated) across shards
        new_store = dataclasses.replace(
            new_store,
            n_nodes=store.n_nodes + stats["new_nodes"],
            n_edges=store.n_edges + stats["new_edges"],
        )
        return new_store, stats

    store_specs = GraphStore(
        node_keys=P("data"), node_count=P("data"), node_degree=P("data"),
        edge_keys=P("data"), edge_src=P("data"), edge_dst=P("data"),
        edge_type=P("data"), edge_count=P("data"),
        n_nodes=P(), n_edges=P(),
    )
    in_specs = (store_specs, P("data"), P("data"), P("data"), P("data"))
    out_specs = (store_specs, P())
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        return jax.shard_map(local_ingest, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(local_ingest, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
