"""Device-resident property-graph store — the framework's "Neo4j".

Open-addressing hash tables in JAX arrays (linear probing, vectorised
over the batch; all shapes static).  The store ingests *compressed*
edge-table batches (Algorithm 3 GRAPHPUSH): MERGE semantics for nodes
(insert-if-absent, so ingesting the same node twice never duplicates),
CREATE-or-count for edges (duplicate edges accumulate `count`, the
paper's Alg. 1 line 20 semantics at store level).

`ingest_step` also returns the number of *new* nodes — exactly the
bucket-diversity signal rho the buffer controller needs (§III-A), so
diversity costs nothing extra to compute.

The distributed variant shards both tables over the `data` mesh axis by
key ownership and exchanges entries with a single all_to_all — the
paper's "DBMS ingestion pool" mapped onto a TPU pod (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

MAX_PROBES = 32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphStore:
    node_keys: jax.Array  # (Ncap,) key dtype; 0 = empty
    node_count: jax.Array  # (Ncap,) int32  (times seen, a node property)
    node_degree: jax.Array  # (Ncap,) int32
    edge_keys: jax.Array  # (Ecap,)
    edge_src: jax.Array  # (Ecap,)
    edge_dst: jax.Array  # (Ecap,)
    edge_type: jax.Array  # (Ecap,) int32
    edge_count: jax.Array  # (Ecap,) int32
    n_nodes: jax.Array  # scalar int32
    n_edges: jax.Array  # scalar int32

    def tree_flatten(self):
        return dataclasses.astuple(self), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_store(node_cap: int, edge_cap: int, key_dtype=None) -> GraphStore:
    from repro.core.compression import key_dtype as kd_fn

    kd = key_dtype or kd_fn()
    z32 = lambda c: jnp.zeros((c,), jnp.int32)
    zk = lambda c: jnp.zeros((c,), kd)
    return GraphStore(
        node_keys=zk(node_cap),
        node_count=z32(node_cap),
        node_degree=z32(node_cap),
        edge_keys=zk(edge_cap),
        edge_src=zk(edge_cap),
        edge_dst=zk(edge_cap),
        edge_type=z32(edge_cap),
        edge_count=z32(edge_cap),
        n_nodes=jnp.zeros((), jnp.int32),
        n_edges=jnp.zeros((), jnp.int32),
    )


def _probe_hash(keys: jax.Array, cap: int, i: jax.Array) -> jax.Array:
    kd = keys.dtype
    c = jnp.asarray(0x9E3779B97F4A7C15 if kd == jnp.uint64 else 0x9E3779B9, kd)
    h = keys * c
    h = h ^ (h >> 16)
    return ((h.astype(jnp.uint32) + i.astype(jnp.uint32)) % jnp.uint32(cap)).astype(jnp.int32)


def _insert_batch(table_keys: jax.Array, keys: jax.Array, valid: jax.Array):
    """Vectorised insert-if-absent of UNIQUE keys.

    Returns (new_table_keys, slot (int32), is_new (bool)).  Batch keys
    must be pre-deduplicated (always true: we ingest compressed batches).
    Linear probing, MAX_PROBES rounds, scatter-max resolves races.
    """
    cap = table_keys.shape[0]
    n = keys.shape[0]

    def body(i, carry):
        tk, slot, done = carry
        cand = _probe_hash(keys, cap, jnp.full((n,), i, jnp.int32))
        cur = tk[cand]
        hit = (cur == keys) & valid & ~done
        empty = (cur == 0) & valid & ~done
        # race for empty slots: scatter-max, winners check back
        tk = tk.at[jnp.where(empty, cand, cap)].max(keys, mode="drop")
        won = empty & (tk[cand] == keys)
        placed = hit | won
        slot = jnp.where(placed, cand, slot)
        done = done | placed
        return tk, slot, done

    slot0 = jnp.full((n,), -1, jnp.int32)
    done0 = ~valid
    tk, slot, done = jax.lax.fori_loop(0, MAX_PROBES, body, (table_keys, slot0, done0))
    # is_new: slot points at our key and it wasn't a pre-existing hit --
    # recompute: a key existed before iff some probe found cur==key before
    # any empty. Track via membership BEFORE insert:
    return tk, slot, done


def _lookup_batch(table_keys: jax.Array, keys: jax.Array, valid: jax.Array):
    """Returns (found (bool), slot (int32, -1 if absent))."""
    cap = table_keys.shape[0]
    n = keys.shape[0]

    def body(i, carry):
        found, slot, dead = carry
        cand = _probe_hash(keys, cap, jnp.full((n,), i, jnp.int32))
        cur = table_keys[cand]
        hit = (cur == keys) & valid & ~found & ~dead
        miss = (cur == 0) & ~found & ~dead  # empty slot: key absent
        slot = jnp.where(hit, cand, slot)
        return found | hit, slot, dead | miss

    found0 = jnp.zeros((n,), bool)
    slot0 = jnp.full((n,), -1, jnp.int32)
    found, slot, _ = jax.lax.fori_loop(0, MAX_PROBES, body, (found0, slot0, jnp.zeros((n,), bool)))
    return found, slot


@jax.jit
def ingest_step(store: GraphStore, et) -> Tuple[GraphStore, dict]:
    """GRAPHPUSH (Algorithm 3): commit one compressed edge table.

    Returns (store', stats) where stats carries the controller signals:
    new-node count (diversity rho numerator), sizes, and the effective
    instruction count actually applied."""
    # ---- nodes: MERGE ----
    # NB masked lanes scatter to the out-of-range capacity index, which
    # mode="drop" discards; -1 would WRAP to the last slot and corrupt it.
    ncap = store.node_keys.shape[0]
    ecap = store.edge_keys.shape[0]
    pre_found, _ = _lookup_batch(store.node_keys, et.node_ids, et.node_valid)
    nk, nslot, ok = _insert_batch(store.node_keys, et.node_ids, et.node_valid)
    is_new = et.node_valid & ~pre_found & ok
    node_count = store.node_count.at[jnp.where(et.node_valid & ok, nslot, ncap)].add(
        1, mode="drop"
    )
    n_new_nodes = jnp.sum(is_new.astype(jnp.int32))

    # ---- edges: CREATE-or-count ----
    from repro.core.compression import mix_keys

    ekey = mix_keys(et.src, et.dst, et.etype)
    e_pre, _ = _lookup_batch(store.edge_keys, ekey, et.edge_valid)
    ek, eslot, eok = _insert_batch(store.edge_keys, ekey, et.edge_valid)
    e_new = et.edge_valid & ~e_pre & eok
    wr = jnp.where(et.edge_valid & eok, eslot, ecap)
    edge_src = store.edge_src.at[jnp.where(e_new, eslot, ecap)].set(et.src, mode="drop")
    edge_dst = store.edge_dst.at[jnp.where(e_new, eslot, ecap)].set(et.dst, mode="drop")
    edge_type = store.edge_type.at[jnp.where(e_new, eslot, ecap)].set(et.etype, mode="drop")
    edge_count = store.edge_count.at[wr].add(et.count, mode="drop")
    n_new_edges = jnp.sum(e_new.astype(jnp.int32))

    # ---- degree update (both endpoints of new edges) ----
    sf, sslot = _lookup_batch(nk, et.src, e_new)
    df, dslot = _lookup_batch(nk, et.dst, e_new)
    node_degree = store.node_degree.at[jnp.where(sf, sslot, ncap)].add(1, mode="drop")
    node_degree = node_degree.at[jnp.where(df, dslot, ncap)].add(1, mode="drop")

    new_store = GraphStore(
        node_keys=nk,
        node_count=node_count,
        node_degree=node_degree,
        edge_keys=ek,
        edge_src=edge_src,
        edge_dst=edge_dst,
        edge_type=edge_type,
        edge_count=edge_count,
        n_nodes=store.n_nodes + n_new_nodes,
        n_edges=store.n_edges + n_new_edges,
    )
    stats = {
        "new_nodes": n_new_nodes,
        "new_edges": n_new_edges,
        "batch_nodes": jnp.sum(et.node_valid.astype(jnp.int32)),
        "batch_edges": jnp.sum(et.edge_valid.astype(jnp.int32)),
        "instructions": n_new_nodes + jnp.sum(et.edge_valid.astype(jnp.int32)),
        "store_nodes": new_store.n_nodes,
        "store_edges": new_store.n_edges,
    }
    return new_store, stats


# ---------------------------------------------------------------------------
# Distributed ingest: shard by key ownership over the `data` axis
# ---------------------------------------------------------------------------


def make_distributed_ingest(mesh):
    """shard_map ingest over the `data` axis: each shard owns the keys
    with hash % D == rank; one all_to_all routes every edge to its
    owner shard, then the local path (dedup + MERGE) runs unchanged.

    This is the paper's ingestion-pool architecture mapped onto a pod
    (DESIGN.md §2): the Bolt connector pool becomes the data-axis
    shards, the commit becomes a compiled collective exchange.  The
    `model` (and `pod`) axes replicate the ingest — on a real fleet
    they run the training/serving consumers fed by this store."""
    from jax.sharding import PartitionSpec as P

    D = mesh.shape["data"]
    other_axes = tuple(a for a in mesh.axis_names if a != "data")

    def local_ingest(store, src, dst, etype, valid):
        # src/dst/etype/valid: (n_local,) this shard's raw slice
        own = (src % jnp.asarray(D, src.dtype)).astype(jnp.int32)
        order = jnp.argsort(own)
        srcs, dsts, ets, vals, owns = (
            src[order], dst[order], etype[order], valid[order], own[order]
        )
        n = src.shape[0]
        per = n // D
        # capacity-partitioned exchange: slot i of shard r goes to shard
        # i//per; entries landing in a foreign slice are dropped (rare:
        # hashing balances owners), mirroring the paper's bounded pool
        slot_owner = jnp.arange(n) // per
        keep = vals & (owns == slot_owner)

        def ex(x):
            return jax.lax.all_to_all(x.reshape(D, per), "data", 0, 0, tiled=True).reshape(-1)

        from repro.core.edge_table import build_edge_table

        et = build_edge_table(ex(srcs), ex(dsts), ex(ets), ex(keep))
        new_store, stats = ingest_step(store, et)
        stats = {k: jax.lax.psum(v, "data") for k, v in stats.items()}
        # store-level counters are global (replicated) across shards
        new_store = dataclasses.replace(
            new_store,
            n_nodes=store.n_nodes + stats["new_nodes"],
            n_edges=store.n_edges + stats["new_edges"],
        )
        return new_store, stats

    store_specs = GraphStore(
        node_keys=P("data"), node_count=P("data"), node_degree=P("data"),
        edge_keys=P("data"), edge_src=P("data"), edge_dst=P("data"),
        edge_type=P("data"), edge_count=P("data"),
        n_nodes=P(), n_edges=P(),
    )
    return jax.shard_map(
        local_ingest,
        mesh=mesh,
        in_specs=(store_specs, P("data"), P("data"), P("data"), P("data")),
        out_specs=(store_specs, P()),
        check_vma=False,
    )
