"""Vectorised query ops over a `GraphSnapshot` (all exact).

Every op is a fixed-shape device program over the CSR arrays:

  * `degree_distribution` — histogram of node degrees (scatter-add).
  * `top_k_degree`        — exact top-k heaviest nodes (lax.top_k).
  * `k_hop`               — frontier expansion: each hop is one O(E)
                            gather (frontier mask at edge rows) + one
                            scatter-max into the destination mask —
                            the segment-gather formulation of BFS.
  * `triangle_count`      — dense-adjacency trace(A^3)/6 on the MXU
                            (guarded to small node capacities).
  * `edge_lookup`         — total weight of (src, dst) over all edge
                            types: two vectorised binary searches into
                            the lexicographically sorted edge list +
                            one prefix-sum gather.

The directed store orientation is src -> dst; ops taking `directed`
use the reverse CSR to traverse both ways when False.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.query.snapshot import GraphSnapshot, node_index


@partial(jax.jit, static_argnames=("num_bins",))
def degree_distribution(snap: GraphSnapshot, num_bins: int = 64) -> jax.Array:
    """Histogram of node degrees: bin i counts nodes with degree i
    (degrees >= num_bins-1 land in the last bin)."""
    ncap = snap.node_cap
    valid = jnp.arange(ncap) < snap.n_nodes
    b = jnp.clip(snap.node_degree, 0, num_bins - 1)
    return jnp.zeros((num_bins,), jnp.int32).at[
        jnp.where(valid, b, num_bins)
    ].add(1, mode="drop")


@partial(jax.jit, static_argnames=("k",))
def top_k_degree(snap: GraphSnapshot, k: int = 10
                 ) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k (node_key, degree), heaviest first."""
    ncap = snap.node_cap
    valid = jnp.arange(ncap) < snap.n_nodes
    score = jnp.where(valid, snap.node_degree, -1)
    v, i = jax.lax.top_k(score, k)
    return jnp.where(v >= 0, snap.node_key[i], 0), jnp.maximum(v, 0)


@partial(jax.jit, static_argnames=("hops", "directed"))
def k_hop(snap: GraphSnapshot, seed_keys: jax.Array, hops: int = 2,
          directed: bool = False) -> jax.Array:
    """Nodes within `hops` edges of the seeds: (Ncap,) bool mask over
    compact node indices (seeds included).  `directed=False` also
    walks edges backwards via the reverse CSR.

    Slot Ncap is a trash slot: invalid edges point there on both ends,
    so they self-absorb without touching real nodes."""
    ncap = snap.node_cap
    found, idx = node_index(snap, seed_keys)
    visited = jnp.zeros((ncap + 1,), jnp.int32).at[
        jnp.where(found, idx, ncap)
    ].max(1)

    def body(_, vis):
        # both reaches read the start-of-hop mask so one iteration
        # traverses exactly one edge (in either direction)
        fwd = vis[snap.edge_row] > 0
        nxt = vis.at[jnp.where(fwd, snap.edge_col, ncap)].max(1)
        if not directed:
            bwd = vis[snap.redge_row] > 0
            nxt = nxt.at[jnp.where(bwd, snap.redge_col, ncap)].max(1)
        return nxt

    visited = jax.lax.fori_loop(0, hops, body, visited)
    live = jnp.arange(ncap) < snap.n_nodes
    return (visited[:ncap] > 0) & live


def triangle_count(snap: GraphSnapshot, max_dense_nodes: int = 4096) -> int:
    """Exact triangle count of the undirected simple graph (edge
    directions and multiplicities collapsed, self-loops dropped):
    trace(A^3) / 6 via two dense matmuls.  Dense adjacency is
    O(Ncap^2), so the node capacity is guarded; at Ncap <= 4096 every
    wedge count (<= Ncap < 2^24) is exact in f32 and each int32 row
    sum (<= Ncap^2 = 2^24) is exact, so the host-side total is exact
    at any triangle count."""
    if snap.node_cap > max_dense_nodes:
        raise ValueError(
            f"triangle_count is dense: node capacity {snap.node_cap} exceeds "
            f"max_dense_nodes={max_dense_nodes}; build the store (or pass "
            f"max_dense_nodes) accordingly")
    rows = np.asarray(_triangle_row_sums(snap), dtype=np.int64)
    return int(rows.sum()) // 6


@jax.jit
def _triangle_row_sums(snap: GraphSnapshot) -> jax.Array:
    """Per-row sums of (A @ A) * A, int32 (exact; see triangle_count)."""
    ncap = snap.node_cap
    live = snap.edge_row < ncap
    a = jnp.zeros((ncap + 1, ncap + 1), jnp.float32).at[
        jnp.where(live, snap.edge_row, ncap),
        jnp.where(live, snap.edge_col, ncap),
    ].max(1.0)
    a = a[:ncap, :ncap]
    a = jnp.maximum(a, a.T) * (1.0 - jnp.eye(ncap, dtype=jnp.float32))
    wedges = jnp.matmul(a, a, preferred_element_type=jnp.float32) * a
    return jnp.sum(wedges.astype(jnp.int32), axis=1)


def _bsearch_range(arr: jax.Array, lo: jax.Array, hi: jax.Array,
                   target: jax.Array, side: str) -> jax.Array:
    """Vectorised binary search of `target` within arr[lo:hi]
    (per-query bounds), log2(len) fixed iterations."""
    steps = int(math.ceil(math.log2(max(arr.shape[0], 2)))) + 1
    n = arr.shape[0]

    def body(_, c):
        lo, hi = c
        mid = (lo + hi) // 2
        v = arr[jnp.clip(mid, 0, n - 1)]
        go_right = (v < target) if side == "left" else (v <= target)
        open_ = lo < hi
        return (jnp.where(open_ & go_right, mid + 1, lo),
                jnp.where(open_ & ~go_right, mid, hi))

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


@jax.jit
def edge_lookup(snap: GraphSnapshot, src_keys: jax.Array,
                dst_keys: jax.Array) -> jax.Array:
    """Exact total edge weight src->dst summed over edge types
    (0 when either endpoint or the edge is absent)."""
    ncap = snap.node_cap
    fs, si = node_index(snap, src_keys)
    fd, di = node_index(snap, dst_keys)
    row = jnp.clip(si, 0, ncap - 1)
    lo = snap.indptr[row]
    hi = snap.indptr[row + 1]
    left = _bsearch_range(snap.edge_col, lo, hi, di, side="left")
    right = _bsearch_range(snap.edge_col, lo, hi, di, side="right")
    total = snap.edge_prefix[right] - snap.edge_prefix[left]
    return jnp.where(fs & fd, total, 0)
