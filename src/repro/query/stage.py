"""Ingestion-time sketch maintenance: the pipeline plug-ins.

Two complementary placements, both over `repro.api` protocols:

  * `SketchStage` — a `Stage` (records -> records pass-through) that
    maps each tick's filtered records through the same declarative
    `MappingSpec` the transform uses and absorbs the resulting edge
    table into its sketch.  It observes the stream at *filter time*,
    before the buffer/controller, so its answers are available live
    even while batches are held, spilled or throttled — and since
    every record passes here at most once (spill-drain re-enters the
    buffer, not the filter), sketch totals upper-bound store totals.
  * `QuerySink` — a `Sink` wrapper that updates its sketch only on
    *committed* edge tables, so its sketch is commit-consistent with
    the store; it can periodically publish live answers as `"sketch"`
    events on the `MetricsHub`.

Both expose the same numpy-friendly query surface: `degree`,
`edge_weight`, `heavy_hitters`, `error_bound`.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.edge_table import from_raw_batch
from repro.core.transform import MappingSpec, create_edges, tweet_mapping
from repro.query.sketch import (
    GraphSketch,
    init_sketch,
    sketch_degree,
    sketch_edge_weight,
    sketch_error_bound,
    sketch_heavy_hitters,
    sketch_update,
)


def _slice_raw(raw, lo: int, hi: int):
    import dataclasses

    return dataclasses.replace(
        raw, src=raw.src[lo:hi], dst=raw.dst[lo:hi], etype=raw.etype[lo:hi],
        src_type=raw.src_type[lo:hi], dst_type=raw.dst_type[lo:hi])


class _SketchQueries:
    """Shared numpy-facing query surface over `self.sketch`."""

    sketch: GraphSketch

    def degree(self, keys, mode: str = "total") -> np.ndarray:
        import jax.numpy as jnp

        kd = self.sketch.hh_keys.dtype
        return np.asarray(sketch_degree(self.sketch, jnp.asarray(keys, kd),
                                        mode=mode))

    def edge_weight(self, src, dst) -> np.ndarray:
        import jax.numpy as jnp

        kd = self.sketch.hh_keys.dtype
        return np.asarray(sketch_edge_weight(
            self.sketch, jnp.asarray(src, kd), jnp.asarray(dst, kd)))

    def heavy_hitters(self, k: int = 10):
        hk, hc = sketch_heavy_hitters(self.sketch, k)
        return np.asarray(hk), np.asarray(hc)

    def error_bound(self) -> float:
        return sketch_error_bound(self.sketch)


class SketchStage(_SketchQueries):
    """Stage-protocol pass-through observer maintaining a graph sketch
    at filter time (see module docstring for placement semantics)."""

    name = "sketch"

    def __init__(self, sketch: Optional[GraphSketch] = None,
                 mapping: Optional[MappingSpec] = None,
                 depth: int = 4, width: int = 256, hh_slots: int = 64,
                 max_edges_per_batch: int = 8_192,
                 use_kernel: Optional[bool] = None):
        from repro.kernels import ops

        from repro.telemetry.spans import NULL_REGISTRY

        self.sketch = sketch if sketch is not None else init_sketch(
            depth=depth, width=width, hh_slots=hh_slots)
        self.mapping = mapping or tweet_mapping()
        self.max_edges_per_batch = max_edges_per_batch
        self.use_kernel = ops.ON_TPU if use_kernel is None else use_kernel
        self.ticks_seen = 0
        self.telemetry = NULL_REGISTRY

    def __call__(self, records: List[dict], ctx=None) -> List[dict]:
        if records:
            with self.telemetry.span("sketch.update"):
                raw = create_edges(records, self.mapping)
                # absorb in <=cap chunks: a burst tick larger than the
                # device batch must never silently truncate, or the
                # sketch-upper-bounds-the-store guarantee breaks
                for lo in range(0, raw.n_edges, self.max_edges_per_batch):
                    hi = min(lo + self.max_edges_per_batch, raw.n_edges)
                    cap = max(64, 1 << int(np.ceil(np.log2(hi - lo))))
                    et = from_raw_batch(_slice_raw(raw, lo, hi), cap)
                    self.sketch = sketch_update(self.sketch, et,
                                                use_kernel=self.use_kernel)
        self.ticks_seen += 1
        return records

    # ---- checkpoint surface (repro.resilience); the sketch itself
    # snapshots as array leaves, not here ----
    def state(self) -> dict:
        return {"ticks_seen": self.ticks_seen}

    def restore_state(self, s: dict) -> None:
        self.ticks_seen = int(s["ticks_seen"])


class QuerySink(_SketchQueries):
    """Sink wrapper: commit-consistent sketch + live `"sketch"` events
    + incrementally maintained exact CSR snapshot.

    Delegates `commit` to the wrapped sink and absorbs every edge
    table the store *actually* commits: when the wrapped sink exposes
    a `GraphIngestor` (duck-typed via `.ingestor.commit_hook`), the
    sketch hooks the ingestor's successful-commit callback — which
    also catches pooled batches drained by later pushes and archived
    batches replayed by `retry_archive`.  Otherwise it falls back to
    absorbing the pushed table when the commit reports success.
    Every `answer_every` commits, a `"sketch"` event with the current
    top-k heavy hitters is emitted on `hub` (when given).

    With `incremental=True` (default) a `SnapshotMaintainer` also
    observes every commit's `CommitDelta`, so `snapshot()` serves an
    exact CSR view by merging pending deltas instead of paying a full
    `build_snapshot` per query.  `exact_topk > 0` additionally puts
    the exact top-k degrees (from the maintained snapshot) on each
    live `"sketch"` event — query-while-ingesting without rebuilds.
    """

    def __init__(self, inner, sketch: Optional[GraphSketch] = None,
                 depth: int = 4, width: int = 256, hh_slots: int = 64,
                 hub=None, answer_every: int = 10, top_k: int = 5,
                 use_kernel: Optional[bool] = None,
                 incremental: bool = True, exact_topk: int = 0):
        from repro.kernels import ops
        from repro.query.snapshot import SnapshotMaintainer
        from repro.telemetry.spans import NULL_REGISTRY

        self.telemetry = NULL_REGISTRY
        self.inner = inner
        self.sketch = sketch if sketch is not None else init_sketch(
            depth=depth, width=width, hh_slots=hh_slots)
        self.hub = hub
        self.answer_every = max(1, answer_every)
        self.top_k = top_k
        self.use_kernel = ops.ON_TPU if use_kernel is None else use_kernel
        self.exact_topk = exact_topk
        self.commits = 0
        self._now = None
        self._hooked = False
        self.maintainer = SnapshotMaintainer() if incremental else None
        ingestor = getattr(inner, "ingestor", None)
        if ingestor is not None and hasattr(ingestor, "commit_hook"):
            ingestor.commit_hook = self._absorb
            self._hooked = True

    def snapshot(self):
        """Exact CSR snapshot of the committed store — incrementally
        maintained (delta merges; full rebuild only on overflow or
        dangling edges) when `incremental`, else a fresh build."""
        from repro.query.snapshot import build_snapshot

        if self.maintainer is None:
            return build_snapshot(self.store)
        return self.maintainer.snapshot(self.store)

    def _absorb(self, et, stats):
        # the maintainer must see the commit's delta BEFORE any
        # exact_topk emission below serves snapshot(), or the served
        # view lags the store by one commit (and the lag would be
        # misread as dangling edges, forcing a rebuild per query)
        if self.maintainer is not None:
            self.maintainer.absorb(et, stats)
        with self.telemetry.span("sketch.absorb"):
            self.sketch = sketch_update(self.sketch, et,
                                        use_kernel=self.use_kernel)
        self.commits += 1
        if self.hub is not None and self.commits % self.answer_every == 0:
            hk, hc = self.heavy_hitters(self.top_k)
            payload = dict(
                commits=self.commits,
                absorbed=int(self.sketch.n_updates),
                hh_keys=hk.tolist(), hh_counts=hc.tolist(),
                error_bound=self.error_bound(),
            )
            if self.exact_topk > 0 and self.maintainer is not None:
                from repro.query.engine import top_k_degree

                keys, degs = top_k_degree(self.snapshot(), self.exact_topk)
                payload["exact_keys"] = np.asarray(keys).tolist()
                payload["exact_degrees"] = np.asarray(degs).tolist()
            self.hub.emit(
                "sketch", self._now if self._now is not None else 0.0,
                **payload,
            )

    def commit(self, et, now: Optional[float] = None) -> Dict:
        self._now = now
        out = self.inner.commit(et, now=now)
        if not self._hooked and out.get("committed", False):
            self._absorb(et, out.get("stats"))
        return out

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> Dict:
        s: Dict = {"commits": self.commits}
        if hasattr(self.inner, "state"):
            s["inner"] = self.inner.state()
        return s

    def restore_state(self, s: Dict) -> None:
        self.commits = int(s["commits"])
        self._now = None
        if self.maintainer is not None:
            # cheaper than checkpointing the CSR: force one full rebuild
            # (apply_delta is bit-exact vs build_snapshot, so the views
            # converge identically)
            self.maintainer.reset()
        if "inner" in s and hasattr(self.inner, "restore_state"):
            self.inner.restore_state(s["inner"])

    # ---- passthrough of the wrapped sink's surface ----
    def retry_archive(self, now: Optional[float] = None) -> int:
        self._now = now
        return self.inner.retry_archive(now)

    @property
    def store(self):
        return self.inner.store

    @property
    def ingestor(self):
        return self.inner.ingestor
