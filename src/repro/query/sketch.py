"""Ingestion-time graph sketch (GSS/TCM-style, fixed shapes).

Summarises the edge stream *as it is ingested* so edge-weight, degree
and top-k queries can be answered live, without touching the store:

  * `edge_w` — a (D, W, W) count-min matrix sketch of the weighted
    adjacency matrix (TCM; GSS is the collision-aware refinement):
    depth d hashes src to a row and dst to a column and accumulates
    the edge's `count` there.  A point query reads the D cells and
    takes the min — an upper bound on the true weight that is exact
    when no collision hit all D cells.
  * `out_deg` / `in_deg` — (D, W) count-min rows of the weighted out-
    and in-degree per node.
  * `hh_keys` / `hh_counts` — a K-slot heavy-hitter table (SpaceSaving
    flavour): every batch's nodes compete by their current sketch
    degree estimate; the K largest survive.  `sketch_heavy_hitters`
    reads top-k from it in O(K).

All shapes are static, the whole state is a pytree, and one update
absorbs one compressed `EdgeTable` — the same batches the store
commits, so sketch totals are directly comparable to store contents.
Updates route through the Pallas scatter kernel on TPU
(`repro.kernels.sketch`) or the pure-jnp oracle path here; both are
bit-exact (integer scatter-add is order-independent).

Guarantees (tested in tests/test_query.py):
  sketch_degree(u)         >= weighted degree of u in the store
  sketch_edge_weight(s, d) >= sum over etype of store edge counts
with expected overestimate <= e * N / W per depth (classic CMS bound,
N = total absorbed count), i.e. vanishing for W >> distinct keys.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import compression as C


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphSketch:
    edge_w: jax.Array  # (D, W, W) int32 count-min of edge weights
    out_deg: jax.Array  # (D, W) int32 count-min of weighted out-degree
    in_deg: jax.Array  # (D, W) int32 count-min of weighted in-degree
    hh_keys: jax.Array  # (K,) key-dtype heavy-hitter candidates; 0 = empty
    hh_counts: jax.Array  # (K,) int32 their degree estimates
    n_updates: jax.Array  # scalar int32: total edge count absorbed

    def tree_flatten(self):
        return dataclasses.astuple(self), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def depth(self) -> int:
        return self.edge_w.shape[0]

    @property
    def width(self) -> int:
        return self.edge_w.shape[1]


def init_sketch(depth: int = 4, width: int = 256, hh_slots: int = 64,
                key_dtype=None) -> GraphSketch:
    """Fresh sketch.  `width` should be a multiple of 128 (TPU lanes);
    memory is depth * width^2 * 4 bytes (1 MB at the defaults)."""
    kd = key_dtype or C.key_dtype()
    return GraphSketch(
        edge_w=jnp.zeros((depth, width, width), jnp.int32),
        out_deg=jnp.zeros((depth, width), jnp.int32),
        in_deg=jnp.zeros((depth, width), jnp.int32),
        hh_keys=jnp.zeros((hh_slots,), kd),
        hh_counts=jnp.zeros((hh_slots,), jnp.int32),
        n_updates=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# hashing: D independent splitmix rounds -> [0, W)
# ---------------------------------------------------------------------------


def _fold32(keys: jax.Array) -> jax.Array:
    if keys.dtype == jnp.uint64:
        return (keys ^ (keys >> jnp.uint64(32))).astype(jnp.uint32)
    return keys.astype(jnp.uint32)


def node_hash(keys: jax.Array, depth: int, width: int) -> jax.Array:
    """(D, n) int32 hash coordinates, one independent row per depth."""
    k32 = _fold32(keys)
    rows = []
    for d in range(depth):
        c1 = jnp.uint32((0x9E3779B9 + 0x7F4A7C15 * d) & 0xFFFFFFFF)
        x = (k32 + c1) * jnp.uint32(0x85EBCA6B)
        x = x ^ (x >> 13)
        x = x * jnp.uint32(0xC2B2AE35)
        x = x ^ (x >> 16)
        rows.append((x % jnp.uint32(width)).astype(jnp.int32))
    return jnp.stack(rows)


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def sketch_scatter_ref(edge_w, out_deg, in_deg, r, c, cnt):
    """Pure-jnp oracle of the Pallas kernel: literally the same body
    (`repro.kernels.sketch.scatter_add`), run outside pallas_call."""
    from repro.kernels.sketch import scatter_add

    return scatter_add(edge_w, out_deg, in_deg, r, c, cnt)


def _merge_top_k(hh_keys, hh_counts, cand_keys, cand_counts):
    """Merge candidates into the K-slot heavy-hitter table.

    Sort-based, fixed shapes: concat, dedup by key keeping the max
    count (CMS estimates only grow, so max = freshest), then top-K.
    Key 0 marks empty slots on both sides."""
    K = hh_keys.shape[0]
    kd = hh_keys.dtype
    sent = C.sentinel_for(kd)
    keys = jnp.concatenate([hh_keys, cand_keys])
    cnts = jnp.concatenate([hh_counts.astype(jnp.int32),
                            cand_counts.astype(jnp.int32)])
    m = keys.shape[0]
    masked = jnp.where(keys != 0, keys, sent)
    order = jnp.argsort(masked)
    sk, sc = masked[order], cnts[order]
    is_valid = sk != sent
    head = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & is_valid
    run = jnp.clip(jnp.cumsum(head.astype(jnp.int32)) - 1, 0, m - 1)
    best = jax.ops.segment_max(jnp.where(is_valid, sc, -1), run, num_segments=m)
    first = jax.ops.segment_min(jnp.where(head, jnp.arange(m), m), run,
                                num_segments=m)
    fp = jnp.clip(first, 0, m - 1)
    n_unique = jnp.sum(head.astype(jnp.int32))
    live = jnp.arange(m) < n_unique
    run_keys = jnp.where(live, sk[fp], 0)
    run_best = jnp.where(live, best, -1)
    top_c, top_i = jax.lax.top_k(run_best, K)
    keep = top_c > 0
    return (jnp.where(keep, run_keys[top_i], 0),
            jnp.where(keep, top_c, 0).astype(jnp.int32))


@partial(jax.jit, static_argnames=("use_kernel",))
def sketch_update(sketch: GraphSketch, et, use_kernel: bool = False) -> GraphSketch:
    """Absorb one compressed `EdgeTable` (the same batch the store
    commits).  `use_kernel=True` routes the scatter hot path through
    the Pallas kernel (default on TPU via `SketchStage`)."""
    D, W = sketch.depth, sketch.width
    cnt = jnp.where(et.edge_valid, et.count, 0).astype(jnp.int32)
    r = node_hash(et.src, D, W)
    c = node_hash(et.dst, D, W)
    if use_kernel:
        from repro.kernels import ops

        ew, od, idg = ops.sketch_scatter(
            sketch.edge_w, sketch.out_deg, sketch.in_deg, r, c, cnt)
    else:
        ew, od, idg = sketch_scatter_ref(
            sketch.edge_w, sketch.out_deg, sketch.in_deg, r, c, cnt)

    # heavy hitters: this batch's (deduplicated) nodes compete by
    # their post-update CMS degree estimate
    nh = node_hash(et.node_ids, D, W)
    drow = jnp.arange(D)[:, None]
    est = jnp.min(od[drow, nh] + idg[drow, nh], axis=0)
    cand_keys = jnp.where(et.node_valid, et.node_ids, 0)
    cand_cnt = jnp.where(et.node_valid, est, -1)
    hh_keys, hh_counts = _merge_top_k(sketch.hh_keys, sketch.hh_counts,
                                      cand_keys, cand_cnt)
    return GraphSketch(
        edge_w=ew, out_deg=od, in_deg=idg,
        hh_keys=hh_keys, hh_counts=hh_counts,
        n_updates=sketch.n_updates + jnp.sum(cnt),
    )


# ---------------------------------------------------------------------------
# queries
# ---------------------------------------------------------------------------


@jax.jit
def sketch_edge_weight(sketch: GraphSketch, src: jax.Array,
                       dst: jax.Array) -> jax.Array:
    """Upper bound on total edge weight src->dst (summed over etype)."""
    D, W = sketch.depth, sketch.width
    r = node_hash(src, D, W)
    c = node_hash(dst, D, W)
    return jnp.min(sketch.edge_w[jnp.arange(D)[:, None], r, c], axis=0)


@partial(jax.jit, static_argnames=("mode",))
def sketch_degree(sketch: GraphSketch, keys: jax.Array,
                  mode: str = "total") -> jax.Array:
    """Upper bound on weighted degree ("out", "in" or "total")."""
    D, W = sketch.depth, sketch.width
    h = node_hash(keys, D, W)
    drow = jnp.arange(D)[:, None]
    if mode == "out":
        v = sketch.out_deg[drow, h]
    elif mode == "in":
        v = sketch.in_deg[drow, h]
    else:
        v = sketch.out_deg[drow, h] + sketch.in_deg[drow, h]
    return jnp.min(v, axis=0)


@partial(jax.jit, static_argnames=("k",))
def sketch_heavy_hitters(sketch: GraphSketch, k: int = 10
                         ) -> Tuple[jax.Array, jax.Array]:
    """Top-k node keys by estimated degree from the HH table."""
    score = jnp.where(sketch.hh_keys != 0, sketch.hh_counts, -1)
    v, i = jax.lax.top_k(score, k)
    return (jnp.where(v > 0, sketch.hh_keys[i], 0),
            jnp.maximum(v, 0))


def sketch_error_bound(sketch: GraphSketch) -> float:
    """Classic CMS additive-error bound: with probability >= 1 - e^-D,
    a point query overestimates by at most e * N / W (N = total edge
    count absorbed so far)."""
    return math.e * float(sketch.n_updates) / float(sketch.width)
