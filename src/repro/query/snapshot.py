"""Store -> device-resident CSR snapshot (compaction + incremental maintenance).

The open-addressing hash tables of `repro.graphstore` are ideal for
O(1) ingest but hostile to traversal: edges of one node are scattered
across the table.  `build_snapshot` compacts them — entirely on
device, one jit — into a CSR form the query engine can traverse with
gathers and segment ops:

  * nodes sorted by key (invalid slots carry the all-ones sentinel and
    sort last), so key -> compact index is a binary search;
  * edges relabelled to compact indices and sorted lexicographically
    by (src, dst, etype), with `indptr` row offsets (forward CSR) and
    the reverse orientation (`rindptr`, sorted by (dst, src, etype))
    for in-edge traversal;
  * a prefix sum over sorted edge counts, so any contiguous edge range
    (e.g. all etypes of one (src, dst) pair) sums in O(1).

Shapes stay static at the store capacities; validity is carried by
masks, so one compiled snapshot program serves any fill level.

**Incremental maintenance** (ROADMAP item, closed): a full
`build_snapshot` pays O(cap log cap) sorts per call.  `apply_delta`
instead merges ONE commit's `CommitDelta` (repro.graphstore.store)
into an existing snapshot with sort-free rank merges: both the base
CSR and the (small, freshly sorted) delta are lexicographically
sorted, so every element's new position is its old position plus its
rank in the other list — two vectorised binary searches and O(cap)
scatters, no O(cap log cap) recompaction.  The tie order is fully
deterministic (3-key sort), so the incremental snapshot is BIT-EXACT
against a fresh `build_snapshot` — tests assert array equality.
`SnapshotMaintainer` drives it: it buffers pending commit deltas and
falls back to a full rebuild only when the buffer overflows or the
store holds dangling edges (saturated node table) the merge cannot
place.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compression as C
from repro.graphstore.store import CommitDelta, GraphStore


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphSnapshot:
    # nodes, sorted by key; slots >= n_nodes hold the sentinel
    node_key: jax.Array  # (Ncap,) key dtype
    node_count: jax.Array  # (Ncap,) int32
    node_degree: jax.Array  # (Ncap,) int32 (unique-edge endpoints, from store)
    # forward CSR: edges sorted by (src_idx, dst_idx, etype); invalid rows = Ncap
    indptr: jax.Array  # (Ncap+1,) int32
    edge_row: jax.Array  # (Ecap,) int32 compact src index
    edge_col: jax.Array  # (Ecap,) int32 compact dst index
    edge_type: jax.Array  # (Ecap,) int32
    edge_count: jax.Array  # (Ecap,) int32
    edge_prefix: jax.Array  # (Ecap+1,) int32 cumsum of edge_count
    # reverse CSR: same edges sorted by (dst_idx, src_idx, etype)
    rindptr: jax.Array  # (Ncap+1,) int32
    redge_row: jax.Array  # (Ecap,) int32 compact dst index (the row)
    redge_col: jax.Array  # (Ecap,) int32 compact src index
    redge_type: jax.Array  # (Ecap,) int32 (delta merges rank by it)
    # sizes
    n_nodes: jax.Array  # scalar int32
    n_edges: jax.Array  # scalar int32 (unique (src,dst,etype) triples)

    def tree_flatten(self):
        return dataclasses.astuple(self), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def node_cap(self) -> int:
        return self.node_key.shape[0]

    @property
    def edge_valid(self) -> jax.Array:
        return self.edge_row < self.node_cap


def _lex_sort3(primary: jax.Array, secondary: jax.Array,
               tertiary: jax.Array) -> jax.Array:
    """Permutation sorting by (primary, secondary, tertiary), stable."""
    o = jnp.argsort(tertiary, stable=True)
    o = o[jnp.argsort(secondary[o], stable=True)]
    return o[jnp.argsort(primary[o], stable=True)]


@jax.jit
def build_snapshot(store: GraphStore) -> GraphSnapshot:
    """Compact the hash-table store into a CSR snapshot (one jit)."""
    kd = store.node_keys.dtype
    sent = C.sentinel_for(kd)
    ncap = store.node_keys.shape[0]

    # ---- nodes: sort by key, invalid last ----
    nvalid = store.node_keys != 0
    masked = jnp.where(nvalid, store.node_keys, sent)
    order = jnp.argsort(masked)
    node_key = masked[order]
    svalid = node_key != sent
    node_count = jnp.where(svalid, store.node_count[order], 0)
    node_degree = jnp.where(svalid, store.node_degree[order], 0)
    n_nodes = jnp.sum(svalid.astype(jnp.int32))

    # ---- edges: relabel endpoints to compact indices ----
    evalid = store.edge_keys != 0

    def to_idx(keys):
        idx = jnp.searchsorted(node_key, keys).astype(jnp.int32)
        ci = jnp.clip(idx, 0, ncap - 1)
        found = node_key[ci] == keys
        return jnp.where(evalid & found, ci, ncap)

    src_idx = to_idx(store.edge_src)
    dst_idx = to_idx(store.edge_dst)
    # an edge is in the snapshot only if BOTH endpoints resolved (a
    # saturated node table can leave dangling endpoints; see ROADMAP)
    dangling = (src_idx == ncap) | (dst_idx == ncap)
    src_idx = jnp.where(dangling, ncap, src_idx)
    dst_idx = jnp.where(dangling, ncap, dst_idx)

    # forward: lexicographic (src, dst, etype); invalid (row = Ncap)
    # sort last.  The etype tiebreak makes the order fully
    # deterministic, which `apply_delta` relies on for exact merges.
    perm = _lex_sort3(src_idx, dst_idx, store.edge_type)
    edge_row = src_idx[perm]
    edge_col = dst_idx[perm]
    live = edge_row < ncap
    edge_type = jnp.where(live, store.edge_type[perm], 0)
    edge_count = jnp.where(live, store.edge_count[perm], 0)
    rows = jnp.arange(ncap + 1, dtype=jnp.int32)
    indptr = jnp.searchsorted(edge_row, rows, side="left").astype(jnp.int32)
    edge_prefix = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(edge_count, dtype=jnp.int32)]
    )

    # reverse: lexicographic (dst, src, etype)
    rperm = _lex_sort3(dst_idx, src_idx, store.edge_type)
    redge_row = dst_idx[rperm]
    rlive = redge_row < ncap
    redge_col = jnp.where(rlive, src_idx[rperm], ncap)
    redge_type = jnp.where(rlive, store.edge_type[rperm], 0)
    rindptr = jnp.searchsorted(redge_row, rows, side="left").astype(jnp.int32)

    return GraphSnapshot(
        node_key=node_key,
        node_count=node_count,
        node_degree=node_degree,
        indptr=indptr,
        edge_row=edge_row,
        edge_col=edge_col,
        edge_type=edge_type,
        edge_count=edge_count,
        edge_prefix=edge_prefix,
        rindptr=rindptr,
        redge_row=redge_row,
        redge_col=redge_col,
        redge_type=redge_type,
        n_nodes=n_nodes,
        n_edges=indptr[-1],
    )


@jax.jit
def node_index(snap: GraphSnapshot, keys: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Key -> compact index lookup: (found (bool), idx (int32))."""
    ncap = snap.node_cap
    idx = jnp.searchsorted(snap.node_key, keys).astype(jnp.int32)
    ci = jnp.clip(idx, 0, ncap - 1)
    found = (snap.node_key[ci] == keys) & (keys != 0)
    return found, jnp.where(found, ci, -1)


# ---------------------------------------------------------------------------
# Incremental maintenance: merge one CommitDelta without recompacting
# ---------------------------------------------------------------------------


def _searchsorted3(ar, ac, at_, qr, qc, qt):
    """Vectorised 'left' binary search over a lexicographically sorted
    triple (ar, ac, at_) — the rank of each query triple.  Avoids a
    composite key (which overflows int32 at large node capacities)."""
    n = ar.shape[0]
    steps = int(math.ceil(math.log2(max(n, 2)))) + 1
    lo = jnp.zeros(qr.shape, jnp.int32)
    hi = jnp.full(qr.shape, n, jnp.int32)

    def body(_, c):
        lo, hi = c
        mid = (lo + hi) // 2
        m = jnp.clip(mid, 0, n - 1)
        vr, vc, vt = ar[m], ac[m], at_[m]
        lt = (vr < qr) | ((vr == qr) & ((vc < qc) | ((vc == qc) & (vt < qt))))
        open_ = lo < hi
        return (jnp.where(open_ & lt, mid + 1, lo),
                jnp.where(open_ & ~lt, mid, hi))

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


@jax.jit
def apply_delta(snap: GraphSnapshot, delta: CommitDelta
                ) -> Tuple[GraphSnapshot, jax.Array]:
    """Merge one commit's delta into the CSR without recompaction.

    Returns (snapshot', unplaced) where `unplaced` counts committed
    edges the merge could not place (dangling endpoints or count
    increments to edges absent from the base CSR) — callers must fall
    back to `build_snapshot` when it is nonzero.

    Everything is a rank merge: base and delta are both sorted, so new
    position = own index + rank in the other list.  Cost: O(cap)
    gathers/scatters plus one small sort of the delta — no O(cap log
    cap) recompaction of the full edge set.  Output is bit-exact
    against `build_snapshot` of the post-commit store."""
    kd = snap.node_key.dtype
    sent = C.sentinel_for(kd)
    ncap = snap.node_cap
    ecap = snap.edge_row.shape[0]
    big = jnp.int32(ncap + 1)  # sorts after every live row AND the ncap tail

    # ---- nodes: sorted-insert the new keys ----
    new_keys = jnp.sort(jnp.where(delta.node_new, delta.node_ids, sent))
    live_new = new_keys != sent
    k_new = jnp.sum(live_new.astype(jnp.int32))
    # base entry i shifts right by the number of new keys below it
    shift = jnp.searchsorted(new_keys, snap.node_key, side="left").astype(jnp.int32)
    base_valid = snap.node_key != sent
    nb = snap.node_key.shape[0]
    pos_base = jnp.where(base_valid,
                         jnp.arange(nb, dtype=jnp.int32) + shift, ncap)
    # new key j lands at (rank among base) + j
    rank_new = jnp.searchsorted(snap.node_key, new_keys, side="left").astype(jnp.int32)
    pos_new = jnp.where(live_new,
                        rank_new + jnp.arange(new_keys.shape[0], dtype=jnp.int32),
                        ncap)

    node_key = jnp.full((ncap,), sent, kd)
    node_key = node_key.at[pos_base].set(snap.node_key, mode="drop")
    node_key = node_key.at[pos_new].set(new_keys, mode="drop")
    node_count = jnp.zeros((ncap,), jnp.int32).at[pos_base].set(
        snap.node_count, mode="drop")
    node_degree = jnp.zeros((ncap,), jnp.int32).at[pos_base].set(
        snap.node_degree, mode="drop")

    def find_node(keys):
        p = jnp.clip(jnp.searchsorted(node_key, keys).astype(jnp.int32),
                     0, ncap - 1)
        return p, node_key[p] == keys

    # per-commit property updates: +1 count per committed node, +1
    # degree per endpoint of a new edge (masks prepared by ingest_step)
    pc, _ = find_node(delta.node_ids)
    node_count = node_count.at[jnp.where(delta.node_placed, pc, ncap)].add(
        1, mode="drop")
    ps, sok = find_node(delta.src)
    pd, dok = find_node(delta.dst)
    node_degree = node_degree.at[jnp.where(delta.src_deg, ps, ncap)].add(
        1, mode="drop")
    node_degree = node_degree.at[jnp.where(delta.dst_deg, pd, ncap)].add(
        1, mode="drop")

    # old compact index -> new compact index (monotone, so relabelled
    # base edges KEEP their lexicographic order — pure gather)
    o2n = jnp.concatenate([
        jnp.where(jnp.arange(nb, dtype=jnp.int32) < snap.n_nodes,
                  jnp.arange(nb, dtype=jnp.int32) + shift, ncap),
        jnp.full((1,), ncap, jnp.int32),
    ])

    # ---- delta edges: endpoints -> new compact indices ----
    live_d = delta.edge_new & sok & dok
    drow = jnp.where(live_d, ps, big)
    dcol = jnp.where(live_d, pd, big)
    det = jnp.where(live_d, delta.etype, 0)
    dcnt = jnp.where(live_d, delta.count, 0)

    def merge(base_row, base_col, base_et, base_cnt, delta_a, delta_b):
        """Rank-merge delta edges (sorted by (delta_a, delta_b, etype),
        where `a` is this orientation's row key) into the relabelled
        base orientation.  New position = own index + rank in the
        other (sorted) list — no recompaction."""
        brow = o2n[base_row]
        bcol = o2n[base_col]
        sa, sb, set_, scnt, slive = jax.lax.sort(
            (delta_a, delta_b, det, dcnt, live_d.astype(jnp.int32)),
            num_keys=3)
        rank_d = _searchsorted3(brow, bcol, base_et, sa, sb, set_)
        pos_d = jnp.where(slive != 0,
                          rank_d + jnp.arange(sa.shape[0], dtype=jnp.int32),
                          ecap)
        rank_b = _searchsorted3(sa, sb, set_, brow, bcol, base_et)
        pos_b = jnp.arange(ecap, dtype=jnp.int32) + rank_b
        row = jnp.full((ecap,), ncap, jnp.int32).at[pos_b].set(
            brow, mode="drop").at[pos_d].set(sa, mode="drop")
        col = jnp.full((ecap,), ncap, jnp.int32).at[pos_b].set(
            bcol, mode="drop").at[pos_d].set(sb, mode="drop")
        et = jnp.zeros((ecap,), jnp.int32).at[pos_b].set(
            base_et, mode="drop").at[pos_d].set(set_, mode="drop")
        cnt = None
        if base_cnt is not None:
            cnt = jnp.zeros((ecap,), jnp.int32).at[pos_b].set(
                base_cnt, mode="drop").at[pos_d].set(scnt, mode="drop")
        return row, col, et, cnt

    # forward orientation: sort/merge by (row, col, etype)
    edge_row, edge_col, edge_type, edge_count = merge(
        snap.edge_row, snap.edge_col, snap.edge_type, snap.edge_count,
        drow, dcol)

    # count increments for pre-existing edges: locate their triple
    inc = delta.edge_placed & ~delta.edge_new & sok & dok
    q = _searchsorted3(edge_row, edge_col, edge_type,
                       jnp.where(inc, ps, big), jnp.where(inc, pd, big),
                       jnp.where(inc, delta.etype, 0))
    qc = jnp.clip(q, 0, ecap - 1)
    match = inc & (edge_row[qc] == ps) & (edge_col[qc] == pd) & \
        (edge_type[qc] == delta.etype)
    edge_count = edge_count.at[jnp.where(match, qc, ecap)].add(
        delta.count, mode="drop")

    rows = jnp.arange(ncap + 1, dtype=jnp.int32)
    indptr = jnp.searchsorted(edge_row, rows, side="left").astype(jnp.int32)
    edge_prefix = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(edge_count, dtype=jnp.int32)]
    )

    # reverse orientation: sort/merge by (col, row, etype)
    redge_row, redge_col, redge_type, _ = merge(
        snap.redge_row, snap.redge_col, snap.redge_type, None, dcol, drow)
    rindptr = jnp.searchsorted(redge_row, rows, side="left").astype(jnp.int32)

    # anything the merge could not place? (dangling new edge, or a
    # count increment whose edge is not in the base CSR)
    unplaced = jnp.sum((delta.edge_new & ~live_d).astype(jnp.int32)) + \
        jnp.sum((inc & ~match).astype(jnp.int32)) + \
        jnp.sum((delta.edge_placed & ~delta.edge_new & ~(sok & dok))
                .astype(jnp.int32))

    out = GraphSnapshot(
        node_key=node_key,
        node_count=node_count,
        node_degree=node_degree,
        indptr=indptr,
        edge_row=edge_row,
        edge_col=edge_col,
        edge_type=edge_type,
        edge_count=edge_count,
        edge_prefix=edge_prefix,
        rindptr=rindptr,
        redge_row=redge_row,
        redge_col=redge_col,
        redge_type=redge_type,
        n_nodes=snap.n_nodes + k_new,
        n_edges=indptr[-1],
    )
    return out, unplaced


class SnapshotMaintainer:
    """Keeps a CSR snapshot current across commits without paying a
    full `build_snapshot` per query (ROADMAP "incremental snapshot
    maintenance").

    `absorb(et, stats)` (the `GraphIngestor.commit_hooks` shape)
    buffers each commit's `CommitDelta`; `snapshot(store)` applies the
    pending deltas to the cached snapshot and falls back to a full
    rebuild only when (a) there is no snapshot yet, (b) the pending
    buffer overflowed `max_pending`, or (c) the store holds edges the
    merge cannot place (dangling endpoints under node-table
    saturation).  `full_builds` / `delta_applies` count both paths."""

    def __init__(self, max_pending: int = 32):
        from repro.telemetry.spans import NULL_REGISTRY

        self.max_pending = max_pending
        self._snap: Optional[GraphSnapshot] = None
        self._pending: List[CommitDelta] = []
        self._force_rebuild = True
        self.full_builds = 0
        self.delta_applies = 0
        self.telemetry = NULL_REGISTRY

    def absorb(self, et, stats) -> None:
        delta = None if stats is None else stats.get("delta")
        if delta is None:
            self._force_rebuild = True  # opaque commit: cannot merge
        else:
            self._pending.append(delta)

    def reset(self) -> None:
        """Drop cached/pending state so the next `snapshot()` is a full
        rebuild — how checkpoint restore (repro.resilience) re-anchors
        the view on the restored store without serialising the CSR."""
        self._snap = None
        self._pending = []
        self._force_rebuild = True

    def snapshot(self, store: GraphStore) -> GraphSnapshot:
        tel = self.telemetry
        pending, self._pending = self._pending, []
        snap = self._snap
        if (snap is None or self._force_rebuild
                or len(pending) > self.max_pending):
            with tel.span("snapshot.rebuild"):
                snap = build_snapshot(store)
            self.full_builds += 1
        else:
            for d in pending:
                with tel.span("snapshot.apply_delta"):
                    snap, unplaced = apply_delta(snap, d)
                self.delta_applies += 1
                if int(unplaced):
                    with tel.span("snapshot.rebuild"):
                        snap = build_snapshot(store)
                    self.full_builds += 1
                    break
        self._snap = snap
        # dangling edges (store committed, CSR excluded) can be
        # resurrected by later node inserts — only a rebuild sees that
        self._force_rebuild = int(store.n_edges) != int(snap.n_edges)
        return snap
