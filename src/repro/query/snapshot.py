"""Store -> device-resident CSR snapshot (the compaction pass).

The open-addressing hash tables of `repro.graphstore` are ideal for
O(1) ingest but hostile to traversal: edges of one node are scattered
across the table.  `build_snapshot` compacts them — entirely on
device, one jit — into a CSR form the query engine can traverse with
gathers and segment ops:

  * nodes sorted by key (invalid slots carry the all-ones sentinel and
    sort last), so key -> compact index is a binary search;
  * edges relabelled to compact indices and sorted lexicographically
    by (src, dst), with `indptr` row offsets (forward CSR) and the
    reverse orientation (`rindptr`, sorted by (dst, src)) for in-edge
    traversal;
  * a prefix sum over sorted edge counts, so any contiguous edge range
    (e.g. all etypes of one (src, dst) pair) sums in O(1).

Shapes stay static at the store capacities; validity is carried by
masks, so one compiled snapshot program serves any fill level.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import compression as C
from repro.graphstore.store import GraphStore


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphSnapshot:
    # nodes, sorted by key; slots >= n_nodes hold the sentinel
    node_key: jax.Array  # (Ncap,) key dtype
    node_count: jax.Array  # (Ncap,) int32
    node_degree: jax.Array  # (Ncap,) int32 (unique-edge endpoints, from store)
    # forward CSR: edges sorted by (src_idx, dst_idx); invalid rows = Ncap
    indptr: jax.Array  # (Ncap+1,) int32
    edge_row: jax.Array  # (Ecap,) int32 compact src index
    edge_col: jax.Array  # (Ecap,) int32 compact dst index
    edge_type: jax.Array  # (Ecap,) int32
    edge_count: jax.Array  # (Ecap,) int32
    edge_prefix: jax.Array  # (Ecap+1,) int32 cumsum of edge_count
    # reverse CSR: same edges sorted by (dst_idx, src_idx)
    rindptr: jax.Array  # (Ncap+1,) int32
    redge_row: jax.Array  # (Ecap,) int32 compact dst index (the row)
    redge_col: jax.Array  # (Ecap,) int32 compact src index
    # sizes
    n_nodes: jax.Array  # scalar int32
    n_edges: jax.Array  # scalar int32 (unique (src,dst,etype) triples)

    def tree_flatten(self):
        return dataclasses.astuple(self), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def node_cap(self) -> int:
        return self.node_key.shape[0]

    @property
    def edge_valid(self) -> jax.Array:
        return self.edge_row < self.node_cap


def _lex_sort(primary: jax.Array, secondary: jax.Array) -> jax.Array:
    """Permutation sorting by (primary, secondary), stable."""
    o1 = jnp.argsort(secondary, stable=True)
    o2 = jnp.argsort(primary[o1], stable=True)
    return o1[o2]


@jax.jit
def build_snapshot(store: GraphStore) -> GraphSnapshot:
    """Compact the hash-table store into a CSR snapshot (one jit)."""
    kd = store.node_keys.dtype
    sent = C.sentinel_for(kd)
    ncap = store.node_keys.shape[0]

    # ---- nodes: sort by key, invalid last ----
    nvalid = store.node_keys != 0
    masked = jnp.where(nvalid, store.node_keys, sent)
    order = jnp.argsort(masked)
    node_key = masked[order]
    svalid = node_key != sent
    node_count = jnp.where(svalid, store.node_count[order], 0)
    node_degree = jnp.where(svalid, store.node_degree[order], 0)
    n_nodes = jnp.sum(svalid.astype(jnp.int32))

    # ---- edges: relabel endpoints to compact indices ----
    evalid = store.edge_keys != 0

    def to_idx(keys):
        idx = jnp.searchsorted(node_key, keys).astype(jnp.int32)
        ci = jnp.clip(idx, 0, ncap - 1)
        found = node_key[ci] == keys
        return jnp.where(evalid & found, ci, ncap)

    src_idx = to_idx(store.edge_src)
    dst_idx = to_idx(store.edge_dst)
    # an edge is in the snapshot only if BOTH endpoints resolved (a
    # saturated node table can leave dangling endpoints; see ROADMAP)
    dangling = (src_idx == ncap) | (dst_idx == ncap)
    src_idx = jnp.where(dangling, ncap, src_idx)
    dst_idx = jnp.where(dangling, ncap, dst_idx)

    # forward: lexicographic (src, dst); invalid (row = Ncap) sort last
    perm = _lex_sort(src_idx, dst_idx)
    edge_row = src_idx[perm]
    edge_col = dst_idx[perm]
    live = edge_row < ncap
    edge_type = jnp.where(live, store.edge_type[perm], 0)
    edge_count = jnp.where(live, store.edge_count[perm], 0)
    rows = jnp.arange(ncap + 1, dtype=jnp.int32)
    indptr = jnp.searchsorted(edge_row, rows, side="left").astype(jnp.int32)
    edge_prefix = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(edge_count, dtype=jnp.int32)]
    )

    # reverse: lexicographic (dst, src)
    rperm = _lex_sort(dst_idx, src_idx)
    redge_row = dst_idx[rperm]
    redge_col = jnp.where(redge_row < ncap, src_idx[rperm], ncap)
    rindptr = jnp.searchsorted(redge_row, rows, side="left").astype(jnp.int32)

    return GraphSnapshot(
        node_key=node_key,
        node_count=node_count,
        node_degree=node_degree,
        indptr=indptr,
        edge_row=edge_row,
        edge_col=edge_col,
        edge_type=edge_type,
        edge_count=edge_count,
        edge_prefix=edge_prefix,
        rindptr=rindptr,
        redge_row=redge_row,
        redge_col=redge_col,
        n_nodes=n_nodes,
        n_edges=indptr[-1],
    )


@jax.jit
def node_index(snap: GraphSnapshot, keys: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Key -> compact index lookup: (found (bool), idx (int32))."""
    ncap = snap.node_cap
    idx = jnp.searchsorted(snap.node_key, keys).astype(jnp.int32)
    ci = jnp.clip(idx, 0, ncap - 1)
    found = (snap.node_key[ci] == keys) & (keys != 0)
    return found, jnp.where(found, ci, -1)
