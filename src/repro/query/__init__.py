"""Streaming query & analytics engine — the read path over the store.

Two complementary ways to query the ingested graph:

  * **Ingestion-time sketch** (`repro.query.sketch`): a GSS/TCM-style
    fixed-shape count-min sketch of the edge-weight matrix plus
    per-node degree counters and a heavy-hitter table, updated
    incrementally as batches flow through the pipeline
    (`SketchStage` / `QuerySink`).  Answers edge-weight, degree and
    top-k queries *live, during ingestion*, without touching the
    store; answers are upper bounds that closely track exact counts.
  * **Snapshot engine** (`repro.query.snapshot` + `repro.query.engine`):
    a compaction pass converts the open-addressing hash tables of
    `repro.graphstore` into a device-resident CSR snapshot; vectorised
    ops answer exact queries over it — degree distribution, top-k
    heavy nodes, k-hop neighborhood expansion, triangle counting,
    edge lookups.

CLI: ``python -m repro.launch.query`` (ingest-then-query and
query-while-ingesting modes).
"""
from repro.query.sketch import (
    GraphSketch,
    init_sketch,
    sketch_degree,
    sketch_edge_weight,
    sketch_error_bound,
    sketch_heavy_hitters,
    sketch_update,
)
from repro.query.snapshot import (
    GraphSnapshot,
    SnapshotMaintainer,
    apply_delta,
    build_snapshot,
    node_index,
)
from repro.query.engine import (
    degree_distribution,
    edge_lookup,
    k_hop,
    top_k_degree,
    triangle_count,
)
from repro.query.stage import QuerySink, SketchStage

__all__ = [
    "GraphSketch", "init_sketch", "sketch_update",
    "sketch_edge_weight", "sketch_degree", "sketch_heavy_hitters",
    "sketch_error_bound",
    "GraphSnapshot", "build_snapshot", "apply_delta",
    "SnapshotMaintainer", "node_index",
    "degree_distribution", "top_k_degree", "k_hop", "triangle_count",
    "edge_lookup",
    "SketchStage", "QuerySink",
]
