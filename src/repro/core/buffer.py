"""Adaptive buffer controller — Algorithm 2 + PerfMon (§III).

The controller senses three signal families, exactly as the paper:
  * data rate: velocity (1st derivative) and acceleration (2nd),
  * data content: bucket diversity ratio rho and graph density d
    (from the edge table),
  * consumer load: mu, the occupancy of the store's ingest engine
    (the paper's Zabbix CPU-usage; here the measured busy-fraction of
    the compiled ingest step — DESIGN.md §2).

Control law (paper steps 1-7):
  1. PerfMon predicts beta_e (Eq. 2) and mu_exp (Eq. 4/5) and the CPU
     slope s.
  2. mu_exp >= cpu_max            -> grow buffer by theta1 * headroom
  3. mu_exp >= (1+theta2)*cpu_max
     and load still rising (s>=0) -> THROTTLE: spill batch to disk
  4. mu_exp < cpu_max             -> push to the store (GRAPHPUSH)
  5. buffer > beta_min and calm   -> shrink by theta2 (latency recovery)
  6. mu_exp <= theta2 * cpu_max   -> drain spilled data from disk
  7. predictors updated online (RLS) from observed (rho, d, beta_e, mu)
"""
from __future__ import annotations

import collections
import dataclasses
import os
import pickle
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.paper_ingest import IngestConfig
from repro.core import predictor as P


@dataclasses.dataclass
class PerfSample:
    t: float
    mu: float  # consumer occupancy [0,1]
    rho: float  # bucket diversity ratio
    density: float
    beta: int  # current buffer size (records)
    beta_e: float  # effective (output) buffer size
    velocity: float  # records/s
    accel: float
    action: str
    spill_depth: int
    compression: float
    delay_s: float = 0.0  # system delay alpha (Eq. 3): queued work at consumer


class PerfMon:
    """PERFMON (Alg. 2 lines 16-23): content stats + load predictions."""

    # weight of the sketch's diversity hint when blended into rho
    # (the window-mean stays the anchor; the sketch refines it)
    SKETCH_RHO_WEIGHT = 0.5
    # weight of the dictionary-compression hint when shrinking the
    # predicted effective buffer: referenced edges commit by direct
    # scatter (no probing), so a highly compressible bucket loads the
    # consumer far less than its size suggests
    COMPRESS_BETA_WEIGHT = 0.5

    def __init__(self, cfg: IngestConfig):
        self.cfg = cfg
        self.beta_model = P.init_beta_model(cfg.K, cfg.R)
        self.mu_model = P.init_mu_model(cfg.A, cfg.B)
        self.mu_hist: Deque[float] = collections.deque([0.0] * 16, maxlen=16)
        self.rate_hist: Deque[Tuple[float, float]] = collections.deque(maxlen=16)
        self.rho_hist: Deque[float] = collections.deque(maxlen=cfg.diversity_window)
        # store table pressure (fused-upsert commit stats): load factor
        # of the fuller table, and inserts dropped by the last commit
        self.table_pressure = 0.0
        self.dropped_inserts = 0
        # sketch-guided diversity hint (None until a "sketch" event is
        # observed; then blended into predict()'s rho)
        self.sketch_rho: Optional[float] = None
        # dictionary-compression hint (None until a compressed commit
        # reports; the paper's "data content" signal, §III-A)
        self.dict_hit: Optional[float] = None

    # ---- signal ingestion ----
    def observe_rate(self, t: float, records: float):
        self.rate_hist.append((t, records))

    def observe_mu(self, mu: float):
        self.mu_hist.append(float(mu))

    def observe_pressure(self, pressure: float, dropped: int):
        """Table-pressure signal from commit stats: the store's load
        factor and the inserts its (already escalated) probing dropped."""
        self.table_pressure = float(pressure)
        self.dropped_inserts = int(dropped)

    def observe_sketch(self, concentration: float):
        """Sketch-guided control (ROADMAP): the ingestion-time sketch's
        heavy-hitter mass fraction is a content-diversity signal richer
        than the pre-commit bloom rho — high concentration means the
        stream is collapsing onto few nodes, so compression will be
        strong and the effective buffer small.  Stored as a diversity
        hint rho ~ 1 - concentration and blended in `predict`."""
        self.sketch_rho = float(np.clip(1.0 - concentration, 0.0, 1.0))

    def observe_compression(self, hit_rate: float, ratio: float):
        """Compressibility signal from the dictionary-compression path
        (repro.compress): the fraction of the last commit's unique
        edges that became pattern references.  Stored as a hint that
        scales the predicted effective buffer in `predict` — high hit
        rates mean the next push costs less than its size suggests."""
        del ratio  # reported for observability; the hit rate drives beta_e
        self.dict_hit = float(np.clip(hit_rate, 0.0, 1.0))

    def observe_bucket(self, rho: float, density: float, beta_e: float):
        self.rho_hist.append(float(rho))
        # online refinement of Eq. 2 (K[i], R[i] tracked per time chunk)
        x = P.beta_features(float(np.mean(self.rho_hist)), float(density))
        self.beta_model = P.rls_update(self.beta_model, x, np.float32(beta_e))

    def observe_mu_outcome(self, mu_prev: float, beta_e: float, mu_now: float):
        x = P.mu_features(float(mu_prev), float(beta_e))
        self.mu_model = P.rls_update(self.mu_model, x, np.float32(mu_now))

    # ---- derived signals ----
    def velocity(self) -> Tuple[float, float]:
        """(records/s, d(records/s)/dt) from the rate history."""
        if len(self.rate_hist) < 3:
            return 0.0, 0.0
        ts = np.asarray([t for t, _ in self.rate_hist])
        rs = np.asarray([r for _, r in self.rate_hist])
        dt = np.maximum(np.diff(ts), 1e-6)
        v = rs[1:] / dt
        vel = float(v[-1])
        acc = float((v[-1] - v[0]) / max(ts[-1] - ts[1], 1e-6))
        return vel, acc

    def predict(self, edge_table_size: float, density: float) -> Tuple[float, float, float]:
        """Returns (beta_e, mu_exp, slope) — Alg. 2 line 2."""
        rho = float(np.mean(self.rho_hist)) if self.rho_hist else 1.0
        if self.sketch_rho is not None:
            w = self.SKETCH_RHO_WEIGHT
            rho = (1.0 - w) * rho + w * self.sketch_rho
        beta_e = float(P.predict_beta_e(self.beta_model, rho, density))
        beta_e = max(beta_e, float(edge_table_size))
        if self.dict_hit is not None:
            # referenced edges skip probing: shrink the effective load
            beta_e *= 1.0 - self.COMPRESS_BETA_WEIGHT * self.dict_hit
        mu_prev = self.mu_hist[-1]
        mu_exp = float(P.predict_mu(self.mu_model, mu_prev, beta_e))
        s = float(P.cpu_slope(np.asarray(self.mu_hist, np.float32)))
        return beta_e, mu_exp, s

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> dict:
        import jax

        npify = lambda t: jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), t)
        return {
            "beta_model": npify(self.beta_model),
            "mu_model": npify(self.mu_model),
            "mu_hist": list(self.mu_hist),
            "rate_hist": list(self.rate_hist),
            "rho_hist": list(self.rho_hist),
            "table_pressure": self.table_pressure,
            "dropped_inserts": self.dropped_inserts,
            "sketch_rho": self.sketch_rho,
            "dict_hit": self.dict_hit,
        }

    def restore_state(self, s: dict) -> None:
        import jax
        import jax.numpy as jnp

        devify = lambda t: jax.tree_util.tree_map(jnp.asarray, t)
        self.beta_model = devify(s["beta_model"])
        self.mu_model = devify(s["mu_model"])
        self.mu_hist = collections.deque(s["mu_hist"],
                                         maxlen=self.mu_hist.maxlen)
        self.rate_hist = collections.deque(s["rate_hist"],
                                           maxlen=self.rate_hist.maxlen)
        self.rho_hist = collections.deque(s["rho_hist"],
                                          maxlen=self.rho_hist.maxlen)
        self.table_pressure = float(s["table_pressure"])
        self.dropped_inserts = int(s["dropped_inserts"])
        self.sketch_rho = s["sketch_rho"]
        self.dict_hit = s["dict_hit"]


class SpillStore:
    """Data-throttling spill file (Alg. 2 FlushDataToDisk / LoadFromDisk)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(path, exist_ok=True)
        self._n = 0
        self._order: List[str] = []

    def flush(self, records: list):
        fn = os.path.join(self.path, f"spill_{self._n:08d}.pkl")
        with open(fn, "wb") as f:
            pickle.dump(records, f)
        self._order.append(fn)
        self._n += 1

    def drain(self, max_batches: int = 1) -> list:
        out = []
        for _ in range(min(max_batches, len(self._order))):
            fn = self._order.pop(0)
            with open(fn, "rb") as f:
                out.extend(pickle.load(f))
            os.unlink(fn)
        return out

    @property
    def depth(self) -> int:
        return len(self._order)

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> dict:
        """Spill-file CONTENTS, not just names: files drained between a
        checkpoint and a crash would otherwise be unreadable on resume."""
        files = []
        for fn in self._order:
            with open(fn, "rb") as f:
                files.append((os.path.basename(fn), f.read()))
        return {"n": self._n, "files": files}

    def restore_state(self, s: dict) -> None:
        os.makedirs(self.path, exist_ok=True)
        self._order = []
        for base, blob in s["files"]:
            fn = os.path.join(self.path, base)
            with open(fn, "wb") as f:
                f.write(blob)
            self._order.append(fn)
        self._n = int(s["n"])


@dataclasses.dataclass
class ControllerDecision:
    action: str  # "push" | "hold" | "throttle" | "drain+push"
    beta: int  # new buffer size
    beta_e: float
    mu_exp: float
    slope: float
    reason: str = ""  # throttle cause: "load" (step 3) | "pressure" (table)


class BufferController:
    """Algorithm 2.  Host-side control; all heavy math jit-compiled."""

    def __init__(self, cfg: IngestConfig, spill_dir: str = "/tmp/repro_spill"):
        self.cfg = cfg
        self.beta = cfg.beta_init
        self.perfmon = PerfMon(cfg)
        self.spill = SpillStore(spill_dir)
        self.trace: List[PerfSample] = []
        # observability (workload harness): per-action decision counts,
        # table-pressure throttle count, and an optional decision hook
        self.decision_counts: collections.Counter = collections.Counter()
        self.pressure_throttles = 0
        self.on_decision: Optional[Callable[["ControllerDecision"], None]] = None
        # audit trail (repro.telemetry.AuditTrail): when attached, every
        # decision is recorded with the full PerfMon input vector and
        # later resolved with the realized (mu, beta_e) by the tick loop
        self.audit = None

    def decide(self, edge_table_size: float, density: float,
               now: Optional[float] = None) -> ControllerDecision:
        cfg = self.cfg
        # dropped_inserts is consumed by the pressure throttle below;
        # capture it first so the audit trail sees what decide() saw
        dropped_in = self.perfmon.dropped_inserts
        beta_e, mu_exp, s = self.perfmon.predict(edge_table_size, density)
        beta = self.beta
        action = "push"
        reason = ""

        if mu_exp >= cfg.cpu_max:
            # step 2: high alert -- absorb by growing the buffer
            grow = int(cfg.theta1 * (cfg.beta_max - beta))
            if beta + grow <= cfg.beta_max:
                beta = beta + max(grow, 1)
            action = "hold"
            if mu_exp >= (1.0 + cfg.theta2) * cfg.cpu_max and s >= 0.0:
                # step 3: still rising -> data throttling to disk
                action = "throttle"
                reason = "load"
        else:
            # step 4: push; step 5: recover latency by shrinking
            if beta - cfg.theta2 * beta >= cfg.beta_min:
                beta = int(beta - cfg.theta2 * beta)
            action = "push"
            if mu_exp <= cfg.theta2 * cfg.cpu_max and self.spill.depth > 0:
                action = "drain+push"  # step 6

        # table pressure (fused-upsert commit stats): if the last push
        # dropped inserts even under escalated probing, the store is
        # saturating — spill this bucket instead of losing data.  One-
        # shot: the signal is consumed so the next tick retries a push
        # (the adaptive probe budget may have grown meanwhile).
        if self.perfmon.dropped_inserts > 0 and action in ("push", "drain+push"):
            action = "throttle"
            reason = "pressure"
            self.pressure_throttles += 1
            self.perfmon.dropped_inserts = 0

        self.beta = max(cfg.beta_min, min(beta, cfg.beta_max))
        dec = ControllerDecision(action, self.beta, beta_e, mu_exp, s, reason)
        self.decision_counts[action] += 1
        if self.audit is not None:
            self.audit.record(dec, self.perfmon, now,
                              spill_depth=self.spill.depth,
                              dropped=dropped_in)
        if self.on_decision is not None:
            self.on_decision(dec)
        return dec

    def observe_sketch(self, payload: Dict):
        """Policy hook for MetricsHub "sketch" events (QuerySink): turn
        the heavy-hitter table into a concentration signal — the mass
        the top-k nodes hold of everything the sketch absorbed — and
        feed it to PerfMon as a diversity hint (sketch-guided control)."""
        absorbed = float(payload.get("absorbed", 0) or 0)
        hh = payload.get("hh_counts") or []
        if absorbed <= 0 or not len(hh):
            return
        conc = float(np.clip(float(np.sum(hh)) / absorbed, 0.0, 1.0))
        self.perfmon.observe_sketch(conc)

    def record(self, sample: PerfSample):
        self.trace.append(sample)

    # ---- checkpoint surface (repro.resilience) ----
    def state(self) -> dict:
        return {
            "beta": self.beta,
            "perfmon": self.perfmon.state(),
            "spill": self.spill.state(),
            "trace": list(self.trace),
            "decision_counts": dict(self.decision_counts),
            "pressure_throttles": self.pressure_throttles,
        }

    def restore_state(self, s: dict) -> None:
        self.beta = int(s["beta"])
        self.perfmon.restore_state(s["perfmon"])
        self.spill.restore_state(s["spill"])
        self.trace = list(s["trace"])
        self.decision_counts = collections.Counter(s["decision_counts"])
        self.pressure_throttles = int(s["pressure_throttles"])

    def trace_arrays(self):
        keys = [f.name for f in dataclasses.fields(PerfSample) if f.name != "action"]
        return {k: np.asarray([getattr(s, k) for s in self.trace]) for k in keys}, [
            s.action for s in self.trace
        ]
