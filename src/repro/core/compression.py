"""Graph compression (§I, §III-B "Graph Compression", Algorithms 1+3).

The paper's insight: during a burst, content is highly redundant (shared
hashtags/users), so the redundant portion of the graph must be ingested
only once — duplicate edges collapse into a `count` property, duplicate
nodes are emitted once per batch.

TPU adaptation (DESIGN.md §2): the paper's serial hash-map INSERTEDGE
does pointer chasing; here dedup is *sort-based* — mix (src,dst,etype)
into one key, sort, mark run heads, segment-sum counts — fully
vectorised and MXU/VPU friendly.  The Pallas kernel in
repro.kernels.edge_dedup tiles the same algorithm in VMEM; this module
is the pure-jnp implementation (and the kernel's oracle).

All functions are dtype-agnostic over the key width: uint32 in default
jax config, uint64 under x64 (the ingestion entrypoints enable x64 for
exact identity; see launch/ingest.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def key_dtype():
    return jnp.uint64 if jax.config.jax_enable_x64 else jnp.uint32


def sentinel_for(kd):
    """All-ones key (sorts last, marks invalid)."""
    return jnp.asarray(2**64 - 1 if kd == jnp.uint64 else 2**32 - 1, kd)


# Bijective packing layout for uint64 keys: [1b tag=0][1b pack=1]
# [27b src][27b dst][8b etype].  Ids that fit get an exact, collision-
# free key; anything wider falls back to the splitmix hash with bit 63
# set, so the packed and mixed domains can never alias each other.
PACK_SRC_BITS = 27
PACK_DST_BITS = 27
PACK_ETYPE_BITS = 8


def mix_keys(src: jax.Array, dst: jax.Array, etype: jax.Array) -> jax.Array:
    """Combine (src, dst, etype) into one dedup key.

    uint32: splitmix-style hash (collisions possible but rare).
    uint64: exact bijective packing when src/dst < 2^27 and
    0 <= etype < 2^8, hash fallback (bit 63 set) otherwise — distinct
    triples that fit always get distinct keys.  Selection is per
    element, so the same triple maps to the same key in every batch.
    """
    kd = src.dtype
    c1 = jnp.asarray(0x9E3779B97F4A7C15 if kd == jnp.uint64 else 0x9E3779B9, kd)
    c2 = jnp.asarray(0xBF58476D1CE4E5B9 if kd == jnp.uint64 else 0x85EBCA6B, kd)
    x = src * c1 + dst
    x = (x ^ (x >> 30)) * c2
    x = x ^ (x >> 27)
    x = x + etype.astype(kd)
    if kd == jnp.uint64:
        et = etype.astype(kd)
        fits = ((src < (1 << PACK_SRC_BITS)) & (dst < (1 << PACK_DST_BITS))
                & (etype >= 0) & (et < (1 << PACK_ETYPE_BITS)))
        packed = (jnp.asarray(1 << 62, kd)
                  | (src << (PACK_DST_BITS + PACK_ETYPE_BITS))
                  | (dst << PACK_ETYPE_BITS) | et)
        x = jnp.where(fits, packed, x | jnp.asarray(1 << 63, kd))
    # keep the all-ones sentinel and the 0 = empty-slot marker free
    sentinel = sentinel_for(kd)
    x = jnp.where(x == sentinel, sentinel - jnp.asarray(1, kd), x)
    return jnp.where(x == 0, jnp.asarray(2, kd), x)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedBatch:
    """Fixed-capacity dedup result (valid-masked)."""

    keys: jax.Array  # (n,) sorted unique keys (invalid slots = sentinel)
    counts: jax.Array  # (n,) int32 multiplicity of each unique key
    index: jax.Array  # (n,) original position of each unique key's first hit
    valid: jax.Array  # (n,) bool
    n_unique: jax.Array  # scalar int32
    n_input: jax.Array  # scalar int32 (valid inputs)

    def tree_flatten(self):
        # NOT dataclasses.astuple: astuple recurses into children and
        # rebuilds containers (a PartitionSpec leaf would come back a
        # plain tuple) — return the fields themselves.
        return (self.keys, self.counts, self.index, self.valid,
                self.n_unique, self.n_input), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def dedup_with_counts(keys: jax.Array, valid: jax.Array) -> CompressedBatch:
    """Sort-based dedup: O(n log n), fixed shapes throughout."""
    kd = keys.dtype
    sentinel = sentinel_for(kd)  # all ones; sorts last
    n = keys.shape[0]
    masked = jnp.where(valid, keys, sentinel)
    order = jnp.argsort(masked)
    sk = masked[order]
    is_valid = sk != sentinel
    head = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & is_valid
    run = jnp.cumsum(head.astype(jnp.int32)) - 1  # run id per sorted position
    n_unique = jnp.sum(head.astype(jnp.int32))
    run_c = jnp.clip(run, 0, n - 1)
    counts = jax.ops.segment_sum(is_valid.astype(jnp.int32), run_c, num_segments=n)
    # sorted position of each run's head (dups carry value n; min -> head)
    first_pos = jax.ops.segment_min(
        jnp.where(head, jnp.arange(n), n), run_c, num_segments=n
    )
    fp = jnp.clip(first_pos, 0, n - 1)
    uk = jnp.where(jnp.arange(n) < n_unique, sk[fp], sentinel)
    uidx = order[fp]
    return CompressedBatch(
        keys=uk,
        counts=jnp.where(jnp.arange(n) < n_unique, counts, 0),
        index=jnp.where(jnp.arange(n) < n_unique, uidx, 0),
        valid=jnp.arange(n) < n_unique,
        n_unique=n_unique,
        n_input=jnp.sum(valid.astype(jnp.int32)),
    )


@jax.jit
def compress_edges(src, dst, etype, valid) -> Tuple[CompressedBatch, jax.Array]:
    """Algorithm-1 edge compression: returns (dedup result, density).

    Density d = 2|E| / (|V| (|V|-1)) over the batch (paper §III-A)."""
    keys = mix_keys(src, dst, etype)
    comp = dedup_with_counts(keys, valid)
    nodes = unique_nodes(src, dst, valid)
    v = jnp.maximum(nodes.n_unique.astype(jnp.float32), 2.0)
    density = 2.0 * comp.n_unique.astype(jnp.float32) / (v * (v - 1.0))
    return comp, density


@jax.jit
def unique_nodes(src, dst, valid) -> CompressedBatch:
    both = jnp.concatenate([src, dst])
    v = jnp.concatenate([valid, valid])
    return dedup_with_counts(both, v)


def compression_ratio(n_unique_nodes, n_unique_edges, n_raw_edges) -> jax.Array:
    """Paper Fig. 13 metric: effective insert instructions over raw.

    Raw Cypher load = one MERGE per edge endpoint pair + CREATE per edge
    (2 node instructions + 1 edge instruction per raw edge); compressed
    load = unique nodes + unique edges."""
    eff = (n_unique_nodes + n_unique_edges).astype(jnp.float32)
    raw = jnp.maximum((3 * n_raw_edges).astype(jnp.float32), 1.0)
    return eff / raw
