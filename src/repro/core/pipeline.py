"""The seven-step ingestion pipeline (Fig. 4):

  Filter -> Buffer -> Model Transformation -> Batch Optimizer ->
  Graph Ingestor -> DBMS pool -> Store

`IngestionPipeline.run()` is the closed control loop: each tick pulls
from the stream, filters, buffers; the buffer controller (Algorithm 2)
decides push/hold/throttle/drain from the predictive models; pushed
buckets are model-transformed (Algorithm 1, with graph compression) and
committed (Algorithm 3).  `uncontrolled=True` bypasses the controller
(and optionally compression) — the paper's meltdown baseline
(Figs. 1-3, 7).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, List, Optional

import numpy as np

from repro.configs.paper_ingest import IngestConfig
from repro.core.buffer import BufferController, PerfSample
from repro.core.edge_table import from_raw_batch
from repro.core.ingestor import GraphIngestor
from repro.core.transform import MappingSpec, create_edges, tweet_mapping
from repro.graphstore.store import init_store
from repro.ingest.filter import analysis_filter, api_keyword_filter, apply_filters


@dataclasses.dataclass
class PipelineReport:
    samples: dict
    actions: List[str]
    total_records: int
    total_instructions: int
    raw_instructions: int
    spill_events: int
    drain_events: int
    compression_ratios: np.ndarray
    wall_s: float

    @property
    def mean_compression(self) -> float:
        cr = self.compression_ratios
        return float(cr.mean()) if cr.size else 1.0


class IngestionPipeline:
    def __init__(
        self,
        cfg: IngestConfig = IngestConfig(),
        mapping: Optional[MappingSpec] = None,
        keywords: Iterable[str] = (),
        uncontrolled: bool = False,
        compress: bool = True,
        spill_dir: str = "/tmp/repro_spill",
        consumer_speed: float = 1.0,
    ):
        self.cfg = cfg
        self.mapping = mapping or tweet_mapping()
        self.stage1 = api_keyword_filter(list(keywords))
        self.uncontrolled = uncontrolled
        self.compress = compress
        self.controller = BufferController(cfg, spill_dir=spill_dir)
        self.store = init_store(cfg.store_nodes, cfg.store_edges)
        self.ingestor = GraphIngestor(self.store, occupancy_window=8.0)
        self.buffer: List[dict] = []
        self.consumer_speed = consumer_speed  # scales simulated mu
        self._mu_sim = 0.0

    # ------------------------------------------------------------------
    def _consume_mu(self, instructions: int, dt: float) -> float:
        """Queued consumer model of the store engine.

        On real hardware mu is measured (ingestor.occupancy); the
        closed-loop simulation models the paper's observed behaviour: a
        finite-capacity engine with a commit queue.  Sustained
        over-delivery pins mu at 1.0 (the Fig. 2 meltdown) and builds
        backlog, which is exactly the system-delay term alpha of Eq. 3."""
        cap = 3_000.0 * self.consumer_speed  # instructions/s at mu=1
        self._backlog = getattr(self, "_backlog", 0.0) + instructions
        can = cap * dt
        done = min(self._backlog, can)
        self._backlog -= done
        inst_mu = done / can
        # short smoothing window (Zabbix-style sampling)
        self._mu_sim = 0.5 * self._mu_sim + 0.5 * inst_mu
        return min(self._mu_sim, 1.0)

    @property
    def system_delay_s(self) -> float:
        """alpha (Eq. 3): seconds of work queued at the consumer."""
        cap = 3_000.0 * self.consumer_speed
        return getattr(self, "_backlog", 0.0) / cap

    def _transform_and_commit(self, records: List[dict], now: float, dt: float):
        raw = create_edges(records, self.mapping)
        cap = max(64, 1 << int(np.ceil(np.log2(max(raw.n_edges, 1)))))
        cap = min(cap, self.cfg.max_edges_per_batch)
        et = from_raw_batch(raw, cap)
        if not self.compress:
            # uncompressed baseline: ingestion load = raw instructions
            n_instr = 3 * raw.n_edges
        else:
            n_instr = int(et.n_nodes) + int(et.n_edges)
        out = self.ingestor.push(et, now=now)
        mu = self._consume_mu(n_instr, dt)
        rho = out.get("rho", 1.0) if out.get("committed") else 1.0
        cr = float(et.compression_ratio())
        return et, mu, rho, cr, n_instr, 3 * raw.n_edges

    # ------------------------------------------------------------------
    def run(self, source_ticks, max_ticks: int = 300) -> PipelineReport:
        cfg = self.cfg
        ctl = self.controller
        total_records = 0
        total_instr = 0
        raw_instr = 0
        spills = drains = 0
        crs: List[float] = []
        t_start = time.time()
        last_beta_e, last_mu = cfg.beta_init, 0.0

        for i, tick in enumerate(source_ticks):
            if i >= max_ticks:
                break
            now, dt = tick.t, 1.0
            # ---- 1. filter ----
            recs = apply_filters(tick.records, self.stage1, analysis_filter)
            total_records += len(recs)
            ctl.perfmon.observe_rate(now, len(recs))
            # ---- 2. buffer ----
            self.buffer.extend(recs)

            if self.uncontrolled:
                # paper Figs. 1-3/7: push every tick, no control
                if self.buffer:
                    batch, self.buffer = self.buffer, []
                    et, mu, rho, cr, ni, ri = self._transform_and_commit(batch, now, dt)
                    ctl.perfmon.observe_mu(mu)
                    total_instr += ni
                    raw_instr += ri
                    crs.append(cr)
                    ctl.record(PerfSample(now, mu, rho, float(et.density()),
                                          len(self.buffer), float(et.size()),
                                          *ctl.perfmon.velocity(), "push",
                                          ctl.spill.depth, cr, self.system_delay_s))
                continue

            # ---- 3-7. controlled path ----
            density = 0.0
            size_est = len(self.buffer) * 4.0  # ~edges per record
            dec = ctl.decide(size_est, density)

            if dec.action in ("push", "drain+push") and len(self.buffer) >= 1:
                if dec.action == "drain+push" and ctl.spill.depth:
                    self.buffer.extend(ctl.spill.drain())
                    drains += 1
                batch = self.buffer[: ctl.beta]
                self.buffer = self.buffer[ctl.beta :]
                if batch:
                    et, mu, rho, cr, ni, ri = self._transform_and_commit(batch, now, dt)
                    ctl.perfmon.observe_mu(mu)
                    ctl.perfmon.observe_bucket(rho, float(et.density()), float(et.size()))
                    ctl.perfmon.observe_mu_outcome(last_mu, last_beta_e, mu)
                    last_beta_e, last_mu = float(et.size()), mu
                    total_instr += ni
                    raw_instr += ri
                    crs.append(cr)
                    ctl.record(PerfSample(now, mu, rho, float(et.density()),
                                          len(self.buffer), float(et.size()),
                                          *ctl.perfmon.velocity(), dec.action,
                                          ctl.spill.depth, cr, self.system_delay_s))
            elif dec.action == "throttle":
                # spill the whole buffer to disk (data throttling)
                if self.buffer:
                    ctl.spill.flush(self.buffer)
                    self.buffer = []
                    spills += 1
                mu = self._consume_mu(0, dt)
                ctl.perfmon.observe_mu(mu)
                ctl.record(PerfSample(now, mu, 0.0, 0.0, 0,
                                      dec.beta_e, *ctl.perfmon.velocity(),
                                      "throttle", ctl.spill.depth, 1.0,
                                      self.system_delay_s))
            else:  # hold
                mu = self._consume_mu(0, dt)
                ctl.perfmon.observe_mu(mu)
                ctl.record(PerfSample(now, mu, 0.0, 0.0, len(self.buffer),
                                      dec.beta_e, *ctl.perfmon.velocity(),
                                      "hold", ctl.spill.depth, 1.0,
                                      self.system_delay_s))

        samples, actions = ctl.trace_arrays()
        return PipelineReport(
            samples=samples,
            actions=actions,
            total_records=total_records,
            total_instructions=total_instr,
            raw_instructions=raw_instr,
            spill_events=spills,
            drain_events=drains,
            compression_ratios=np.asarray(crs),
            wall_s=time.time() - t_start,
        )
