"""Back-compat wrapper over the composable API (`repro.api`).

The seven-step loop (Fig. 4) used to live here as one fused
`IngestionPipeline.run()`; it is now `repro.api.StreamPipeline`
composed from Source/Stage/Consumer/Sink parts.  This module keeps the
original constructor and `run()` contract (same reports, same mu/delay
numerics for a fixed seed) for existing callers; new code should use
`repro.api.PipelineBuilder` directly.
"""
from __future__ import annotations

from typing import Iterable, Optional

from repro.api.consumers import SimulatedConsumer
from repro.api.metrics import PipelineReport
from repro.api.pipeline import StreamPipeline
from repro.api.sinks import GraphStoreSink
from repro.api.stages import BufferControlStage, FilterStage, TransformStage
from repro.configs.paper_ingest import IngestConfig
from repro.core.buffer import BufferController
from repro.core.transform import MappingSpec

__all__ = ["IngestionPipeline", "PipelineReport"]


class IngestionPipeline:
    """The paper pipeline with its original (seed) signature."""

    def __init__(
        self,
        cfg: IngestConfig = IngestConfig(),
        mapping: Optional[MappingSpec] = None,
        keywords: Iterable[str] = (),
        uncontrolled: bool = False,
        compress: bool = True,
        spill_dir: str = "/tmp/repro_spill",
        consumer_speed: float = 1.0,
    ):
        self.cfg = cfg
        self.uncontrolled = uncontrolled
        self.compress = compress
        self.consumer_speed = consumer_speed
        controller = BufferController(cfg, spill_dir=spill_dir)
        self._pipe = StreamPipeline(
            cfg=cfg,
            filter_stage=FilterStage(keywords),
            transform=TransformStage(
                mapping=mapping,
                max_edges_per_batch=cfg.max_edges_per_batch,
                compress=compress,
            ),
            buffer_stage=BufferControlStage(controller=controller),
            consumer=SimulatedConsumer(speed=consumer_speed),
            sink=GraphStoreSink(node_cap=cfg.store_nodes,
                                edge_cap=cfg.store_edges),
            uncontrolled=uncontrolled,
        )

    # ---- seed-era accessors ----
    @property
    def controller(self) -> BufferController:
        return self._pipe.controller

    @property
    def ingestor(self):
        return self._pipe.sink.ingestor

    @property
    def store(self):
        return self._pipe.store

    @property
    def buffer(self):
        return self._pipe.buffer

    @property
    def mapping(self):
        return self._pipe.transform.mapping

    @property
    def system_delay_s(self) -> float:
        """alpha (Eq. 3): seconds of work queued at the consumer."""
        return self._pipe.system_delay_s

    def run(self, source_ticks, max_ticks: int = 300) -> PipelineReport:
        return self._pipe.run(source_ticks, max_ticks=max_ticks)
