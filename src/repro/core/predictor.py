"""Predictive models of §III-A, learned online.

Two models drive the adaptive buffer controller:

  Eq. 2   beta_e[i] = K[i] * phi1(rho[i]) + R[i] * phi2(d[i])
          with phi1 linear and phi2 quadratic (paper §IV-A finding;
          fitted K=0.597, R=1.48 on their testbed).

  Eq. 4/5 mu_exp[n] = A * mu[n-1] + B * log(beta_e[n]) + c
          (model (g) of Table I — the paper's best fit).

Both are fit by jit-compiled recursive least squares (RLS) with a
forgetting factor, so the coefficients track regime changes (bursts)
exactly as the paper's "parameters need to be dynamically determined at
each time chunk" requires.  The paper's offline scikit-learn fits are
reproduced in benchmarks/bench_prediction.py using the same feature
maps.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RLSState:
    """Recursive least squares over features x: theta ~ P * x * err."""

    theta: jax.Array  # (k,)
    P: jax.Array  # (k,k) inverse covariance
    n: jax.Array  # scalar observation count

    def tree_flatten(self):
        return (self.theta, self.P, self.n), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def rls_init(k: int, theta0=None, p0: float = 100.0) -> RLSState:
    theta = jnp.zeros((k,), jnp.float32) if theta0 is None else jnp.asarray(theta0, jnp.float32)
    return RLSState(theta=theta, P=jnp.eye(k, dtype=jnp.float32) * p0, n=jnp.zeros((), jnp.float32))


@jax.jit
def rls_update(s: RLSState, x: jax.Array, y: jax.Array, lam: float = 0.98) -> RLSState:
    """One RLS step with forgetting factor lam."""
    x = x.astype(jnp.float32)
    Px = s.P @ x
    denom = lam + x @ Px
    k_gain = Px / denom
    err = y - s.theta @ x
    theta = s.theta + k_gain * err
    P = (s.P - jnp.outer(k_gain, Px)) / lam
    return RLSState(theta=theta, P=P, n=s.n + 1)


@jax.jit
def rls_predict(s: RLSState, x: jax.Array) -> jax.Array:
    return s.theta @ x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Eq. 2 — effective buffer size from content statistics
# ---------------------------------------------------------------------------


def beta_features(rho: float, d: float) -> jax.Array:
    """phi1 linear in rho, phi2 quadratic in d, plus intercept."""
    return jnp.asarray([rho, d * d, 1.0], jnp.float32)


def init_beta_model(K: float = 0.597, R: float = 1.48) -> RLSState:
    """Seeded with the paper's fitted coefficients."""
    return rls_init(3, theta0=[K, R, 0.0])


def predict_beta_e(s: RLSState, rho: float, d: float) -> jax.Array:
    return jnp.maximum(rls_predict(s, beta_features(rho, d)), 0.0)


# ---------------------------------------------------------------------------
# Eq. 4/5 — expected consumer load from effective buffer size
# ---------------------------------------------------------------------------


def mu_features(mu_prev: float, beta_e: float) -> jax.Array:
    return jnp.asarray(
        [mu_prev, jnp.log(jnp.maximum(beta_e, 1.0)), 1.0], jnp.float32
    )


def init_mu_model(A: float = 0.01, B: float = 0.09, c: float = 0.0) -> RLSState:
    """Model (g) of Table I: mu = A*mu[n-1] + B*log(beta_e) + c."""
    return rls_init(3, theta0=[A, B, c])


def predict_mu(s: RLSState, mu_prev: float, beta_e: float) -> jax.Array:
    return jnp.clip(rls_predict(s, mu_features(mu_prev, beta_e)), 0.0, 1.0)


# ---------------------------------------------------------------------------
# CPU-slope estimator (PerfMon's `s <- getCPUSlope()`)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("window",))
def cpu_slope(mu_hist: jax.Array, window: int = 8) -> jax.Array:
    """Least-squares slope of the last `window` load samples."""
    y = mu_hist[-window:]
    x = jnp.arange(window, dtype=jnp.float32)
    xm = x - x.mean()
    ym = y - y.mean()
    return (xm @ ym) / jnp.maximum(xm @ xm, 1e-9)


# ---------------------------------------------------------------------------
# Offline fits (Table I reproduction) — closed-form ridge on features
# ---------------------------------------------------------------------------


def fit_offline(xs: np.ndarray, ys: np.ndarray, ridge: float = 1e-6):
    """Least squares fit; returns (coef, mae, mse, rmse) like Table I."""
    xs = np.asarray(xs, np.float64)
    ys = np.asarray(ys, np.float64)
    k = xs.shape[1]
    coef = np.linalg.solve(xs.T @ xs + ridge * np.eye(k), xs.T @ ys)
    pred = xs @ coef
    err = ys - pred
    mae = float(np.abs(err).mean())
    mse = float((err ** 2).mean())
    return coef, mae, mse, float(np.sqrt(mse))


TABLE1_MODELS = {
    # name -> feature builder f(mu_prev, beta_e) matching Table I rows
    "a_mu_log": lambda m, b: [m, np.log(np.maximum(b, 1.0)), np.ones_like(m)],
    "b_mu_beta2": lambda m, b: [m, b ** 2, np.ones_like(m)],
    "c_mu_beta": lambda m, b: [m, b, np.ones_like(m)],
    "d_logmu_log": lambda m, b: [np.log(np.maximum(m, 1e-3)), np.log(np.maximum(b, 1.0)), np.ones_like(m)],
    "f_mu2_log": lambda m, b: [m ** 2, np.log(np.maximum(b, 1.0)), np.ones_like(m)],
}
