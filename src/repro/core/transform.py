"""Model transformation (§II-A, Fig. 5/6, Algorithm 1 CREATEEDGE).

Transforms native stream objects (tweet-like JSON dicts) into property-
graph edge batches.  Portability works exactly as in the paper: the
problem-specific part is a declarative `MappingSpec` (the paper's XML
map file — here a python/JSON structure with the same content: input
model, output model, node types, edge defs, extractor bindings), while
the extraction library is generic over dict-shaped records.

Output is device-ready: fixed-capacity int64 id arrays (nodes are
identified by a 64-bit splitmix hash of (type_tag, key) — the TPU
adaptation of the paper's string node index, see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# 64-bit hashing (shared with the Pallas kernels and the graph store)
# ---------------------------------------------------------------------------

_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finaliser (uint64 -> uint64)."""
    x = x.astype(np.uint64)
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15)) & _MASK
        z = x
        z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & _MASK
        z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & _MASK
        z = z ^ (z >> np.uint64(31))
    return z


def hash_str(type_tag: int, s: str) -> int:
    """Stable node id for (node_type, key)."""
    h = np.uint64(1469598103934665603)  # FNV offset
    with np.errstate(over="ignore"):
        for b in s.encode("utf-8"):
            h = ((h ^ np.uint64(b)) * np.uint64(1099511628211)) & _MASK
        h ^= np.uint64(type_tag) << np.uint64(56)
    v = int(splitmix64(np.asarray([h]))[0])
    return v or 1  # 0 is the empty-slot sentinel


# ---------------------------------------------------------------------------
# Mapping spec (the paper's XML map file, Fig. 5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NodeDef:
    type_name: str
    type_tag: int
    key: Callable[[dict], Optional[str]]  # extraction binding (getName()...)


@dataclasses.dataclass(frozen=True)
class EdgeDef:
    name: str
    etype: int
    # return list of (src_key, dst_key) string pairs for one record
    extract: Callable[[dict], List[Tuple[str, str]]]
    src_type: int = 0
    dst_type: int = 0


@dataclasses.dataclass(frozen=True)
class MappingSpec:
    input_model: str  # "json"
    output_model: str  # "property-graph"
    nodes: Tuple[NodeDef, ...]
    edges: Tuple[EdgeDef, ...]
    max_edges_per_record: int = 24


# node type tags
T_USER, T_TWEET, T_HASHTAG = 1, 2, 3
# edge types (Fig. 6)
E_OWNER, E_MENTIONED, E_HT_USED_IN, E_MENTIONED_WITH_HT = 1, 2, 3, 4


def tweet_mapping() -> MappingSpec:
    """The paper's Twitter mapping (Fig. 6): user/tweet/hashtag nodes,
    owner / mentioned / hashtag-used-in / mentioned-with-ht edges."""

    def owner(r):
        return [(r["user"], r["id"])]

    def mentioned(r):
        return [(r["id"], m) for m in r.get("mentions", ())]

    def ht_used(r):
        return [(h, r["id"]) for h in r.get("hashtags", ())]

    def ht_mention(r):
        return [
            (h, m)
            for h in r.get("hashtags", ())
            for m in r.get("mentions", ())
        ]

    return MappingSpec(
        input_model="json",
        output_model="property-graph",
        nodes=(
            NodeDef("user", T_USER, lambda r: r["user"]),
            NodeDef("tweet", T_TWEET, lambda r: r["id"]),
            NodeDef("hashtag", T_HASHTAG, lambda r: None),
        ),
        edges=(
            EdgeDef("owner", E_OWNER, owner, T_USER, T_TWEET),
            EdgeDef("mentioned", E_MENTIONED, mentioned, T_TWEET, T_USER),
            EdgeDef("hashtag-used-in", E_HT_USED_IN, ht_used, T_HASHTAG, T_TWEET),
            EdgeDef("mentioned-with-ht", E_MENTIONED_WITH_HT, ht_mention, T_HASHTAG, T_USER),
        ),
    )


def reddit_mapping() -> MappingSpec:
    """Portability demo (paper §III-B): same data model, different map —
    author/post/subreddit graph from reddit-like records."""

    def authored(r):
        return [(r["author"], r["id"])]

    def posted_in(r):
        return [(r["id"], r["subreddit"])]

    def replied(r):
        p = r.get("parent")
        return [(r["id"], p)] if p else []

    return MappingSpec(
        input_model="json",
        output_model="property-graph",
        nodes=(
            NodeDef("author", T_USER, lambda r: r["author"]),
            NodeDef("post", T_TWEET, lambda r: r["id"]),
            NodeDef("subreddit", T_HASHTAG, lambda r: r["subreddit"]),
        ),
        edges=(
            EdgeDef("authored", E_OWNER, authored, T_USER, T_TWEET),
            EdgeDef("posted-in", E_HT_USED_IN, posted_in, T_TWEET, T_HASHTAG),
            EdgeDef("replied-to", E_MENTIONED, replied, T_TWEET, T_TWEET),
        ),
    )


# ---------------------------------------------------------------------------
# CREATEEDGE (Algorithm 1) — batch transformation to edge arrays
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RawEdgeBatch:
    """Device-ready edge batch (pre-compression)."""

    src: np.ndarray  # (n,) uint64 node ids
    dst: np.ndarray  # (n,) uint64
    etype: np.ndarray  # (n,) int32
    src_type: np.ndarray  # (n,) int32
    dst_type: np.ndarray  # (n,) int32
    n_records: int

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


def create_edges(records: Sequence[dict], mapping: MappingSpec) -> RawEdgeBatch:
    """CREATEEDGE over a mini-batch of records.  Linear in #edges."""
    srcs: List[int] = []
    dsts: List[int] = []
    ets: List[int] = []
    sts: List[int] = []
    dts: List[int] = []
    for r in records:
        for ed in mapping.edges:
            pairs = ed.extract(r)
            if len(pairs) > mapping.max_edges_per_record:
                pairs = pairs[: mapping.max_edges_per_record]
            for sk, dk in pairs:
                srcs.append(hash_str(ed.src_type, str(sk)))
                dsts.append(hash_str(ed.dst_type, str(dk)))
                ets.append(ed.etype)
                sts.append(ed.src_type)
                dts.append(ed.dst_type)
    return RawEdgeBatch(
        src=np.asarray(srcs, np.uint64),
        dst=np.asarray(dsts, np.uint64),
        etype=np.asarray(ets, np.int32),
        src_type=np.asarray(sts, np.int32),
        dst_type=np.asarray(dts, np.int32),
        n_records=len(records),
    )
