"""The in-memory edge-centric structure of Algorithm 1 / Figs. 8-9.

`EdgeTable` is the device-resident, fixed-capacity analogue of the
paper's multithreaded edge table: a deduplicated edge list with a
`count` property per edge (duplicate handling of Alg. 1 line 20), the
indexed node list, and the table-level metadata the controller reads —
diversity ratio, density, velocity (§III-A parameters).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as C
from repro.core.transform import RawEdgeBatch


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EdgeTable:
    """Fixed-capacity deduplicated edge table + node index (device)."""

    # edges
    src: jax.Array  # (cap,) key-dtype
    dst: jax.Array
    etype: jax.Array  # (cap,) int32
    count: jax.Array  # (cap,) int32   duplicate-edge multiplicity
    edge_valid: jax.Array  # (cap,) bool
    # node index — (2*cap,) so every endpoint of a valid edge is
    # present (cap edges have up to 2*cap distinct endpoints; the seed
    # truncated to cap, silently dropping node instructions)
    node_ids: jax.Array  # (2*cap,) sorted unique keys, sentinel tail
    node_valid: jax.Array  # (2*cap,) bool
    # per-edge endpoint positions in `node_ids` (the dedup index): the
    # store reuses the node-upsert slots through these instead of
    # re-probing the hash table for degree updates
    src_node_idx: jax.Array  # (cap,) int32
    dst_node_idx: jax.Array  # (cap,) int32
    # metadata
    n_edges: jax.Array  # scalar int32 (unique)
    n_nodes: jax.Array  # scalar int32 (unique)
    n_raw: jax.Array  # scalar int32 (pre-compression edge instructions)

    def tree_flatten(self):
        # NOT dataclasses.astuple: it deep-copies every leaf and
        # rebuilds tuple-subclass leaves (PartitionSpec) as plain
        # tuples — return the fields themselves
        return tuple(getattr(self, f.name)
                     for f in dataclasses.fields(self)), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ---- table-level metadata (PerfMon inputs, Alg. 2 lines 17-19) ----
    def density(self) -> jax.Array:
        v = jnp.maximum(self.n_nodes.astype(jnp.float32), 2.0)
        return 2.0 * self.n_edges.astype(jnp.float32) / (v * (v - 1.0))

    def size(self) -> jax.Array:
        """PerfMon `e = edgeTable.size() + nodeIndex.size()`."""
        return self.n_edges + self.n_nodes

    def compression_ratio(self) -> jax.Array:
        return C.compression_ratio(self.n_nodes, self.n_edges, self.n_raw)


@jax.jit
def build_edge_table(src, dst, etype, valid) -> EdgeTable:
    """Model transformation output -> compressed edge table (Alg. 1)."""
    cap = src.shape[0]
    ecomp, _ = C.compress_edges(src, dst, etype, valid)
    ncomp = C.unique_nodes(src, dst, valid)
    # gather representative (src,dst,etype) of each unique edge
    idx = ecomp.index
    esrc = jnp.where(ecomp.valid, src[idx], 0)
    edst = jnp.where(ecomp.valid, dst[idx], 0)
    # endpoint -> node-index position: `node_ids` is sorted unique with
    # a sentinel tail, so the position is one binary search; every
    # valid endpoint is guaranteed present (index is 2*cap wide)
    nidx = lambda k: jnp.clip(
        jnp.searchsorted(ncomp.keys, k).astype(jnp.int32), 0, 2 * cap - 1)
    return EdgeTable(
        src=esrc,
        dst=edst,
        etype=jnp.where(ecomp.valid, etype[idx], 0),
        count=ecomp.counts,
        edge_valid=ecomp.valid,
        node_ids=ncomp.keys,
        node_valid=ncomp.valid,
        src_node_idx=nidx(esrc),
        dst_node_idx=nidx(edst),
        n_edges=ecomp.n_unique,
        n_nodes=ncomp.n_unique,
        n_raw=ecomp.n_input,
    )


def from_raw_batch(raw: RawEdgeBatch, capacity: int) -> EdgeTable:
    """Host RawEdgeBatch -> padded device arrays -> EdgeTable."""
    kd = C.key_dtype()
    n = min(raw.n_edges, capacity)
    pad = capacity - n

    def prep(a, dtype):
        a = np.asarray(a[:n])
        return jnp.concatenate(
            [jnp.asarray(a, dtype), jnp.zeros((pad,), dtype)]
        )

    src = prep(raw.src, kd)
    dst = prep(raw.dst, kd)
    et = prep(raw.etype, jnp.int32)
    valid = jnp.arange(capacity) < n
    return build_edge_table(src, dst, et, valid)
