"""Graph Ingestor + Commit (Algorithm 3 GRAPHPUSH).

Bridges the pipeline to the graph store: converts compressed edge
tables into store commits, respecting a bounded ingestion pool
(the paper's bolt-connector pool), with commit-failure archiving and
retry.  The consumer-occupancy measurement lives here: mu = busy-time
of the ingest engine over the sampling window — the TPU-native stand-in
for the paper's Zabbix CPU-user-time (DESIGN.md §2).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Tuple

import jax

from repro.core.edge_table import EdgeTable
from repro.graphstore.store import GraphStore, commit_compressed, ingest_step
from repro.telemetry.spans import NULL_REGISTRY


@dataclasses.dataclass
class CommitRecord:
    t: float
    busy_s: float
    instructions: int
    new_nodes: int
    batch_nodes: int
    ok: bool
    probe_rounds: int = 0  # adaptive probe budget the commit ran with
    dropped: int = 0  # inserts lost to table pressure (probing exhausted)
    refs: int = 0  # dictionary pattern references applied (repro.compress)


class GraphIngestor:
    def __init__(self, store: GraphStore, max_pool_size: int = 4, fail_hook=None,
                 occupancy_window: float = 10.0):
        self.store = store
        self.max_pool_size = max_pool_size
        self.pool: Deque[EdgeTable] = collections.deque()
        self.archive: List[EdgeTable] = []  # failed commits (Alg. 3 line 18)
        self.commits: List[CommitRecord] = []
        self.fail_hook = fail_hook  # fault injection for tests
        # observers of every SUCCESSFUL commit: hook(et, stats).  Push can
        # drain pooled batches and retry_archive replays old ones, so a
        # commit-consistent observer (e.g. repro.query.QuerySink) must
        # hook here rather than watch push() arguments.  `commit_hook`
        # is the single assignable slot (sketch maintenance);
        # `commit_hooks` fan out to any number of extra observers
        # (e.g. the incremental snapshot maintainer).
        self.commit_hook = None
        self.commit_hooks: List = []
        self.occupancy_window = occupancy_window
        self._busy: Deque[Tuple[float, float]] = collections.deque(maxlen=512)
        # span telemetry (repro.telemetry): commit milliseconds split
        # into upsert-dispatch / device-wait / observer-hook sub-spans.
        # NULL_REGISTRY = disabled; PipelineBuilder.with_telemetry swaps
        # in the live registry.
        self.telemetry = NULL_REGISTRY

    # ------------------------------------------------------------------
    def push(self, et: EdgeTable, now: Optional[float] = None) -> dict:
        """GRAPHPUSH: pool admission + commit.  Returns commit stats."""
        if len(self.pool) >= self.max_pool_size:
            # pool full: hold in local memory until timeout (paper §III-B)
            self.pool.append(et)
            return {"committed": False, "pooled": len(self.pool)}
        self.pool.append(et)
        stats = {}
        while self.pool:
            batch = self.pool.popleft()
            stats = self._commit(batch, now)
            if not stats["committed"]:
                break
        return stats

    def _commit(self, et: EdgeTable, now: Optional[float]) -> dict:
        tel = self.telemetry
        t0 = time.perf_counter()
        try:
            if self.fail_hook is not None and self.fail_hook():
                raise ConnectionError("injected commit failure")
            compressed = hasattr(et, "residual")
            with tel.span("commit.upsert"):
                if compressed:
                    # pattern-aware path: repro.compress.CompressedCommit
                    new_store, s = commit_compressed(self.store, et)
                else:
                    new_store, s = ingest_step(self.store, et)
            with tel.span("commit.wait"):
                jax.block_until_ready(new_store.n_nodes)
            self.store = new_store
            busy = time.perf_counter() - t0
            tel.observe("commit.total", busy)
            wall = now if now is not None else time.time()
            self._busy.append((wall, busy))
            rec = CommitRecord(
                t=wall,
                busy_s=busy,
                instructions=int(s["instructions"]),
                new_nodes=int(s["new_nodes"]),
                batch_nodes=int(s["batch_nodes"]),
                ok=True,
                probe_rounds=int(s.get("probe_rounds", 0)),
                dropped=int(s.get("dropped_inserts", 0)),
                refs=int(s.get("dict_refs", 0)),
            )
            self.commits.append(rec)
            with tel.span("commit.hooks"):
                if self.commit_hook is not None:
                    self.commit_hook(et, s)
                for hook in self.commit_hooks:
                    hook(et, s)
            rho = rec.new_nodes / max(rec.batch_nodes, 1)
            out = {
                "committed": True,
                "stats": s,
                "busy_s": busy,
                "rho": rho,
                "instructions": rec.instructions,
                # table-pressure signals for the Algorithm-2 controller
                "dropped": rec.dropped,
                "probe_rounds": rec.probe_rounds,
                "pressure": max(float(s.get("node_load", 0.0)),
                                float(s.get("edge_load", 0.0))),
            }
            if "dict_refs" in s:
                # compressibility signals (repro.compress -> controller)
                out["refs"] = rec.refs
                out["dict_hit_rate"] = float(s["dict_hit_rate"])
            return out
        except ConnectionError:
            # commit failed (network/DBMS) -> archive for replay
            self.archive.append(et)
            self.commits.append(
                CommitRecord(now or time.time(), 0.0, 0, 0, 0, ok=False)
            )
            return {"committed": False, "archived": len(self.archive)}

    # ------------------------------------------------------------------
    def retry_archive(self, now: Optional[float] = None) -> int:
        """Re-commit archived batches (connection restored)."""
        n = 0
        while self.archive:
            et = self.archive.pop(0)
            if not self._commit(et, now)["committed"]:
                break
            n += 1
        return n

    def occupancy(self, now: float, sim_busy: Optional[float] = None) -> float:
        """mu in [0,1]: ingest busy-fraction over the trailing window."""
        w0 = now - self.occupancy_window
        busy = sum(b for (t, b) in self._busy if t >= w0)
        return min(busy / self.occupancy_window, 1.0)

    def pending_work_s(self) -> float:
        """Estimated seconds of work queued in the pool (system-delay
        alpha for the measured path): pooled batches x mean commit
        cost over the busy window."""
        busy = [b for (_, b) in self._busy]
        mean_busy = sum(busy) / len(busy) if busy else 0.0
        return len(self.pool) * mean_busy
