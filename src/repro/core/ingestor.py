"""Graph Ingestor + Commit (Algorithm 3 GRAPHPUSH).

Bridges the pipeline to the graph store: converts compressed edge
tables into store commits, respecting a bounded ingestion pool
(the paper's bolt-connector pool), with commit-failure archiving and
retry.  The consumer-occupancy measurement lives here: mu = busy-time
of the ingest engine over the sampling window — the TPU-native stand-in
for the paper's Zabbix CPU-user-time (DESIGN.md §2).

Resilience posture (repro.resilience):
  * the archive is BOUNDED — past `max_archive` in-memory batches,
    failed commits spill to disk (pickled host pytrees) and refill
    FIFO as retries drain them, so a long outage cannot OOM the host;
  * the pool has a hard cap (`pool_cap`, default 4x `max_pool_size`):
    overflow batches divert to the archive instead of growing the
    deque without bound, counted in `pool_overflows`;
  * with a `RetryPolicy` attached, consecutive commit failures arm a
    capped-exponential-backoff gate (`next_retry_t`): `retry_archive`
    refuses to hot-loop while the gate is closed, and after
    `degrade_after` consecutive failures `push` enters DEGRADED mode —
    batches archive directly without hammering the dead store, while
    sketch/telemetry service upstream continues.  With no policy
    (the default) every legacy behavior is unchanged.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import pickle
import tempfile
import time
from typing import Deque, List, Optional, Tuple

import jax
import numpy as np

from repro.core.edge_table import EdgeTable
from repro.graphstore.store import GraphStore, commit_compressed, ingest_step
from repro.telemetry.spans import NULL_REGISTRY


@dataclasses.dataclass
class CommitRecord:
    t: float
    busy_s: float
    instructions: int
    new_nodes: int
    batch_nodes: int
    ok: bool
    probe_rounds: int = 0  # adaptive probe budget the commit ran with
    dropped: int = 0  # inserts lost to table pressure (probing exhausted)
    refs: int = 0  # dictionary pattern references applied (repro.compress)


def _to_host(et):
    """Edge-table pytree -> host numpy leaves (pickle/spill-safe)."""
    return jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), et)


class GraphIngestor:
    def __init__(self, store: GraphStore, max_pool_size: int = 4, fail_hook=None,
                 occupancy_window: float = 10.0, retry_policy=None,
                 pool_cap: Optional[int] = None, max_archive: int = 128,
                 archive_dir: Optional[str] = None, degrade_after: int = 3):
        self.store = store
        self.max_pool_size = max_pool_size
        # hard admission ceiling: beyond it, batches divert to the archive
        self.pool_cap = pool_cap if pool_cap is not None else 4 * max_pool_size
        self.pool: Deque[EdgeTable] = collections.deque()
        self.archive: Deque[EdgeTable] = collections.deque()  # Alg. 3 line 18
        self.commits: List[CommitRecord] = []
        self.fail_hook = fail_hook  # fault injection (nullary, or a
        # repro.resilience.FaultInjector with `wants_now = True`)
        # observers of every SUCCESSFUL commit: hook(et, stats).  Push can
        # drain pooled batches and retry_archive replays old ones, so a
        # commit-consistent observer (e.g. repro.query.QuerySink) must
        # hook here rather than watch push() arguments.  `commit_hook`
        # is the single assignable slot (sketch maintenance);
        # `commit_hooks` fan out to any number of extra observers
        # (e.g. the incremental snapshot maintainer).
        self.commit_hook = None
        self.commit_hooks: List = []
        self.occupancy_window = occupancy_window
        self._busy: Deque[Tuple[float, float]] = collections.deque(maxlen=512)
        # span telemetry (repro.telemetry): commit milliseconds split
        # into upsert-dispatch / device-wait / observer-hook sub-spans.
        # NULL_REGISTRY = disabled; PipelineBuilder.with_telemetry swaps
        # in the live registry.
        self.telemetry = NULL_REGISTRY
        # ---- resilience (repro.resilience; None policy = legacy) ----
        self.retry_policy = retry_policy
        self.max_archive = max_archive
        self.archive_dir = archive_dir
        self.degrade_after = degrade_after
        self._archive_spill: List[str] = []  # on-disk overflow, FIFO
        self._archive_n = 0  # monotone spill-file counter
        self.consecutive_failures = 0
        self.next_retry_t = float("-inf")  # backoff gate (simulated time)
        # accounting: archived_total == replayed + archive_depth must
        # hold at all times (the chaos harness's no-batch-lost invariant)
        self.archived_total = 0
        self.replayed = 0
        self.attempts = 0
        self.pool_overflows = 0
        # ---- provenance (repro.lineage; None tracker = zero cost) ----
        # `_lineage_next` is the tag the pipeline staged for the very
        # next push; `_pool_tags`/`_archive_tags` ride parallel to the
        # pool and the LOGICAL archive (memory + disk spill, FIFO) so
        # the spill-file format stays unchanged.  Tests that poke
        # batches straight into `pool`/`archive` never see any of this:
        # every tag op is guarded on the tracker and on deque depth.
        self.lineage = None
        self._lineage_next = None
        self._pool_tags: Deque = collections.deque()
        self._archive_tags: Deque = collections.deque()

    # ---- archive (bounded, disk-spilled past max_archive) -----------
    @property
    def archive_depth(self) -> int:
        """Failed batches awaiting replay, memory + disk."""
        return len(self.archive) + len(self._archive_spill)

    @property
    def degraded(self) -> bool:
        """Store considered down: policy attached and the consecutive-
        failure count passed `degrade_after`."""
        return (self.retry_policy is not None
                and self.consecutive_failures >= self.degrade_after)

    def _spill_path(self) -> str:
        if self.archive_dir is None:
            self.archive_dir = tempfile.mkdtemp(prefix="repro_archive_")
        os.makedirs(self.archive_dir, exist_ok=True)
        fn = os.path.join(self.archive_dir,
                          f"archive_{self._archive_n:08d}.pkl")
        self._archive_n += 1
        return fn

    def _archive_put(self, et, tag=None, now: Optional[float] = None,
                     degraded: bool = False) -> None:
        if self.lineage is not None and tag is not None:
            self._archive_tags.append(tag)
            self.lineage.mark_archived(
                tag, now if now is not None else time.time(),
                degraded=degraded)
        self.archived_total += 1
        # keep FIFO across the memory/disk boundary: once anything
        # spilled, later batches must spill too or replay reorders
        if self._archive_spill or len(self.archive) >= self.max_archive:
            fn = self._spill_path()
            with open(fn, "wb") as f:
                pickle.dump(_to_host(et), f, pickle.HIGHEST_PROTOCOL)
            self._archive_spill.append(fn)
            self.telemetry.count("archive.spilled")
        else:
            self.archive.append(et)

    def _archive_refill(self) -> None:
        """Pull spilled batches back into memory headroom, in order."""
        while self._archive_spill and len(self.archive) < self.max_archive:
            fn = self._archive_spill.pop(0)
            with open(fn, "rb") as f:
                self.archive.append(pickle.load(f))
            os.unlink(fn)

    # ------------------------------------------------------------------
    def push(self, et: EdgeTable, now: Optional[float] = None) -> dict:
        """GRAPHPUSH: pool admission + commit.  Returns commit stats."""
        tag, self._lineage_next = self._lineage_next, None
        wall = now if now is not None else time.time()
        if self.retry_policy is not None and self.degraded:
            if wall < self.next_retry_t:
                # degraded mode: the store is down and the backoff gate
                # is closed — preserve the batch without a doomed probe
                self._archive_put(et, tag, now=wall, degraded=True)
                return {"committed": False, "archived": self.archive_depth,
                        "degraded": True}
        if len(self.pool) >= self.max_pool_size:
            if len(self.pool) >= self.pool_cap:
                # hard cap: divert to the archive instead of unbounded
                # pool growth under sustained failure
                self.pool_overflows += 1
                self._archive_put(et, tag, now=wall)
                return {"committed": False, "pooled": len(self.pool),
                        "pool_overflow": self.pool_overflows}
            # pool full: hold in local memory until timeout (paper §III-B)
            self.pool.append(et)
            if self.lineage is not None and tag is not None:
                self._pool_tags.append(tag)
                self.lineage.mark_pooled(tag, wall)
            return {"committed": False, "pooled": len(self.pool)}
        self.pool.append(et)
        if self.lineage is not None and tag is not None:
            self._pool_tags.append(tag)
        stats = {}
        while self.pool:
            batch = self.pool.popleft()
            btag = self._pool_tags.popleft() if self._pool_tags else None
            stats = self._commit(batch, now, tag=btag)
            if not stats["committed"]:
                break
        return stats

    def _commit(self, et: EdgeTable, now: Optional[float],
                archive_on_fail: bool = True, tag=None) -> dict:
        tel = self.telemetry
        wall = now if now is not None else time.time()
        t0 = time.perf_counter()
        self.attempts += 1
        try:
            if self.fail_hook is not None:
                fh = self.fail_hook
                hit = fh(wall) if getattr(fh, "wants_now", False) else fh()
                if hit:
                    raise ConnectionError("injected commit failure")
            compressed = hasattr(et, "residual")
            with tel.span("commit.upsert"):
                if compressed:
                    # pattern-aware path: repro.compress.CompressedCommit
                    new_store, s = commit_compressed(self.store, et)
                else:
                    new_store, s = ingest_step(self.store, et)
            with tel.span("commit.wait"):
                jax.block_until_ready(new_store.n_nodes)
            self.store = new_store
            busy = time.perf_counter() - t0
            tel.observe("commit.total", busy)
            self._busy.append((wall, busy))
            self.consecutive_failures = 0
            self.next_retry_t = float("-inf")
            rec = CommitRecord(
                t=wall,
                busy_s=busy,
                instructions=int(s["instructions"]),
                new_nodes=int(s["new_nodes"]),
                batch_nodes=int(s["batch_nodes"]),
                ok=True,
                probe_rounds=int(s.get("probe_rounds", 0)),
                dropped=int(s.get("dropped_inserts", 0)),
                refs=int(s.get("dict_refs", 0)),
            )
            self.commits.append(rec)
            if self.lineage is not None and tag is not None:
                # store took it: the committed low watermark may advance
                self.lineage.mark_committed(tag, wall)
            with tel.span("commit.hooks"):
                if self.commit_hook is not None:
                    self.commit_hook(et, s)
                for hook in self.commit_hooks:
                    hook(et, s)
            if self.lineage is not None and tag is not None:
                # the hook fan-out (snapshot maintainer absorb + sketch
                # update) has run: queries can now SEE these records —
                # only here does the queryable watermark advance
                self.lineage.mark_queryable(tag, wall)
            rho = rec.new_nodes / max(rec.batch_nodes, 1)
            out = {
                "committed": True,
                "stats": s,
                "busy_s": busy,
                "rho": rho,
                "instructions": rec.instructions,
                # table-pressure signals for the Algorithm-2 controller
                "dropped": rec.dropped,
                "probe_rounds": rec.probe_rounds,
                "pressure": max(float(s.get("node_load", 0.0)),
                                float(s.get("edge_load", 0.0))),
            }
            if "dict_refs" in s:
                # compressibility signals (repro.compress -> controller)
                out["refs"] = rec.refs
                out["dict_hit_rate"] = float(s["dict_hit_rate"])
            return out
        except ConnectionError:
            # commit failed (network/DBMS) -> archive for replay.
            # `wall`, not `now or time.time()`: now=0.0 is falsy, so the
            # old form stamped simulated-clock failures with wall time.
            self.consecutive_failures += 1
            out = {"committed": False}
            if self.retry_policy is not None:
                delay = self.retry_policy.delay(self.consecutive_failures - 1)
                self.next_retry_t = wall + delay
                out["retry_in_s"] = delay
                tel.count("retry.backoff")
                if self.degraded:
                    out["degraded"] = True
            if archive_on_fail:
                self._archive_put(et, tag, now=wall,
                                  degraded=bool(out.get("degraded")))
            self.commits.append(
                CommitRecord(wall, 0.0, 0, 0, 0, ok=False)
            )
            out["archived"] = self.archive_depth
            return out

    # ------------------------------------------------------------------
    def retry_archive(self, now: Optional[float] = None) -> int:
        """Re-commit archived batches (connection restored).  With a
        `RetryPolicy` attached the backoff gate is honoured: while
        `now < next_retry_t` nothing is attempted (no hot-looping);
        one probe failure re-arms the gate with the next delay."""
        if self.retry_policy is not None:
            wall = now if now is not None else time.time()
            if wall < self.next_retry_t:
                return 0
        n = 0
        while self.archive_depth:
            self._archive_refill()
            et = self.archive.popleft()
            tag = None
            if self.lineage is not None and self._archive_tags:
                tag = self._archive_tags.popleft()
                self.lineage.mark_replay(
                    tag, now if now is not None else time.time())
            if self._commit(et, now, archive_on_fail=False,
                            tag=tag)["committed"]:
                n += 1
                self.replayed += 1
                continue
            # failed head returns to the FRONT: replay order is FIFO
            self.archive.appendleft(et)
            if tag is not None:
                self._archive_tags.appendleft(tag)
            break
        if n:
            self.telemetry.count("retry.replayed", n)
        return n

    def occupancy(self, now: float, sim_busy: Optional[float] = None) -> float:
        """mu in [0,1]: ingest busy-fraction over the trailing window."""
        w0 = now - self.occupancy_window
        busy = sum(b for (t, b) in self._busy if t >= w0)
        return min(busy / self.occupancy_window, 1.0)

    def pending_work_s(self) -> float:
        """Estimated seconds of work queued in the pool (system-delay
        alpha for the measured path): pooled batches x mean commit
        cost over the busy window."""
        busy = [b for (_, b) in self._busy]
        mean_busy = sum(busy) / len(busy) if busy else 0.0
        return len(self.pool) * mean_busy

    # ---- checkpoint surface (repro.resilience) -----------------------
    def state(self) -> dict:
        """Everything except `store` (which snapshots as array leaves):
        pool/archive batches as host pytrees, archive spill CONTENTS
        (the files may be gone by restore time), counters, the backoff
        gate, and the fault injector's attempt counter when present."""
        spilled = []
        for fn in self._archive_spill:
            with open(fn, "rb") as f:
                spilled.append(f.read())  # already-pickled host pytree
        fh = self.fail_hook
        return {
            "pool": [_to_host(et) for et in self.pool],
            "archive": [_to_host(et) for et in self.archive],
            "archive_spill": spilled,
            "archive_n": self._archive_n,
            "commits": list(self.commits),
            "busy": list(self._busy),
            "attempts": self.attempts,
            "archived_total": self.archived_total,
            "replayed": self.replayed,
            "pool_overflows": self.pool_overflows,
            "consecutive_failures": self.consecutive_failures,
            "next_retry_t": self.next_retry_t,
            "fail_hook": fh.state() if hasattr(fh, "state") else None,
            "pool_tags": list(self._pool_tags),
            "archive_tags": list(self._archive_tags),
            "lineage_next": self._lineage_next,
        }

    def restore_state(self, s: dict) -> None:
        self.pool = collections.deque(s["pool"])
        self.archive = collections.deque(s["archive"])
        self._archive_spill = []
        self._archive_n = int(s["archive_n"])
        for blob in s["archive_spill"]:
            # rewrite under fresh (still-monotone) names: the original
            # files may live in a dead temp dir or have been drained
            fn = self._spill_path()
            with open(fn, "wb") as f:
                f.write(blob)
            self._archive_spill.append(fn)
        self.commits = list(s["commits"])
        self._busy = collections.deque(s["busy"], maxlen=self._busy.maxlen)
        self.attempts = int(s["attempts"])
        self.archived_total = int(s["archived_total"])
        self.replayed = int(s["replayed"])
        self.pool_overflows = int(s["pool_overflows"])
        self.consecutive_failures = int(s["consecutive_failures"])
        self.next_retry_t = float(s["next_retry_t"])
        if s.get("fail_hook") is not None \
                and hasattr(self.fail_hook, "restore_state"):
            self.fail_hook.restore_state(s["fail_hook"])
        # .get: checkpoints written before lineage landed lack these
        self._pool_tags = collections.deque(s.get("pool_tags", ()))
        self._archive_tags = collections.deque(s.get("archive_tags", ()))
        self._lineage_next = s.get("lineage_next")
