"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
[arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        shared_attn_every=6,
        # in the long_500k shape the shared attention block uses a sliding
        # window so the hybrid stays sub-quadratic (see DESIGN.md §5)
        sliding_window=None,
        tie_embeddings=True,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
