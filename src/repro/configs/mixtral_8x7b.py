"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000.
[arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=0,  # all FFN capacity lives in the experts
        vocab_size=32000,
        num_experts=8,
        num_experts_per_tok=2,
        moe_d_ff=14336,
        sliding_window=4096,
        rope_theta=1_000_000.0,
        # §Perf m8: per-shard-capacity shard_map MoE + mb=4 -> 15.4% of
        # roofline at 9.5 GiB/dev (vs 1.8% / 193 GiB naive-SPMD baseline)
        microbatch_seqs=4,
    )
)
