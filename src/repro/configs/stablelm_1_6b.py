"""stablelm-1.6b [dense] — LayerNorm, MHA (kv=heads).

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="stablelm-1.6b",
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        use_layernorm=True,
        qkv_bias=False,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
