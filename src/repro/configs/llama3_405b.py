"""llama3-405b [dense] — GQA, 128k vocab.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
[arXiv:2407.21783]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        head_dim=128,
        rope_theta=500_000.0,
        # 405B on a 256-chip v5e pod (4 TB HBM): fp32 AdamW state alone is
        # 4.9 TB — provably does not fit (see EXPERIMENTS.md §Dry-run).
        # Production posture: bf16 params + bf16 adam moments + bf16 grad
        # accumulation, microbatch 1.
        param_dtype="bfloat16",
        opt_state_dtype="bfloat16",
        grad_accum_dtype="bfloat16",
        microbatch_seqs=1,  # fits 2-pod HBM exactly (mb2 = +6% frac but 19.2G)
    )
)
