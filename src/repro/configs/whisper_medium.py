"""whisper-medium [audio] — encoder-decoder, conv frontend (STUB).

24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
The conv frontend is a stub: input_specs() supplies precomputed frame
embeddings (batch, enc_seq, d_model).  [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="whisper-medium",
        family="audio",
        num_layers=24,  # decoder layers
        enc_layers=24,
        enc_seq=1500,  # 30 s of audio after the conv2 stride-2 stub
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        use_layernorm=True,
        act="gelu",
        use_rope=False,  # learned absolute positions
        qkv_bias=True,
        # d_model == 1024 collides with the default attention chunk in
        # the score-chain analysis; 512 keeps shapes unambiguous
        attn_chunk=512,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
