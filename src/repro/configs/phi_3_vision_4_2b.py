"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (STUB).

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.  The vision tower is
a stub: input_specs() supplies precomputed patch embeddings
(batch, num_patches, d_model) which the backbone prepends to the token
embeddings.  [hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        num_patches=576,  # CLIP ViT-L/14 @ 336px
        rope_theta=10_000.0,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
