"""mamba2-780m [ssm] — pure Mamba2 (SSD), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        tie_embeddings=True,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
