"""Architecture configs. Importing this package registers every arch."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeSpec,
    all_arch_ids,
    get_config,
    register,
    smoke_config,
)

# one module per assigned architecture (registration side-effect)
from repro.configs import (  # noqa: F401
    zamba2_7b,
    mamba2_780m,
    mixtral_8x7b,
    qwen2_moe_a2_7b,
    llama3_405b,
    qwen2_5_3b,
    stablelm_1_6b,
    qwen3_4b,
    phi_3_vision_4_2b,
    whisper_medium,
    paper_ingest,
)
