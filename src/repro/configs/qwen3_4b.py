"""qwen3-4b [dense] — qk-norm, GQA, head_dim 128.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.
[hf:Qwen/Qwen3 family]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen3-4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=9728,
        vocab_size=151936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
