"""Config system for the repro framework.

Every assigned architecture is a `ModelConfig`; every assigned input
shape is a `ShapeSpec`.  The dry-run, smoke tests, trainers and servers
all consume these.  Configs are plain frozen dataclasses — no jax import
at module scope so that importing a config never touches device state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes (assigned; LM shapes are seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # tokens; None = full attention
    rope_theta: float = 10_000.0
    use_rope: bool = True  # False -> learned absolute positions (whisper)
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style): shared attention+mlp block applied every N layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper-style)
    enc_layers: int = 0
    enc_seq: int = 0  # fixed encoder positions (audio frames after conv stub)

    # modality frontend stubs
    num_patches: int = 0  # vlm: precomputed patch embeddings prepended

    # norm / act
    norm_eps: float = 1e-5
    use_layernorm: bool = False  # False -> RMSNorm
    act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP)

    # numerics / parallel policy knobs (overridable per run)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"  # adam m/v (bf16/int8 for huge models)
    grad_accum_dtype: str = "float32"
    # parallelism profile: "2d" = FSDP(data) x TP(model);
    # "dp" = pure data parallel over BOTH axes + 2D-FSDP params (the
    # right-sizing win for small models -- see EXPERIMENTS.md §Perf)
    sharding_profile: str = "2d"
    remat: str = "full"  # full | dots | none
    microbatch_seqs: int = 0  # per-DP-replica seqs per microbatch; 0 = auto
    attn_chunk: int = 1024  # online-softmax KV block for long sequences
    use_scan_layers: bool = True
    seq_shard_long: bool = True  # shard decode KV length over "model" axis
    attn_full_max: int = 8192  # materialised attention up to this S (2048 = paper-faithful baseline)
    moe_shard_map: bool = True  # per-shard-capacity MoE (False = naive SPMD baseline)

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        # pad for clean sharding over the model axis (16) and MXU lanes
        return round_up(self.vocab_size, 128)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the long_500k shape?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    # ------------- parameter counting (analytic; used for 6ND) -------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _mlp_params(self, d_ff: int) -> int:
        if self.act == "silu":
            return 3 * self.d_model * d_ff  # SwiGLU: wi, wg, wo
        return 2 * self.d_model * d_ff

    def _mamba_params(self) -> int:
        d_in = self.ssm_d_inner
        nh = self.ssm_heads
        # in_proj -> [x, z, B, C, dt]; out_proj; conv; A,D, dt_bias, norm
        in_proj = self.d_model * (2 * d_in + 2 * self.ssm_state + nh)
        out_proj = d_in * self.d_model
        conv = self.ssm_conv * (d_in + 2 * self.ssm_state)
        small = 3 * nh + d_in
        return in_proj + out_proj + conv + small

    def layer_params(self) -> Tuple[int, int]:
        """(total_per_layer, active_per_layer) for one decoder layer."""
        if self.family == "ssm":
            p = self._mamba_params() + self.d_model
            return p, p
        attn = self._attn_params() + self.d_model  # + norm
        if self.num_experts:
            router = self.d_model * self.num_experts
            experts = self.num_experts * self._mlp_params(self.moe_d_ff)
            shared = self._mlp_params(self.shared_d_ff) if self.shared_d_ff else 0
            total = attn + router + experts + shared + self.d_model
            active = (
                attn
                + router
                + self.num_experts_per_tok * self._mlp_params(self.moe_d_ff)
                + shared
                + self.d_model
            )
            return total, active
        mlp = self._mlp_params(self.d_ff) + self.d_model
        return attn + mlp, attn + mlp

    def param_count(self) -> Tuple[int, int]:
        """(total, active) parameters, embeddings included once."""
        emb = self.padded_vocab * self.d_model
        head = 0 if self.tie_embeddings else self.padded_vocab * self.d_model
        total = emb + head + self.d_model  # final norm

        if self.family == "hybrid":
            per, _ = ModelConfig.layer_params(
                dataclasses.replace(self, family="ssm")
            )
            total += self.num_layers * per
            # one shared attention+mlp block (weights reused every Nth layer)
            shared_blk = self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
            total += shared_blk
            return total, total

        per, act = self.layer_params()
        n_dec = self.num_layers
        total += n_dec * per
        active = emb + head + self.d_model + n_dec * act

        if self.is_encdec:
            # encoder layers (self-attn + mlp) + decoder cross-attn additions
            enc_per = self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
            total += self.enc_layers * enc_per
            cross = self.num_layers * (self._attn_params() + self.d_model)
            total += cross
            active = total
        return total, active

    def flops_per_token(self) -> Tuple[int, int]:
        """(6*N_total, 6*N_active) matmul FLOPs per trained token."""
        t, a = self.param_count()
        return 6 * t, 6 * a

    def auto_microbatch(self, shape: ShapeSpec, dp: int) -> int:
        """Sequences per microbatch per DP replica, bounded by activation heuristic."""
        if self.microbatch_seqs:
            return self.microbatch_seqs
        per_dp = max(1, shape.global_batch // dp)
        # heuristic activation budget: ~2 GiB of checkpointed layer inputs
        layer_bytes_per_seq = (
            (self.num_layers + self.enc_layers) * shape.seq_len * self.d_model * 2
        )
        budget = 2 * (1 << 30) * 16  # assume /16 model-axis seq sharding
        mb = max(1, min(per_dp, budget // max(layer_bytes_per_seq, 1)))
        # power of two <= mb that divides per_dp
        while per_dp % mb:
            mb -= 1
        return max(1, mb)


_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        # late import of the module defining it
        import importlib

        mod = arch_id.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch_id]


def all_arch_ids():
    from repro import configs  # noqa: F401  (triggers registration)

    return sorted(_REGISTRY.keys())


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)) if cfg.num_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        microbatch_seqs=2,
        remat="none",
        attn_chunk=32,
    )
    if cfg.num_experts:
        # capacity_factor=E/K makes the smoke MoE dropless -> deterministic
        # prefill/forward equivalence in tests
        kw.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=64, capacity_factor=2.0)
        if cfg.num_shared_experts:
            kw.update(num_shared_experts=1, shared_d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.shared_attn_every:
        kw.update(shared_attn_every=2)
    if cfg.is_encdec:
        kw.update(enc_layers=2, enc_seq=32)
    if cfg.num_patches:
        kw.update(num_patches=8)
    return dataclasses.replace(cfg, **kw)
