"""Paper scenario config — the ingestion pipeline itself (§II–§IV).

Not an LM architecture: these are the knobs of the adaptive buffer
controller and graph-compression pipeline, set to the paper's testbed
values where the paper states them.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    # buffer bounds (records)
    beta_min: int = 200
    beta_max: int = 50_000
    beta_init: int = 1_500  # paper Fig. 12: "initial buffer size 1500 records"

    # consumer-load bounds (fraction of capacity, paper uses CPU %)
    cpu_max: float = 0.55  # paper tests 35% and 55%
    cpu_min: float = 0.10
    theta1: float = 0.10  # buffer growth fraction
    theta2: float = 0.25  # throttle threshold factor / shrink fraction

    # predictive-model seeds (paper §IV-A); refined online by RLS
    K: float = 0.597  # linear coefficient of phi1(rho)
    R: float = 1.48  # coefficient of phi2(d) (quadratic)
    A: float = 0.01  # mu[n-1] coefficient
    B: float = 0.09  # log(beta_e) coefficient

    # bucketing
    bucket_records: int = 256  # mini-batch ("bucket") size B[i]
    diversity_window: int = 8  # k temporal buckets for rho

    # device-side table capacities (per ingest step)
    max_edges_per_batch: int = 8_192
    max_nodes_per_batch: int = 8_192

    # graph store capacity
    store_nodes: int = 1 << 20
    store_edges: int = 1 << 21

    # stream shape
    mean_rate: float = 60.0  # records/s (paper: ~60 tweets/s at 1%)
    burst_multiplier: float = 5.0  # paper simulation: up to 5x
    duplicate_frac: float = 0.125  # paper: 5–20% duplicate tweets


DEFAULT = IngestConfig()
