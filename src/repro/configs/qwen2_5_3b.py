"""qwen2.5-3b [dense] — GQA kv=2, QKV bias, tied embeddings.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
[hf:Qwen/Qwen2.5-3B family]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen2.5-3b",
        family="dense",
        num_layers=36,
        d_model=2048,
        num_heads=16,
        num_kv_heads=2,
        d_ff=11008,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
