"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4x shared expert.

24L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=0,
        vocab_size=151936,
        num_experts=60,
        num_experts_per_tok=4,
        moe_d_ff=1408,
        num_shared_experts=4,
        shared_d_ff=5632,  # 4 x 1408 fused shared expert
        qkv_bias=True,
        rope_theta=1_000_000.0,
        # right-sized parallelism: pure DP + 2D-FSDP beats 16-way TP for
        # this scale (EXPERIMENTS.md §Perf q2: -87%% collective bytes)
        sharding_profile="dp",
    )
)
