"""Pallas TPU kernel: Mamba2 SSD chunked scan (fwd).

Grid (batch*heads, nchunks) with the chunk axis sequential; the
inter-chunk recurrent state (N x p) lives in VMEM scratch across chunk
iterations.  Within a chunk everything is matmuls (MXU):

  seg   = LT1 @ dA          (cumsum as lower-triangular ones matmul)
  G     = C @ B^T           (Q x Q)
  y_in  = (G * L) @ (dt*x)  intra-chunk
  y_out = C @ (exp(seg) * state)  inter-chunk carry-in
  state = exp(total) * state + B^T @ (w * x)

Oracle: repro.models.mamba2.ssd_chunked (pure jnp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref,
                *, Q: int, N: int, p: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (Q, p)
    dt = dt_ref[0].astype(jnp.float32)  # (Q,)
    A = a_ref[0].astype(jnp.float32)  # scalar (1,)
    B = b_ref[0].astype(jnp.float32)  # (Q, N)
    C = c_ref[0].astype(jnp.float32)  # (Q, N)

    dA = dt * A  # (Q,) negative
    lt1 = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    seg = jnp.dot(lt1, dA, preferred_element_type=jnp.float32)  # cumsum
    total = seg[Q - 1]

    # intra-chunk
    li = seg[:, None] - seg[None, :]
    mask = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    L = jnp.exp(jnp.where(mask, li, -1e30)) * dt[None, :]
    G = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    y_intra = jnp.dot(G * L, x, preferred_element_type=jnp.float32)

    # inter-chunk carry-in
    h = state_ref[...]  # (N, p)
    y_inter = jnp.exp(seg)[:, None] * jnp.dot(C, h, preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    w = jnp.exp(total - seg) * dt  # (Q,)
    upd = jnp.dot(B.T, w[:, None] * x, preferred_element_type=jnp.float32)  # (N,p)
    state_ref[...] = jnp.exp(total) * h + upd

    @pl.when(ci == nc - 1)
    def _done():
        st_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,   # (BH, S, p)   per-head inputs, batch*heads flattened
    dt: jax.Array,  # (BH, S)      positive step sizes
    A: jax.Array,   # (BH,)        negative decay rate per (batch,head)
    B: jax.Array,   # (BH, S, N)
    C: jax.Array,   # (BH, S, N)
    chunk: int = 128,
    interpret: bool = True,
):
    BH, S, p = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    kern = functools.partial(_ssd_kernel, Q=Q, N=N, p=p, nc=nc)
    return pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q), lambda b, c: (b, c)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, p), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, p), x.dtype),
            jax.ShapeDtypeStruct((BH, N, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, B, C)
