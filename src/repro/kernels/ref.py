"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

The fused-upsert oracle lives next to its kernel (they share the probe
sweep body so they cannot drift) and is re-exported here:
`fused_upsert_ref`.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sampler import traffic_ids_ref  # noqa: F401 (re-export)
from repro.kernels.upsert import fused_upsert_ref  # noqa: F401 (re-export)

# ---------------------------------------------------------------------------
# edge_dedup oracle
# ---------------------------------------------------------------------------


def sort_dedup_ref(keys: jax.Array):
    """Returns (sorted_keys, order, head_flags) — jnp sort + shift-compare."""
    order = jnp.argsort(keys, stable=True)
    sk = keys[order]
    prev = jnp.concatenate([sk[:1] ^ jnp.uint32(0xFFFFFFFF), sk[:-1]])
    head = (sk != prev).astype(jnp.int32)
    return sk, order.astype(jnp.int32), head


# ---------------------------------------------------------------------------
# bloom oracle
# ---------------------------------------------------------------------------


def _hash_round_ref(keys, r):
    c1 = np.uint32((0x9E3779B9 + 0x7F4A7C15 * r) & 0xFFFFFFFF)
    c2 = np.uint32(0x85EBCA6B)
    with np.errstate(over="ignore"):
        x = (np.asarray(keys, np.uint32) + c1) * c2
        x = x ^ (x >> np.uint32(13))
        x = x * np.uint32(0xC2B2AE35)
        return x ^ (x >> np.uint32(16))


def bloom_build_ref(keys, bitmap, hashes: int = 4):
    bm = np.asarray(bitmap, np.uint32).copy().reshape(-1)
    words = bm.shape[0]
    for r in range(hashes):
        h = _hash_round_ref(keys, r)
        w = (h >> np.uint32(5)) % np.uint32(words)
        b = h % np.uint32(32)
        for wi, bi in zip(w, b):
            bm[int(wi)] |= np.uint32(1) << np.uint32(bi)
    return bm.reshape(np.asarray(bitmap).shape)


def bloom_probe_ref(keys, bitmap, hashes: int = 4):
    bm = np.asarray(bitmap, np.uint32).reshape(-1)
    words = bm.shape[0]
    hit = np.ones(len(keys), np.int32)
    for r in range(hashes):
        h = _hash_round_ref(keys, r)
        w = (h >> np.uint32(5)) % np.uint32(words)
        b = h % np.uint32(32)
        hit &= ((bm[w] >> b) & np.uint32(1)).astype(np.int32)
    return hit


# ---------------------------------------------------------------------------
# flash attention oracle (naive, materialised scores)
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, causal=True, window: Optional[int] = None):
    BH, S, d = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# ssd_scan oracle — wraps the model's chunked SSD (itself validated
# against a brute-force recurrence in tests)
# ---------------------------------------------------------------------------


def ssd_scan_ref(x, dt, A, B, C, chunk: int = 128):
    """x (BH,S,p), dt (BH,S), A (BH,), B/C (BH,S,N) -> (y, final_state).

    Brute-force sequential recurrence (the definition):
      h[t] = exp(dt[t] A) h[t-1] + dt[t] B[t] x[t]^T ;  y[t] = C[t]^T h[t]
    """
    BH, S, p = x.shape
    N = B.shape[-1]
    f32 = jnp.float32

    def per_bh(x1, dt1, a1, b1, c1):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(dtt * a1) * h + dtt * jnp.outer(bt, xt)
            return h, ct @ h

        h0 = jnp.zeros((N, p), f32)
        hT, ys = jax.lax.scan(
            step, h0, (x1.astype(f32), dt1.astype(f32), b1.astype(f32), c1.astype(f32))
        )
        return ys, hT

    ys, hT = jax.vmap(per_bh)(x, dt, A.astype(f32), B, C)
    return ys.astype(x.dtype), hT
