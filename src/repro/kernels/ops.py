"""Public jit'd wrappers over the Pallas kernels.

On this CPU container every kernel runs through the Pallas interpreter
(`interpret=True`, the validation mode); on a real TPU the same call
sites compile the Mosaic kernels (`interpret=False`).  `ON_TPU` flips
the default.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import bloom as _bloom
from repro.kernels import edge_dedup as _dedup
from repro.kernels import flash_attention as _flash
from repro.kernels import pattern_mine as _mine
from repro.kernels import sampler as _sampler
from repro.kernels import sketch as _sketch
from repro.kernels import ssd_scan as _ssd
from repro.kernels import upsert as _upsert

ON_TPU = any(d.platform == "tpu" for d in jax.devices())
_INTERP = not ON_TPU


def sort_dedup(keys: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(sorted, order, head) for power-of-two uint32 key vectors."""
    return _dedup.sort_dedup(keys, interpret=_INTERP)


def dedup_sorted_counts(sorted_keys: jax.Array, head: jax.Array):
    """Per-run counts from the kernel's (sorted, head) output."""
    n = sorted_keys.shape[0]
    run = jnp.cumsum(head) - 1
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), jnp.clip(run, 0, n - 1), num_segments=n)
    n_unique = head.sum()
    return counts, n_unique


def bloom_build(keys: jax.Array, bitmap: jax.Array) -> jax.Array:
    return _bloom.bloom_build(keys, bitmap, interpret=_INTERP)


def bloom_probe(keys: jax.Array, bitmap: jax.Array) -> jax.Array:
    return _bloom.bloom_probe(keys, bitmap, interpret=_INTERP)


def bloom_diversity(keys: jax.Array, bitmap: jax.Array):
    """(rho, new_bitmap): fraction of unseen keys + updated filter —
    the pre-commit diversity signal for the buffer controller."""
    hit = bloom_probe(keys, bitmap)
    rho = 1.0 - hit.mean(dtype=jnp.float32)
    return rho, bloom_build(keys, bitmap)


def pattern_mine(src, dst, etype, count, valid, star_min, hot_min,
                 use_kernel=None):
    """Frequent-substructure mining over a dedup'd batch (GraphZip
    front-end, repro.compress): (fan_out, fan_in, flags, psig) per
    edge.  The jnp oracle is the fast path off-TPU."""
    use_kernel = ON_TPU if use_kernel is None else use_kernel
    if use_kernel:
        return _mine.pattern_mine(src, dst, etype, count, valid,
                                  star_min, hot_min, interpret=_INTERP)
    return _mine.pattern_mine_ref(src, dst, etype, count, valid,
                                  star_min, hot_min)


def fused_upsert(table_keys, keys, valid, n_probes, use_kernel=None):
    """Fused lookup-or-insert (GRAPHPUSH commit hot path): one probe
    sweep per table instead of lookup-then-insert.  Returns
    (table_keys', slot (-1 = dropped), is_new).  The jnp oracle is the
    fast path off-TPU (interpret-mode Pallas is validation-only)."""
    use_kernel = ON_TPU if use_kernel is None else use_kernel
    if use_kernel:
        return _upsert.fused_upsert(table_keys, keys, valid, n_probes,
                                    interpret=_INTERP)
    return _upsert.fused_upsert_ref(table_keys, keys, valid, n_probes)


def traffic_sample(seed, ctr0, n: int, iparams, fparams, use_kernel=None):
    """Counter-based traffic-id block for the workload generator
    (repro.workloads): (uid, tag, mention, u_dup, u_dupi).  One fused
    sampling launch per block; deterministic in (seed, ctr0)."""
    use_kernel = ON_TPU if use_kernel is None else use_kernel
    if use_kernel:
        return _sampler.traffic_ids(seed, ctr0, n, iparams, fparams,
                                    interpret=_INTERP)
    return _sampler.traffic_ids_ref(seed, ctr0, n, iparams, fparams)


def sketch_scatter(edge_w, out_deg, in_deg, r, c, cnt):
    """Graph-sketch scatter-add hot path (repro.query.sketch)."""
    return _sketch.sketch_scatter(edge_w, out_deg, in_deg, r, c, cnt,
                                  interpret=_INTERP)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True, window: Optional[int] = None,
    block_q: int = 512, block_k: int = 512,
) -> jax.Array:
    """(BH,S,d) attention; MQA/GQA callers broadcast KV beforehand."""
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_INTERP,
    )


def ssd_scan(x, dt, A, B, C, chunk: int = 128):
    """(y, final_state) Mamba2 SSD over (BH,S,*) inputs."""
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=_INTERP)
