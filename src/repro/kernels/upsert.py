"""Pallas TPU kernel: fused lookup-or-insert for the open-addressing
graph-store tables (Algorithm 3 GRAPHPUSH commit hot path).

The seed committed a batch with a *lookup* sweep followed by an
*insert* sweep per table (plus two more lookups for degree updates) —
six MAX_PROBES-round gather/scatter loops per commit.  This kernel
fuses lookup-or-insert into ONE probe sweep per table: at each probe
round a lane either hits its key (slot found, not new), claims an
empty slot (scatter-max race, winners check back — slot found, new),
or keeps probing.  Because slots are never freed, a present key is
always hit before the first empty slot of its probe sequence, so the
fused sweep is bit-identical to lookup-then-insert.

The probe budget is *dynamic* (a traced scalar): the caller doubles it
as the table load factor grows (adaptive probing, ROADMAP "store
probing robustness"), so the loop is a `while` with a data-dependent
trip count rather than a statically unrolled scan.

`upsert_sweep` is the pure body shared verbatim by the Pallas kernel
and the jnp oracle `fused_upsert_ref` (repro.kernels.ref style), so
the two can never drift; tests assert bit-exactness anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def probe_hash(keys: jax.Array, cap: int, i: jax.Array) -> jax.Array:
    """Linear-probing slot for `keys` at probe round `i` (splitmix mix)."""
    kd = keys.dtype
    c = jnp.asarray(0x9E3779B97F4A7C15 if kd == jnp.uint64 else 0x9E3779B9, kd)
    h = keys * c
    h = h ^ (h >> 16)
    return ((h.astype(jnp.uint32) + i.astype(jnp.uint32)) % jnp.uint32(cap)).astype(jnp.int32)


def upsert_sweep(table_keys: jax.Array, keys: jax.Array, valid: jax.Array,
                 n_probes: jax.Array):
    """Single-pass fused upsert of UNIQUE keys (pre-deduplicated batch).

    Returns (table_keys', slot (int32, -1 = dropped), is_new (bool)).
    `n_probes` may be a traced scalar (adaptive probe budget).  Races
    for empty slots resolve by scatter-max; losers keep probing.
    """
    cap = table_keys.shape[0]
    n = keys.shape[0]

    def body(i, carry):
        tk, slot, is_new, done = carry
        cand = probe_hash(keys, cap, jnp.full((n,), i, jnp.int32))
        cur = tk[cand]
        hit = (cur == keys) & valid & ~done
        empty = (cur == 0) & valid & ~done
        tk = tk.at[jnp.where(empty, cand, cap)].max(keys, mode="drop")
        won = empty & (tk[cand] == keys)
        placed = hit | won
        slot = jnp.where(placed, cand, slot)
        is_new = is_new | won
        done = done | placed
        return tk, slot, is_new, done

    tk, slot, is_new, _ = jax.lax.fori_loop(
        0, n_probes, body,
        (table_keys, jnp.full((n,), -1, jnp.int32), jnp.zeros((n,), bool), ~valid))
    return tk, slot, is_new


@jax.jit
def fused_upsert_ref(table_keys: jax.Array, keys: jax.Array, valid: jax.Array,
                     n_probes: jax.Array):
    """jnp oracle (and the CPU hot path — interpret-mode Pallas is the
    validation path, not the fast path; see repro.kernels.ops)."""
    return upsert_sweep(table_keys, keys, valid,
                        jnp.asarray(n_probes, jnp.int32))


def _upsert_kernel(probes_ref, table_ref, keys_ref, valid_ref,
                   table_out, slot_out, new_out):
    tk, slot, is_new = upsert_sweep(
        table_ref[...], keys_ref[...], valid_ref[...] != 0, probes_ref[0])
    table_out[...] = tk
    slot_out[...] = slot
    new_out[...] = is_new.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_upsert(table_keys: jax.Array, keys: jax.Array, valid: jax.Array,
                 n_probes: jax.Array, interpret: bool = True):
    """Fused upsert through the Pallas kernel.

    table_keys (cap,) key dtype (0 = empty); keys (n,) unique batch;
    valid (n,) bool; n_probes scalar int32 (dynamic probe budget).
    Returns (table_keys', slot (int32, -1 = dropped), is_new (bool)).
    VMEM budget: table + batch keys resident (4 MB at cap = 1M uint32).
    """
    cap = table_keys.shape[0]
    n = keys.shape[0]
    probes = jnp.asarray(n_probes, jnp.int32).reshape(1)
    tk, slot, new_i = pl.pallas_call(
        _upsert_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap,), table_keys.dtype),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(probes, table_keys, keys, valid.astype(jnp.int32))
    return tk, slot, new_i != 0
