"""Pallas TPU kernel: bitonic sort + run-head marking for edge dedup.

The ingestion hot spot (Algorithm 1's INSERTEDGE dedup) adapted to the
TPU: instead of the paper's serial hash map, keys are sorted in VMEM by
a bitonic network (log^2 n compare-exchange stages, pure VPU min/max on
(n/2j, 2, j)-reshaped vectors — no data-dependent control flow), then
run heads are marked by a shifted comparison.  Segment counting runs in
XLA afterwards (repro.kernels.ops.dedup_sorted_counts) where
segment-sum is already optimal.

VMEM budget: one uint32 key vector + one index vector; n <= 65536 keys
per block (512 KiB) — far below the ~16 MiB VMEM of a v5e core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_stage(x: jax.Array, idx: jax.Array, k: int, j: int):
    """One compare-exchange stage on (x, idx) (keys + payload indices)."""
    n = x.shape[0]
    xr = x.reshape(n // (2 * j), 2, j)
    ir = idx.reshape(n // (2 * j), 2, j)
    a, b = xr[:, 0, :], xr[:, 1, :]
    ia, ib = ir[:, 0, :], ir[:, 1, :]
    # ascending iff bit k of the element's position is 0
    pos = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), j), 0) * (2 * j) + \
        jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), j), 1)
    asc = (pos & k) == 0
    swap = jnp.where(asc, a > b, a < b)
    na = jnp.where(swap, b, a)
    nb = jnp.where(swap, a, b)
    nia = jnp.where(swap, ib, ia)
    nib = jnp.where(swap, ia, ib)
    x = jnp.stack([na, nb], axis=1).reshape(n)
    idx = jnp.stack([nia, nib], axis=1).reshape(n)
    return x, idx


def _dedup_kernel(keys_ref, sorted_ref, order_ref, head_ref, *, n: int):
    x = keys_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x, idx = _bitonic_stage(x, idx, k, j)
            j //= 2
        k *= 2
    sorted_ref[...] = x
    order_ref[...] = idx
    # run heads: first occurrence of each key value
    prev = jnp.concatenate([x[:1] ^ jnp.uint32(0xFFFFFFFF), x[:-1]])
    head_ref[...] = (x != prev).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_dedup(keys: jax.Array, interpret: bool = True):
    """keys: (n,) uint32, n a power of two.
    Returns (sorted_keys, order, head_flags)."""
    n = keys.shape[0]
    assert n & (n - 1) == 0, f"n must be a power of two, got {n}"
    kern = functools.partial(_dedup_kernel, n=n)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(keys)
