"""Pallas TPU kernel: counter-based traffic-id sampling (repro.workloads).

The scenario generator must synthesise hundreds of thousands of
records per second without stealing cycles from the ingest hot path,
so the per-record id sampling — Zipf heavy-hitter user picks, hot-
topic/long-tail hashtag mixing, and retweet-cascade mention targets —
is one fused, stateless kernel launch per block.  Statelessness is
the point: every lane derives its randomness from a *counter-based*
PRNG (murmur3/lowbias32 finaliser over (seed, lane counter)), so a
block of n records is a pure function of (seed, ctr0) — reproducible
across hosts, shards and re-runs, with no RNG state to thread.

Per lane the kernel draws disjoint counter substreams and produces:
  * `uid`     — Zipf(a_user) rank over n_users (bounded-Pareto inverse
    CDF: the heavy-hitter user skew of real social streams),
  * `tag`     — with probability `burst_frac` a hot-topic hashtag
    (one of `burst_ntags` ids at `topic_base`, the #ReleaseTheMemo
    effect: diversity collapses exactly when volume spikes), else a
    Zipf(a_tag) rank over n_tags,
  * `mention` — with probability `copy_frac` the author of a uniformly
    chosen *earlier record in the block* (the copy-model approximation
    of preferential attachment: retweet cascades re-mention whoever is
    already active), else a Zipf(a_mention) celebrity pick,
  * `u_dup`/`u_dupi` — spare uniforms the host-side source uses for
    duplicate-tweet decisions (kept in-kernel so duplicates are also
    counter-deterministic).

`traffic_body` is the pure body shared verbatim by the Pallas kernel
and the jnp oracle `traffic_ids_ref` (repro.kernels idiom), so the
two are bit-exact by construction; tests assert it anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# one record consumes NSTREAMS consecutive counter lanes (6 used, 2
# reserved) so blocks advance the counter by n * NSTREAMS
NSTREAMS = 8


def _fmix32(x: jax.Array) -> jax.Array:
    """lowbias32 finaliser: bijective uint32 mix with full avalanche."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def counter_mix(seed: jax.Array, ctr: jax.Array) -> jax.Array:
    """Counter-based PRNG draw: two lowbias32 rounds keyed by the seed.

    The seed is diffused into a key `k = fmix(seed)` that enters both
    before and after the first diffusion round (`fmix(fmix(ctr + k) ^
    k)`), so different seeds are genuinely independent streams — a
    mere additive or XOR pre-mix would make seed s and seed s + d
    produce counter-shifted copies of one sequence.  Pure uint32 ->
    uint32; equal (seed, ctr) gives identical bits."""
    k = _fmix32(jnp.asarray(seed, jnp.uint32))
    x = _fmix32(ctr.astype(jnp.uint32) + k)
    return _fmix32(x ^ k)


def uniform01(bits: jax.Array) -> jax.Array:
    """uint32 bits -> float32 uniforms in [0, 1) (24-bit mantissa)."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def zipf_rank(u: jax.Array, n, a) -> jax.Array:
    """Approximate Zipf(a) ranks in [0, n) via the bounded-Pareto
    inverse CDF on [1, n+1): F^-1(u) = (1 + u((n+1)^(1-a) - 1))^(1/(1-a)).

    Exact for the continuous power law, rank-faithful for the discrete
    Zipf at the skews social streams show (a in ~[1.05, 3]; a must not
    be 1, the harmonic pole)."""
    nf = jnp.asarray(n, jnp.float32)
    af = jnp.asarray(a, jnp.float32)
    one_m_a = 1.0 - af
    top = jnp.power(nf + 1.0, one_m_a) - 1.0
    x = jnp.power(1.0 + u * top, 1.0 / one_m_a)
    return jnp.clip(x.astype(jnp.int32) - 1, 0, jnp.asarray(n, jnp.int32) - 1)


def traffic_body(lanes, pos, seed, n_users, n_tags, burst_ntags, topic_base,
                 a_user, a_tag, a_mention, burst_frac, copy_frac):
    """The shared sampling body (see module docstring).

    lanes (n,) uint32 — base counters, stride NSTREAMS per record;
    pos (n,) int32 — record position within the block (cascade index).
    Returns (uid, tag, mention) int32 and (u_dup, u_dupi) float32.
    """
    u = lambda s: uniform01(counter_mix(seed, lanes + jnp.uint32(s)))
    u_uid, u_tag, u_mix = u(0), u(1), u(2)
    u_cas, u_src, u_men = u(3), u(4), u(5)

    uid = zipf_rank(u_uid, n_users, a_user)
    hot = (jnp.asarray(topic_base, jnp.int32)
           + (u_tag * jnp.asarray(burst_ntags, jnp.float32)).astype(jnp.int32)
           ) % jnp.asarray(n_tags, jnp.int32)
    tag = jnp.where(u_mix < burst_frac, hot, zipf_rank(u_tag, n_tags, a_tag))
    # retweet cascade: copy the author of an earlier record in-block
    j = (u_src * pos.astype(jnp.float32)).astype(jnp.int32)
    use_copy = (u_cas < copy_frac) & (pos > 0)
    mention = jnp.where(use_copy, uid[j],
                        zipf_rank(u_men, n_users, a_mention))
    return uid, tag, mention, u(6), u(7)


def _lanes(ctr0, n: int):
    """Base counter + block position for n records."""
    pos = jnp.arange(n, dtype=jnp.int32)
    lanes = jnp.asarray(ctr0, jnp.uint32) + pos.astype(jnp.uint32) * jnp.uint32(NSTREAMS)
    return lanes, pos


@functools.partial(jax.jit, static_argnames=("n",))
def traffic_ids_ref(seed, ctr0, n: int, iparams, fparams):
    """jnp oracle (and the CPU fast path — interpret-mode Pallas is the
    validation path, not the fast path; see repro.kernels.ops).

    iparams (4,) int32: n_users, n_tags, burst_ntags, topic_base;
    fparams (5,) float32: a_user, a_tag, a_mention, burst_frac, copy_frac.
    """
    lanes, pos = _lanes(ctr0, n)
    return traffic_body(lanes, pos, jnp.asarray(seed, jnp.uint32),
                        iparams[0], iparams[1], iparams[2], iparams[3],
                        fparams[0], fparams[1], fparams[2], fparams[3],
                        fparams[4])


def _traffic_kernel(seed_ref, ip_ref, fp_ref, lanes_ref, pos_ref,
                    uid_out, tag_out, men_out, dup_out, dupi_out):
    uid, tag, men, u_dup, u_dupi = traffic_body(
        lanes_ref[...], pos_ref[...], seed_ref[0],
        ip_ref[0], ip_ref[1], ip_ref[2], ip_ref[3],
        fp_ref[0], fp_ref[1], fp_ref[2], fp_ref[3], fp_ref[4])
    uid_out[...] = uid
    tag_out[...] = tag
    men_out[...] = men
    dup_out[...] = u_dup
    dupi_out[...] = u_dupi


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def traffic_ids(seed, ctr0, n: int, iparams, fparams, interpret: bool = True):
    """Fused traffic-id sampling through the Pallas kernel.

    Same contract as `traffic_ids_ref`; one launch per block, all
    operands VMEM-resident (6n uniforms + 5n outputs: ~90 KB at the
    default n=2048 block)."""
    lanes, pos = _lanes(ctr0, n)
    seed_a = jnp.asarray(seed, jnp.uint32).reshape(1)
    return pl.pallas_call(
        _traffic_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(seed_a, jnp.asarray(iparams, jnp.int32), jnp.asarray(fparams, jnp.float32),
      lanes, pos)
