"""Pallas TPU kernel: blocked Bloom filter build + probe.

Powers the bucket-diversity ratio rho (§III-A): "proportion of new
nodes in the bucket" = fraction of node keys NOT present in the filter
of previously-seen nodes.  The exact store lookup gives the same signal
at commit time; the Bloom probe gives it *before* commit, which is what
the controller needs to size the buffer ahead of the push.

Layout: the filter is a (W, 1024) uint32 bitmap (1024 VPU lanes per
row; W*1024 words = W*32768 bits).  Each key sets/tests HASHES bits from
independent splitmix rounds.  Scatter-OR is realised as 32 per-bit
scatter-max passes (no data races, static unroll — TPU friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HASHES = 4
LANES = 1024


def _hash_round(keys: jax.Array, r: int) -> jax.Array:
    c1 = jnp.uint32((0x9E3779B9 + 0x7F4A7C15 * r) & 0xFFFFFFFF)
    c2 = jnp.uint32(0x85EBCA6B)
    x = (keys + c1) * c2
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> 16)


def _bit_coords(keys: jax.Array, r: int, words: int):
    h = _hash_round(keys, r)
    word = ((h >> jnp.uint32(5)) % jnp.uint32(words)).astype(jnp.int32)
    bit = (h % jnp.uint32(32)).astype(jnp.int32)
    return word, bit


def _probe_kernel(keys_ref, bitmap_ref, hit_ref, *, words: int):
    keys = keys_ref[...]
    n = keys.shape[0]
    flat = bitmap_ref[...].reshape(-1)
    hit = jnp.ones((n,), jnp.int32)
    for r in range(HASHES):
        w, b = _bit_coords(keys, r, words)
        vals = flat[w]
        hit = hit & ((vals >> b.astype(jnp.uint32)) & jnp.uint32(1)).astype(jnp.int32)
    hit_ref[...] = hit


def _build_kernel(keys_ref, bitmap_in_ref, bitmap_ref, *, words: int):
    keys = keys_ref[...]
    flat = bitmap_in_ref[...].reshape(-1)
    for r in range(HASHES):
        w, b = _bit_coords(keys, r, words)
        # scatter-OR as 32 collision-free scatter-max passes
        for bit in range(32):
            sel = b == bit
            tgt = jnp.where(sel, w, words)  # out-of-range -> dropped
            upd = jnp.zeros_like(flat).at[tgt].max(
                jnp.uint32(1 << bit), mode="drop"
            )
            flat = flat | upd
    bitmap_ref[...] = flat.reshape(bitmap_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bloom_probe(keys: jax.Array, bitmap: jax.Array, interpret: bool = True):
    """keys (n,) uint32; bitmap (W, LANES) uint32. Returns hit mask (n,)."""
    n = keys.shape[0]
    W = bitmap.shape[0]
    words = W * LANES
    kern = functools.partial(_probe_kernel, words=words)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((W, LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(keys, bitmap)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bloom_build(keys: jax.Array, bitmap: jax.Array, interpret: bool = True):
    """Insert keys; returns the updated bitmap."""
    n = keys.shape[0]
    W = bitmap.shape[0]
    words = W * LANES
    kern = functools.partial(_build_kernel, words=words)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((W, LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((W, LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((W, LANES), jnp.uint32),
        interpret=interpret,
    )(keys, bitmap)


def init_bitmap(rows: int = 64) -> jax.Array:
    return jnp.zeros((rows, LANES), jnp.uint32)
