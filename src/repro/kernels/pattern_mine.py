"""Pallas TPU kernel: per-batch frequent-substructure mining (GraphZip).

GraphZip (Packer & Holder, arXiv:1703.08614) grows a dictionary of
frequent substructures and emits compact references instead of raw
edges.  The serial algorithm extends candidate subgraphs one edge at a
time; here mining is recast as three sorted-vector problems over the
dedup'd batch, so it vectorises on the VPU exactly like the dedup and
upsert kernels:

  star bursts    fan_out[e] = |{f : (src, etype) equal}|  (hub fan-out)
                 fan_in[e]  = |{f : (dst, etype) equal}|  (hub fan-in)
  cascade chains dst[e] appears as a source elsewhere in the batch
                 (retweet-of-retweet relay nodes)
  hot edges      within-batch multiplicity >= hot_min

Each admitted edge carries a *pattern signature* (the hub or relay
identity mixed with a pattern tag) that the dictionary keeps for
lineage.  The classification itself — binary searches over the three
sorted vectors plus flag logic — is the pure body `mine_body`, shared
verbatim by the Pallas kernel and the jnp oracle.  Only the sort
primitive differs (bitonic network in-kernel, `jnp.sort` in the
oracle); both produce the identical sorted *values*, so the outputs
are bit-exact either way and tests assert it.

VMEM budget: six n-vectors resident; n <= 65536 per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compression import mix_keys, sentinel_for

# pattern-signature tags (the "pattern class" half of a dictionary key)
TAG_STAR_OUT = 0xA1
TAG_STAR_IN = 0xA2
TAG_CHAIN = 0xA3
TAG_HOT = 0xA4

# admit-flag bits returned per edge
FLAG_STAR_OUT = 1
FLAG_STAR_IN = 2
FLAG_CHAIN = 4
FLAG_HOT = 8


def _bisect(sorted_keys: jax.Array, q: jax.Array, right: bool) -> jax.Array:
    """Vectorised binary search (lower/upper bound) — log2(n) gathers."""
    n = sorted_keys.shape[0]
    steps = max(n.bit_length(), 1)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        v = sorted_keys[jnp.clip(mid, 0, n - 1)]
        go = (v <= q) if right else (v < q)
        return jnp.where(go, mid + 1, lo), jnp.where(go, hi, mid)

    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, n, jnp.int32)
    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def _tag(ids: jax.Array, etype: jax.Array, tag: int) -> jax.Array:
    """Pattern signature: hub/relay id x etype x pattern-class tag."""
    kd = ids.dtype
    return mix_keys(ids, etype.astype(kd), jnp.full(ids.shape, tag, jnp.int32))


def mine_body(src, dst, etype, count, valid, star_min, hot_min, sort_fn):
    """Classify every edge of a dedup'd batch (pure body, shared by the
    kernel and the oracle; `sort_fn` must sort ascending).

    Returns (fan_out, fan_in, flags, psig): int32 fan counts, an int32
    FLAG_* bitmask (0 = not a pattern member), and the key-dtype
    pattern signature of the strongest matching pattern.
    """
    kd = src.dtype
    sentinel = sentinel_for(kd)
    gs = _tag(src, etype, TAG_STAR_OUT)   # (src, etype) group key
    gd = _tag(dst, etype, TAG_STAR_IN)    # (dst, etype) group key
    sorted_gs = sort_fn(jnp.where(valid, gs, sentinel))
    sorted_gd = sort_fn(jnp.where(valid, gd, sentinel))
    sorted_src = sort_fn(jnp.where(valid, src, sentinel))

    fan_out = _bisect(sorted_gs, gs, True) - _bisect(sorted_gs, gs, False)
    fan_in = _bisect(sorted_gd, gd, True) - _bisect(sorted_gd, gd, False)
    fan_out = jnp.where(valid, fan_out, 0)
    fan_in = jnp.where(valid, fan_in, 0)

    # cascade chain: this edge's head is some other edge's tail
    pos = _bisect(sorted_src, dst, False)
    member = sorted_src[jnp.clip(pos, 0, src.shape[0] - 1)] == dst
    chain = valid & member & (dst != src)

    staro = valid & (fan_out >= star_min)
    stari = valid & (fan_in >= star_min)
    hot = valid & (count >= hot_min)
    flags = (staro * FLAG_STAR_OUT + stari * FLAG_STAR_IN
             + chain * FLAG_CHAIN + hot * FLAG_HOT).astype(jnp.int32)

    # strongest pattern wins the signature: hub fan-out > fan-in >
    # chain relay > hot edge (the edge's own key)
    psig = _tag(src, etype, TAG_HOT)
    psig = jnp.where(chain, _tag(dst, etype, TAG_CHAIN), psig)
    psig = jnp.where(stari, gd, psig)
    psig = jnp.where(staro, gs, psig)
    return fan_out, fan_in, flags, jnp.where(flags != 0, psig, 0)


# ---------------------------------------------------------------- oracle
@jax.jit
def pattern_mine_ref(src, dst, etype, count, valid, star_min, hot_min):
    """jnp oracle (and the CPU hot path — interpret-mode Pallas is the
    validation path, not the fast path; see repro.kernels.ops)."""
    return mine_body(src, dst, etype, count, valid,
                     jnp.asarray(star_min, jnp.int32),
                     jnp.asarray(hot_min, jnp.int32), jnp.sort)


# ---------------------------------------------------------------- kernel
def _bitonic_sort(x: jax.Array) -> jax.Array:
    """Key-only bitonic network (edge_dedup's stages minus the payload)."""
    n = x.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            xr = x.reshape(n // (2 * j), 2, j)
            a, b = xr[:, 0, :], xr[:, 1, :]
            pos = jax.lax.broadcasted_iota(
                jnp.int32, (n // (2 * j), j), 0) * (2 * j) + \
                jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), j), 1)
            asc = (pos & k) == 0
            swap = jnp.where(asc, a > b, a < b)
            na = jnp.where(swap, b, a)
            nb = jnp.where(swap, a, b)
            x = jnp.stack([na, nb], axis=1).reshape(n)
            j //= 2
        k *= 2
    return x


def _mine_kernel(params_ref, src_ref, dst_ref, etype_ref, count_ref,
                 valid_ref, fan_out_ref, fan_in_ref, flags_ref, psig_ref):
    fan_out, fan_in, flags, psig = mine_body(
        src_ref[...], dst_ref[...], etype_ref[...], count_ref[...],
        valid_ref[...] != 0, params_ref[0], params_ref[1], _bitonic_sort)
    fan_out_ref[...] = fan_out
    fan_in_ref[...] = fan_in
    flags_ref[...] = flags
    psig_ref[...] = psig


@functools.partial(jax.jit, static_argnames=("interpret",))
def pattern_mine(src, dst, etype, count, valid, star_min, hot_min,
                 interpret: bool = True):
    """Pattern mining through the Pallas kernel.

    src/dst (n,) key dtype; etype/count (n,) int32; valid (n,) bool;
    star_min/hot_min scalar int32 thresholds.  n must be a power of
    two (batch caps already are).  Returns (fan_out, fan_in, flags,
    psig) as `mine_body`.
    """
    n = src.shape[0]
    assert n & (n - 1) == 0, f"n must be a power of two, got {n}"
    params = jnp.stack([jnp.asarray(star_min, jnp.int32),
                        jnp.asarray(hot_min, jnp.int32)])
    return pl.pallas_call(
        _mine_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), src.dtype),
        ],
        interpret=interpret,
    )(params, src, dst, etype, count, valid.astype(jnp.int32))
