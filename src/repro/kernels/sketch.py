"""Pallas TPU kernel: batched scatter-update of the graph sketch.

The ingestion-time sketch (repro.query.sketch, GSS/TCM-style) absorbs
one compressed edge table per update: every unique edge adds its
`count` into D hashed cells of the (D, W, W) edge-weight matrix sketch
and into the per-depth out/in degree counter rows.  That triple
scatter-add is the sketch's hot path — one kernel launch per commit,
all operands resident in VMEM (D*W*W ints: 1 MB at the default
D=4, W=256).

Row/col hash coordinates are precomputed outside (cheap VPU work, and
the host-side oracle shares them); the kernel owns the memory-bound
scatter.  Integer scatter-add is order-independent, so the kernel is
bit-exact against the jnp oracle `repro.query.sketch.sketch_scatter_ref`
by construction — tests assert it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def scatter_add(edge_w, out_deg, in_deg, r, c, cnt):
    """The pure scatter-add body, shared verbatim by the Pallas kernel
    and the jnp oracle (repro.query.sketch.sketch_scatter_ref) so the
    two can never drift."""
    W = edge_w.shape[1]
    depth = jax.lax.broadcasted_iota(jnp.int32, r.shape, 0)
    cnt_b = jnp.broadcast_to(cnt[None, :], r.shape)
    flat_e = (depth * (W * W) + r * W + c).reshape(-1)
    ew = edge_w.reshape(-1).at[flat_e].add(cnt_b.reshape(-1)).reshape(edge_w.shape)
    flat_o = (depth * W + r).reshape(-1)
    od = out_deg.reshape(-1).at[flat_o].add(cnt_b.reshape(-1)).reshape(out_deg.shape)
    flat_i = (depth * W + c).reshape(-1)
    idg = in_deg.reshape(-1).at[flat_i].add(cnt_b.reshape(-1)).reshape(in_deg.shape)
    return ew, od, idg


def _scatter_kernel(ew_ref, od_ref, id_ref, r_ref, c_ref, cnt_ref,
                    ew_out, od_out, id_out):
    # r/c: (D, n) int32 row/col hashes; cnt: (n,) int32, 0 for invalid
    ew, od, idg = scatter_add(ew_ref[...], od_ref[...], id_ref[...],
                              r_ref[...], c_ref[...], cnt_ref[...])
    ew_out[...] = ew
    od_out[...] = od
    id_out[...] = idg


@functools.partial(jax.jit, static_argnames=("interpret",))
def sketch_scatter(edge_w: jax.Array, out_deg: jax.Array, in_deg: jax.Array,
                   r: jax.Array, c: jax.Array, cnt: jax.Array,
                   interpret: bool = True):
    """One sketch update: (edge_w', out_deg', in_deg').

    edge_w (D, W, W) int32; out_deg/in_deg (D, W) int32;
    r/c (D, n) int32 hash coordinates; cnt (n,) int32 edge counts
    (invalid slots must carry 0)."""
    D, W, _ = edge_w.shape
    n = cnt.shape[0]
    return pl.pallas_call(
        _scatter_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((D, W, W), lambda i: (0, 0, 0)),
            pl.BlockSpec((D, W), lambda i: (0, 0)),
            pl.BlockSpec((D, W), lambda i: (0, 0)),
            pl.BlockSpec((D, n), lambda i: (0, 0)),
            pl.BlockSpec((D, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((D, W, W), lambda i: (0, 0, 0)),
            pl.BlockSpec((D, W), lambda i: (0, 0)),
            pl.BlockSpec((D, W), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D, W, W), jnp.int32),
            jax.ShapeDtypeStruct((D, W), jnp.int32),
            jax.ShapeDtypeStruct((D, W), jnp.int32),
        ],
        interpret=interpret,
    )(edge_w, out_deg, in_deg, r, c, cnt)
