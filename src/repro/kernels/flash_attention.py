"""Pallas TPU kernel: blocked (flash) causal attention, fwd.

Grid (batch*heads, nq, nk) with the kv axis innermost and sequential
("arbitrary"); online-softmax running stats (acc, m, l) live in VMEM
scratch that persists across the nk iterations.  Q/K/V blocks are
MXU-aligned (block_q x head_dim, block_k x head_dim tiles in VMEM).
Supports causal and sliding-window (SWA) masking.

The pure-jnp oracle is the online-softmax recurrence in
`repro.models.layers._sdpa_chunked`, wired up via repro.kernels.ref.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, block_q: int, block_k: int, nk: int, causal: bool,
    window: Optional[int], scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (block_q, d)
    k = k_ref[0]  # (block_k, d)
    v = v_ref[0]
    s = jnp.dot(
        q.astype(jnp.float32), k.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    ) * scale  # (block_q, block_k)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (BH, S, d)  flattened batch*heads
    k: jax.Array,  # (BH, S, d)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
):
    BH, S, d = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(d)
    kern = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, nk=nk,
        causal=causal, window=window, scale=scale,
    )
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
