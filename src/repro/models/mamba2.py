"""Mamba2 (SSD — state-space duality) block, chunked.

Implements the SSD chunked algorithm of arXiv:2405.21060 §6: within a
chunk the recurrence is computed as a (masked) attention-like matmul;
across chunks a small recurrent state (nh, N, p) is carried by a scan.
Single-token decode is the O(1) recurrent update.

Layout: d_inner = expand * d_model split into nh heads of head_dim p;
B/C are shared across heads (ngroups=1), state size N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard


def _split_proj(x, p, cfg: ModelConfig):
    """x: (B,S,D) -> z,xs (B,S,d_in), Bs,Cs (B,S,N), dt (B,S,nh)."""
    dt_f = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(dt_f))
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt_f))
    Bs = jnp.einsum("bsd,dn->bsn", x, p["w_B"].astype(dt_f))
    Cs = jnp.einsum("bsd,dn->bsn", x, p["w_C"].astype(dt_f))
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt_f))
    return z, xs, Bs, Cs, dt


def _causal_conv(u, w, cache=None):
    """Depthwise causal conv1d. u: (B,S,C), w: (K,C).

    If cache (B,K-1,C) is given, performs the streaming update and
    returns (y (B,S,C), new_cache)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
        up = jnp.concatenate([pad, u], axis=1)
    else:
        up = jnp.concatenate([cache.astype(u.dtype), u], axis=1)
    y = sum(up[:, i : i + u.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_cache = up[:, -(K - 1) :, :] if K > 1 else None
    return y, new_cache


def ssd_chunked(xh, dt, A, Bs, Cs, chunk: int, init_state=None):
    """Chunked SSD scan (pure-jnp oracle of the Pallas ssd_scan kernel).

    xh: (B,S,nh,p) inputs, dt: (B,S,nh) positive step sizes,
    A: (nh,) negative decay rates, Bs/Cs: (B,S,N).
    Returns (y (B,S,nh,p), final_state (B,nh,N,p)).
    """
    B_, S, nh, p = xh.shape
    N = Bs.shape[-1]
    Q = chunk
    S0 = S
    if S % Q:  # pad with dt=0 steps: identity state transition, no output
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
        S = xh.shape[1]
    nc = S // Q

    f32 = jnp.float32
    xh = xh.astype(f32)
    dt = dt.astype(f32)
    Bs = Bs.astype(f32)
    Cs = Cs.astype(f32)
    dA = dt * A[None, None, :]  # (B,S,nh), negative

    xc = xh.reshape(B_, nc, Q, nh, p)
    dtc = dt.reshape(B_, nc, Q, nh)
    dAc = dA.reshape(B_, nc, Q, nh)
    Bc = Bs.reshape(B_, nc, Q, N)
    Cc = Cs.reshape(B_, nc, Q, N)

    seg = jnp.cumsum(dAc, axis=2)  # (B,nc,Q,nh) cumulative within chunk
    total = seg[:, :, -1, :]  # (B,nc,nh)

    # ---- intra-chunk (quadratic within chunk) ----
    # L[i,j] = exp(seg_i - seg_j) * dt_j for j <= i
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (B,nc,Q,Q,nh)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask BEFORE exp: exp of a huge positive (j>i) would be inf and poison
    # the gradient through `where` (NaN-grad trap)
    L = jnp.exp(jnp.where(mask, li, -1e30)) * dtc[:, :, None, :, :]
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", G, L, xc)

    # ---- chunk states ----
    # S_c = sum_j exp(total - seg_j) * dt_j * B_j x_j^T   (B,nc,nh,N,p)
    decay_to_end = jnp.exp(total[:, :, None, :] - seg)  # (B,nc,Q,nh)
    wts = decay_to_end * dtc  # (B,nc,Q,nh)
    S_c = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", wts, Bc, xc)

    # ---- inter-chunk recurrence over chunk index ----
    def body(h, inp):
        s_c, tot = inp  # (B,nh,N,p), (B,nh)
        h_new = h * jnp.exp(tot)[:, :, None, None] + s_c
        return h_new, h  # emit state *before* this chunk

    if init_state is None:
        h0 = jnp.zeros((B_, nh, N, p), f32)
    else:
        h0 = init_state.astype(f32)
    hT, h_prev = jax.lax.scan(
        body,
        h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,nh,N,p)

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(seg)  # (B,nc,Q,nh)
    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", Cc, decay_from_start, h_prev
    )

    y = (y_intra + y_inter).reshape(B_, S, nh, p)
    return y[:, :S0], hT


def ssd_decode_step(xh, dt, A, Bs, Cs, state):
    """One-token SSD update.  xh: (B,nh,p), dt: (B,nh), Bs/Cs: (B,N),
    state: (B,nh,N,p) -> (y (B,nh,p), new_state)."""
    f32 = jnp.float32
    xh, dt, Bs, Cs = (t.astype(f32) for t in (xh, dt, Bs, Cs))
    state = state.astype(f32)
    dA = jnp.exp(dt * A[None, :])  # (B,nh)
    upd = jnp.einsum("bn,bhp->bhnp", Bs, xh * dt[..., None])
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cs, state)
    return y, state


def mamba2_block(x, p, cfg: ModelConfig, state=None, conv_cache=None, decode=False):
    """Full Mamba2 block.  x: (B,S,D).

    Train/prefill: decode=False, returns (y, (final_state, conv_cache)).
    Decode: decode=True with S=1 and caches provided.
    """
    B, S, D = x.shape
    nh = cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    N = cfg.ssm_state

    z, xs, Bs, Cs, dt = _split_proj(x, p, cfg)
    xs = shard(xs, ("batch", None, "ssm_inner"))
    z = shard(z, ("batch", None, "ssm_inner"))

    # depthwise causal conv on [x, B, C]
    conv_in = jnp.concatenate([xs, Bs, Cs], axis=-1)
    new_conv_cache = None
    if decode:
        conv_out, new_conv_cache = _causal_conv(conv_in, p["conv_w"], conv_cache)
    else:
        conv_out, new_conv_cache = _causal_conv(conv_in, p["conv_w"])
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., : cfg.ssm_d_inner]
    Bs = conv_out[..., cfg.ssm_d_inner : cfg.ssm_d_inner + N]
    Cs = conv_out[..., cfg.ssm_d_inner + N :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (nh,)

    xh = xs.reshape(B, S, nh, pdim)
    if decode:
        y1, new_state = ssd_decode_step(
            xh[:, 0], dt[:, 0], A, Bs[:, 0], Cs[:, 0], state
        )
        y = y1[:, None]  # (B,1,nh,p)
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bs, Cs, cfg.ssm_chunk, init_state=state)

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, cfg.ssm_d_inner).astype(x.dtype)

    # gated RMSNorm then out_proj
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    out = shard(out, ("batch", "seq_sp", None))
    return out, (new_state, new_conv_cache)
