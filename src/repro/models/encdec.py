"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: `input_specs()`
supplies precomputed frame embeddings (batch, enc_seq, d_model).
Positions are sinusoidal (computed, not learned) so parameter shapes are
independent of the run shape; noted as a deviation in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamSpec, shard
from repro.models import layers as L
from repro.models.transformer import (
    add_leading,
    attn_specs,
    embed_tokens,
    mlp_specs,
    norm_specs,
    unembed,
    _maybe_remat,
)


def sinusoid_pos(S: int, D: int, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (D // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def enc_layer_specs(cfg: ModelConfig):
    return {
        "attn_norm": norm_specs(cfg, cfg.d_model),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_specs(cfg, cfg.d_model),
        "mlp": mlp_specs(cfg, cfg.d_ff),
    }


def dec_layer_specs(cfg: ModelConfig):
    return {
        "attn_norm": norm_specs(cfg, cfg.d_model),
        "attn": attn_specs(cfg),
        "cross_norm": norm_specs(cfg, cfg.d_model),
        "cross": attn_specs(cfg),  # wq/wk/wv/wo (+biases)
        "mlp_norm": norm_specs(cfg, cfg.d_model),
        "mlp": mlp_specs(cfg, cfg.d_ff),
    }


def encdec_specs(cfg: ModelConfig):
    V, D = cfg.padded_vocab, cfg.d_model
    return {
        "embed": ParamSpec((V, D), ("vocab", "fsdp"), init="small_normal"),
        "enc_layers": add_leading(enc_layer_specs(cfg), cfg.enc_layers, "layers"),
        "enc_final_norm": norm_specs(cfg, D),
        "dec_layers": add_leading(dec_layer_specs(cfg), cfg.num_layers, "layers"),
        "final_norm": norm_specs(cfg, D),
        "head": ParamSpec((D, V), ("fsdp", "vocab")),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, enc_seq, D) precomputed conv-frontend output (stub)."""
    h = frames.astype(jnp.dtype(cfg.dtype))
    h = h + sinusoid_pos(h.shape[1], cfg.d_model, h.dtype)[None]
    h = shard(h, ("batch", "seq_sp", None))

    def body(carry, lp):
        x = carry
        hn = L.apply_norm(x, lp["attn_norm"], cfg)
        x = x + L.attention(hn, lp["attn"], cfg, causal=False)
        hn = L.apply_norm(x, lp["mlp_norm"], cfg)
        x = x + L.mlp(hn, lp["mlp"], cfg)
        return shard(x, ("batch", "seq_sp", None)), None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["enc_layers"])
    return L.apply_norm(h, params["enc_final_norm"], cfg)


def _enc_kv(enc_out, lp, cfg: ModelConfig):
    k = jnp.einsum("bsd,dmh->bsmh", enc_out, lp["cross"]["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dmh->bsmh", enc_out, lp["cross"]["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + lp["cross"]["bk"].astype(enc_out.dtype)
        v = v + lp["cross"]["bv"].astype(enc_out.dtype)
    return k, v


def decode_train(params, cfg: ModelConfig, enc_out, tokens):
    h = embed_tokens(params, cfg, tokens)
    h = h + sinusoid_pos(h.shape[1], cfg.d_model, h.dtype)[None]
    h = shard(h, ("batch", "seq_sp", None))

    def body(carry, lp):
        x = carry
        hn = L.apply_norm(x, lp["attn_norm"], cfg)
        x = x + L.attention(hn, lp["attn"], cfg)
        hn = L.apply_norm(x, lp["cross_norm"], cfg)
        x = x + L.cross_attention(hn, _enc_kv(enc_out, lp, cfg), lp["cross"], cfg)
        hn = L.apply_norm(x, lp["mlp_norm"], cfg)
        x = x + L.mlp(hn, lp["mlp"], cfg)
        return shard(x, ("batch", "seq_sp", None)), None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["dec_layers"])
    h = L.apply_norm(h, params["final_norm"], cfg)
    return unembed(params, cfg, h), jnp.zeros((), jnp.float32)


def encdec_forward(params, cfg: ModelConfig, frames, tokens):
    enc_out = encode(params, cfg, frames)
    return decode_train(params, cfg, enc_out, tokens)


# ---------------------------------------------------------------------------
# Decode (serving): self-attn KV cache + precomputed cross KV
# ---------------------------------------------------------------------------


def encdec_cache_specs(cfg: ModelConfig, batch: int, context: int):
    m, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Ld = cfg.num_layers
    kv = ParamSpec(
        (Ld, batch, context, m, hd),
        ("layers", "batch", "kv_len", "kv_heads", None),
        init="zeros",
        dtype=cfg.dtype,
    )
    cross = ParamSpec(
        (Ld, batch, cfg.enc_seq, m, hd),
        ("layers", "batch", "kv_len", "kv_heads", None),
        init="zeros",
        dtype=cfg.dtype,
    )
    return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross}


def encdec_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    h = embed_tokens(params, cfg, tokens[:, None])
    # position embedding at `pos` (sinusoidal, gathered)
    posemb = sinusoid_pos(1, cfg.d_model, h.dtype) * 0.0 + _pos_at(pos, cfg, h.dtype)
    h = h + posemb[None]

    def sbody(carry, xs):
        lp, ck, cv, xk, xv = xs
        x = carry
        hn = L.apply_norm(x, lp["attn_norm"], cfg)
        a, ck, cv = L.decode_attention(hn, lp["attn"], cfg, ck, cv, pos)
        x = x + a
        hn = L.apply_norm(x, lp["cross_norm"], cfg)
        x = x + L.cross_attention(hn, (xk, xv), lp["cross"], cfg)
        hn = L.apply_norm(x, lp["mlp_norm"], cfg)
        x = x + L.mlp(hn, lp["mlp"], cfg)
        return x, (ck, cv)

    h, (nk, nv) = jax.lax.scan(
        sbody,
        h,
        (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    h = L.apply_norm(h, params["final_norm"], cfg)
    logits = unembed(params, cfg, h)[:, 0]
    return logits, {
        "k": nk,
        "v": nv,
        "cross_k": cache["cross_k"],
        "cross_v": cache["cross_v"],
    }


def _pos_at(pos, cfg: ModelConfig, dtype):
    D = cfg.d_model
    dim = jnp.arange(D // 2, dtype=jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / (D // 2))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)[None, :]
