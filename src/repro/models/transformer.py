"""Decoder-only LM assembly: param specs, scanned forward, decode step.

Per-layer parameters are stacked along a leading "layers" dim and the
layer stack is a `lax.scan` — this keeps the HLO compact enough to
compile 126-layer 405B programs quickly, and is also what makes the
multi-pod SPMD partitioning tractable.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamSpec, shard
from repro.models import layers as L
from repro.models.moe import moe_block

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def norm_specs(cfg: ModelConfig, d: int):
    s = {"scale": ParamSpec((d,), (None,), init="ones")}
    if cfg.use_layernorm:
        s["bias"] = ParamSpec((d,), (None,), init="zeros")
    return s


def attn_specs(cfg: ModelConfig):
    D, n, m, h = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": ParamSpec((D, n, h), ("fsdp", "heads", None)),
        "wk": ParamSpec((D, m, h), ("fsdp", "kv_heads", None)),
        "wv": ParamSpec((D, m, h), ("fsdp", "kv_heads", None)),
        "wo": ParamSpec((n, h, D), ("heads", None, "fsdp")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((n, h), ("heads", None), init="zeros")
        s["bk"] = ParamSpec((m, h), ("kv_heads", None), init="zeros")
        s["bv"] = ParamSpec((m, h), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((h,), (None,), init="ones")
        s["k_norm"] = ParamSpec((h,), (None,), init="ones")
    return s


def mlp_specs(cfg: ModelConfig, d_ff: int):
    D = cfg.d_model
    if cfg.act == "silu":
        return {
            "wi": ParamSpec((D, d_ff), ("fsdp", "mlp")),
            "wg": ParamSpec((D, d_ff), ("fsdp", "mlp")),
            "wo": ParamSpec((d_ff, D), ("mlp", "fsdp")),
        }
    return {
        "wi": ParamSpec((D, d_ff), ("fsdp", "mlp")),
        "bi": ParamSpec((d_ff,), ("mlp",), init="zeros"),
        "wo": ParamSpec((d_ff, D), ("mlp", "fsdp")),
        "bo": ParamSpec((D,), (None,), init="zeros"),
    }


def moe_specs(cfg: ModelConfig):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    s = {
        "router": ParamSpec((D, E), ("fsdp", None)),
        "wi": ParamSpec((E, D, F), ("experts", "fsdp", "mlp")),
        "wg": ParamSpec((E, D, F), ("experts", "fsdp", "mlp")),
        "wo": ParamSpec((E, F, D), ("experts", "mlp", "fsdp")),
    }
    if cfg.shared_d_ff:
        s["shared_wi"] = ParamSpec((D, cfg.shared_d_ff), ("fsdp", "mlp"))
        s["shared_wg"] = ParamSpec((D, cfg.shared_d_ff), ("fsdp", "mlp"))
        s["shared_wo"] = ParamSpec((cfg.shared_d_ff, D), ("mlp", "fsdp"))
        s["shared_gate"] = ParamSpec((D,), (None,), init="zeros")
    return s


def decoder_layer_specs(cfg: ModelConfig):
    s = {
        "attn_norm": norm_specs(cfg, cfg.d_model),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_specs(cfg, cfg.d_model),
    }
    if cfg.num_experts:
        s["moe"] = moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg, cfg.d_ff)
    return s


def add_leading(specs, n: int, name: str):
    def f(p: ParamSpec):
        return ParamSpec((n,) + p.shape, (name,) + p.logical, init=p.init, scale=p.scale, dtype=p.dtype)

    return jax.tree.map(f, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def lm_specs(cfg: ModelConfig):
    V, D = cfg.padded_vocab, cfg.d_model
    s = {
        "embed": ParamSpec((V, D), ("vocab", "fsdp"), init="small_normal"),
        "final_norm": norm_specs(cfg, D),
        "layers": add_leading(decoder_layer_specs(cfg), cfg.num_layers, "layers"),
    }
    if not cfg.tie_embeddings:
        s["head"] = ParamSpec((D, V), ("fsdp", "vocab"))
    if cfg.num_patches:
        s["vision_proj"] = ParamSpec((D, D), ("fsdp", None))
    return s


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def layer_body(x, lp, cfg: ModelConfig, positions=None):
    """One decoder layer; returns (x, aux)."""
    h = L.apply_norm(x, lp["attn_norm"], cfg)
    x = x + L.attention(h, lp["attn"], cfg, positions=positions)
    h = L.apply_norm(x, lp["mlp_norm"], cfg)
    if cfg.num_experts:
        y, aux = moe_block(h, lp["moe"], cfg)
    else:
        y, aux = L.mlp(h, lp["mlp"], cfg), jnp.zeros((), jnp.float32)
    x = x + y
    x = shard(x, ("batch", "seq_sp", None))
    return x, aux


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def scan_layers(x, stacked, cfg: ModelConfig, positions=None):
    body = _maybe_remat(
        lambda carry, lp: layer_body(carry, lp, cfg, positions=positions), cfg
    )
    if not cfg.use_scan_layers:
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], stacked)
            x, aux = body(x, lp)
            aux_total = aux_total + aux
        return x, aux_total

    def sbody(carry, lp):
        x, aux = body(carry, lp)
        return x, aux

    x, auxs = jax.lax.scan(sbody, x, stacked)
    return x, jnp.sum(auxs)


def embed_tokens(params, cfg: ModelConfig, tokens):
    emb = params["embed"]
    h = jnp.take(emb, tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return h * jnp.sqrt(float(cfg.d_model)).astype(h.dtype) if cfg.family == "audio" else h


def unembed(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        w = params["embed"].astype(h.dtype)  # (V, D)
        logits = jnp.einsum("bsd,vd->bsv", h, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype))
    # vocab-parallel logits (Megatron-style CE); seq stays unsharded here
    return shard(logits, ("batch", None, "vocab"))


def lm_forward(params, cfg: ModelConfig, tokens, patches=None):
    """tokens: (B, S_text) int32; patches: (B, P, D) precomputed embeddings
    (vlm stub).  Returns (logits (B,S,V), aux)."""
    h = embed_tokens(params, cfg, tokens)
    if cfg.num_patches and patches is not None:
        pe = jnp.einsum(
            "bpd,de->bpe", patches.astype(h.dtype), params["vision_proj"].astype(h.dtype)
        )
        h = jnp.concatenate([pe, h], axis=1)
    h = shard(h, ("batch", "seq_sp", None))
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    h, aux = scan_layers(h, params["layers"], cfg, positions=positions)
    h = L.apply_norm(h, params["final_norm"], cfg)
    return unembed(params, cfg, h), aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, context: int):
    """KV-cache ParamSpec tree for decode.  context = full KV length
    (or sliding window for SWA archs)."""
    W = context if cfg.sliding_window is None else min(context, cfg.sliding_window)
    m, h = cfg.num_kv_heads, cfg.resolved_head_dim
    kv = ParamSpec(
        (cfg.num_layers, batch, W, m, h),
        ("layers", "batch", "kv_len", "kv_heads", None),
        init="zeros",
        dtype=cfg.dtype,
    )
    return {"k": kv, "v": kv}


def _pack_swa_cache(k, pos_end: int, W: int):
    """Pack the last W entries of a (B,S,m,h) K/V into rolling-buffer slot
    order so decode can continue with slot = pos % W."""
    S = k.shape[1]
    last = k[:, S - W :]
    slots = (jnp.arange(S - W, S)) % W
    buf = jnp.zeros((k.shape[0], W) + k.shape[2:], k.dtype)
    return buf.at[:, slots].set(last)


def lm_prefill(params, cfg: ModelConfig, tokens, patches=None):
    """Process the full prompt; return (last-token logits, decode cache)."""
    h = embed_tokens(params, cfg, tokens)
    if cfg.num_patches and patches is not None:
        pe = jnp.einsum(
            "bpd,de->bpe", patches.astype(h.dtype), params["vision_proj"].astype(h.dtype)
        )
        h = jnp.concatenate([pe, h], axis=1)
    h = shard(h, ("batch", "seq_sp", None))
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        x = carry
        hn = L.apply_norm(x, lp["attn_norm"], cfg)
        a, (k, v) = L.attention(hn, lp["attn"], cfg, positions=positions, return_kv=True)
        x = x + a
        hn = L.apply_norm(x, lp["mlp_norm"], cfg)
        if cfg.num_experts:
            y, _ = moe_block(hn, lp["moe"], cfg)
        else:
            y = L.mlp(hn, lp["mlp"], cfg)
        x = shard(x + y, ("batch", "seq_sp", None))
        if cfg.sliding_window is not None and cfg.sliding_window < S:
            k = _pack_swa_cache(k, S, cfg.sliding_window)
            v = _pack_swa_cache(v, S, cfg.sliding_window)
        k = shard(k.astype(jnp.dtype(cfg.dtype)), ("batch", "kv_len", "kv_heads", None))
        v = shard(v.astype(jnp.dtype(cfg.dtype)), ("batch", "kv_len", "kv_heads", None))
        return x, (k, v)

    h, (ck, cv) = jax.lax.scan(_maybe_remat(body, cfg), h, params["layers"])
    h = L.apply_norm(h[:, -1:], params["final_norm"], cfg)
    logits = unembed(params, cfg, h)[:, 0]
    return logits, {"k": ck, "v": cv}


def layer_decode(x, lp, cfg: ModelConfig, ck, cv, pos):
    h = L.apply_norm(x, lp["attn_norm"], cfg)
    a, ck, cv = L.decode_attention(h, lp["attn"], cfg, ck, cv, pos)
    x = x + a
    h = L.apply_norm(x, lp["mlp_norm"], cfg)
    if cfg.num_experts:
        y, _ = moe_block(h, lp["moe"], cfg)
    else:
        y = L.mlp(h, lp["mlp"], cfg)
    return x + y, ck, cv


def lm_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """tokens: (B,) int32, pos: scalar int32 position being written.
    Returns (logits (B,V), new_cache)."""
    h = embed_tokens(params, cfg, tokens[:, None])

    def sbody(carry, xs):
        lp, ck, cv = xs
        x, ck, cv = layer_decode(carry, lp, cfg, ck, cv, pos)
        return x, (ck, cv)

    h, (nk, nv) = jax.lax.scan(sbody, h, (params["layers"], cache["k"], cache["v"]))
    h = L.apply_norm(h, params["final_norm"], cfg)
    logits = unembed(params, cfg, h)[:, 0]
    return logits, {"k": nk, "v": nv}
