"""Transformer building blocks: norms, RoPE, GQA attention (full /
chunked-online-softmax / sliding-window / decode), SwiGLU & GeLU MLPs.

All functions are pure; params are dicts of arrays built from the
ParamSpec trees in `repro.models.model`.  Activations carry logical
sharding constraints so XLA SPMD propagates the intended layout.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, cfg: ModelConfig):
    if cfg.use_layernorm:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _project_qkv(x, p, cfg: ModelConfig):
    """x: (B,S,D) -> q (B,S,n,h), k,v (B,S,m,h)."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dmh->bsmh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dmh->bsmh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa_full(q, k, v, causal: bool, window: Optional[int], q_offset=0):
    """Materialised-scores attention for short sequences.

    q: (B,Sq,n,h), k/v: (B,Sk,m,h) with n = m*g.
    """
    B, Sq, n, h = q.shape
    m = k.shape[2]
    g = n // m
    qh = q.reshape(B, Sq, m, g, h)
    scale = 1.0 / math.sqrt(h)
    scores = jnp.einsum("bqmgh,bkmh->bmgqk", qh, k).astype(jnp.float32) * scale
    qpos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (Sq, k.shape[1]), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, k.shape[1]), 1)
    mask = jnp.ones((Sq, k.shape[1]), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bmgqk,bkmh->bqmgh", w, v)
    return out.reshape(B, Sq, n, h)


def _sdpa_chunked(q, k, v, causal: bool, window: Optional[int], chunk: int):
    """Online-softmax attention, scanning KV in chunks (flash-style ref).

    Bounded memory for long sequences: live scores are (B,m,g,Sq,chunk).
    This is also the pure-jnp oracle of the Pallas flash kernel.
    """
    B, Sq, n, h = q.shape
    m = k.shape[2]
    g = n // m
    Sk = k.shape[1]
    nchunks = Sk // chunk
    assert Sk % chunk == 0, (Sk, chunk)
    qh = q.reshape(B, Sq, m, g, h).astype(jnp.float32)
    scale = 1.0 / math.sqrt(h)

    kc = k.reshape(B, nchunks, chunk, m, h)
    vc = v.reshape(B, nchunks, chunk, m, h)

    def body(carry, inp):
        acc, mx, den = carry
        ci, kb, vb = inp
        s = jnp.einsum("bqmgh,bkmh->bmgqk", qh, kb.astype(jnp.float32)) * scale
        qpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, chunk), 0)
        kpos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (Sq, chunk), 1)
        msk = jnp.ones((Sq, chunk), jnp.bool_)
        if causal:
            msk &= qpos >= kpos
        if window is not None:
            msk &= qpos - kpos < window
        s = jnp.where(msk, s, -1e30)
        new_mx = jnp.maximum(mx, s.max(axis=-1))
        alpha = jnp.exp(mx - new_mx)
        p = jnp.exp(s - new_mx[..., None])
        den = den * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bmgqk,bkmh->bmgqh", p, vb.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (acc, new_mx, den), None

    acc0 = jnp.zeros((B, m, g, Sq, h), jnp.float32)
    mx0 = jnp.full((B, m, g, Sq), -jnp.inf, jnp.float32)
    den0 = jnp.zeros((B, m, g, Sq), jnp.float32)
    (acc, _, den), _ = jax.lax.scan(
        body,
        (acc0, mx0, den0),
        (jnp.arange(nchunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
    )
    out = acc / den[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, n, h)  # (B,Sq,m,g,h)->flat heads
    return out.astype(q.dtype)


def attention(x, p, cfg: ModelConfig, positions=None, causal=True, return_kv=False):
    """Full-sequence attention (train / prefill)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    q = shard(q, ("batch", None, "heads", None))
    k = shard(k, ("batch", None, "kv_heads", None))
    v = shard(v, ("batch", None, "kv_heads", None))
    if cfg.use_rope:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # chunked online-softmax only where the S^2 score tensor is the real
    # memory problem; at train lengths (<=8k) the materialised form is
    # strictly less HBM traffic (§Perf iteration q1: the scan's carried
    # accumulator rescale cost 4x redundant passes at S=4096)
    if S > max(cfg.attn_full_max, 2 * cfg.attn_chunk) and S % cfg.attn_chunk == 0:
        out = _sdpa_chunked(q, k, v, causal, cfg.sliding_window, cfg.attn_chunk)
    else:
        out = _sdpa_full(q, k, v, causal, cfg.sliding_window)
    out = shard(out, ("batch", None, "heads", None))
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    y = shard(y, ("batch", "seq_sp", None))
    if return_kv:
        return y, (k, v)
    return y


def cross_attention(x, enc_kv, p, cfg: ModelConfig):
    """Decoder cross-attention over precomputed encoder K/V."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    out = _sdpa_full(q, k.astype(x.dtype), v.astype(x.dtype), causal=False, window=None)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    return y


def decode_attention(xt, p, cfg: ModelConfig, cache_k, cache_v, pos):
    """One-token attention against a KV cache.

    xt: (B,1,D); cache_k/v: (B,W,m,h); pos: scalar current position.
    Returns (y (B,1,D), new_cache_k, new_cache_v).
    The cache length W is the full context for dense archs or the
    sliding window for SWA archs; writes wrap mod W for SWA.
    """
    B, one, D = xt.shape
    q, k, v = _project_qkv(xt, p, cfg)
    if cfg.use_rope:
        posb = jnp.full((B, 1), pos, jnp.int32)
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    W = cache_k.shape[1]
    if cfg.sliding_window is not None:
        slot = pos % W  # rolling buffer
    else:
        slot = jnp.minimum(pos, W - 1)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))

    m = cache_k.shape[2]
    n = q.shape[2]
    g = n // m
    h = q.shape[3]
    qh = q.reshape(B, m, g, h).astype(jnp.float32)
    kf = cache_k.astype(jnp.float32)
    scale = 1.0 / math.sqrt(h)
    s = jnp.einsum("bmgh,bwmh->bmgw", qh, kf) * scale  # (B,m,g,W)
    wpos = jax.lax.broadcasted_iota(jnp.int32, (W,), 0)
    if cfg.sliding_window is not None:
        valid = (wpos <= slot) | (pos >= W)  # wrapped buffer fully valid once warm
    else:
        valid = wpos <= slot
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bmgw,bwmh->bmgh", w, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, n, h).astype(xt.dtype)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(xt.dtype))
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp(x, p, cfg: ModelConfig):
    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype)))
        h = h * jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        if "bi" in p:
            h = h + p["bi"].astype(x.dtype)
        h = jax.nn.gelu(h)
    h = shard(h, ("batch", None, "mlp"))
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(x.dtype)
    return shard(y, ("batch", "seq_sp", None))
