"""Model facade: family dispatch for param specs, forward, loss, prefill
and decode, plus `input_specs()` — the ShapeDtypeStruct stand-ins used by
the multi-pod dry-run (weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import ParamSpec, shard
from repro.models import encdec, hybrid, ssm_lm, transformer

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    if cfg.family == "ssm":
        return ssm_lm.ssm_lm_specs(cfg)
    if cfg.family == "hybrid":
        return hybrid.hybrid_specs(cfg)
    if cfg.family == "audio":
        return encdec.encdec_specs(cfg)
    return transformer.lm_specs(cfg)  # dense | moe | vlm


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch: Dict):
    if cfg.family == "ssm":
        return ssm_lm.ssm_lm_forward(params, cfg, batch["tokens"])
    if cfg.family == "hybrid":
        return hybrid.hybrid_forward(params, cfg, batch["tokens"])
    if cfg.family == "audio":
        return encdec.encdec_forward(params, cfg, batch["frames"], batch["tokens"])
    return transformer.lm_forward(params, cfg, batch["tokens"], batch.get("patches"))


def loss_fn(params, cfg: ModelConfig, batch: Dict):
    """Next-token CE with -1-masked labels; returns (loss, metrics)."""
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    mx = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    sub = lf - mx
    lse = jnp.log(jnp.sum(jnp.exp(sub), axis=-1)) + mx[..., 0]
    tgt = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    mask = (labels >= 0).astype(jnp.float32)
    ntok = jnp.maximum(mask.sum(), 1.0)
    ce = jnp.sum(nll * mask) / ntok
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux, "ntok": ntok}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, batch: Dict):
    """Returns (last-token logits (B,V), decode cache)."""
    if cfg.family == "ssm":
        # run forward in chunked mode collecting the final state
        return _ssm_prefill(params, cfg, batch["tokens"])
    if cfg.family == "hybrid":
        return _hybrid_prefill(params, cfg, batch["tokens"])
    if cfg.family == "audio":
        return _encdec_prefill(params, cfg, batch["frames"], batch["tokens"])
    return transformer.lm_prefill(params, cfg, batch["tokens"], batch.get("patches"))


def _ssm_prefill(params, cfg: ModelConfig, tokens):
    from repro.models import layers as L
    from repro.models.mamba2 import mamba2_block

    h = transformer.embed_tokens(params, cfg, tokens)
    h = shard(h, ("batch", "seq_sp", None))

    def body(carry, lp):
        x = carry
        hn = L.apply_norm(x, lp["norm"], cfg)
        y, (st, cv) = mamba2_block(hn, lp["mamba"], cfg)
        return x + y, (st, cv.astype(jnp.dtype(cfg.dtype)))

    h, (states, convs) = jax.lax.scan(transformer._maybe_remat(body, cfg), h, params["layers"])
    h = L.apply_norm(h[:, -1:], params["final_norm"], cfg)
    logits = transformer.unembed(params, cfg, h)[:, 0]
    return logits, {"state": states, "conv": convs}


def _hybrid_prefill(params, cfg: ModelConfig, tokens):
    from repro.models import layers as L
    from repro.models.mamba2 import mamba2_block

    h = transformer.embed_tokens(params, cfg, tokens)
    h = shard(h, ("batch", "seq_sp", None))
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]

    def mbody(carry, lp):
        x = carry
        hn = L.apply_norm(x, lp["norm"], cfg)
        y, (st, cv) = mamba2_block(hn, lp["mamba"], cfg)
        return x + y, (st, cv.astype(jnp.dtype(cfg.dtype)))

    def gbody(carry, gp):
        x, (st, cv) = jax.lax.scan(mbody, carry, gp)
        sp = params["shared"]
        hn = L.apply_norm(x, sp["attn_norm"], cfg)
        a, (k, v) = L.attention(hn, sp["attn"], cfg, positions=positions, return_kv=True)
        x = x + a
        hn = L.apply_norm(x, sp["mlp_norm"], cfg)
        x = x + L.mlp(hn, sp["mlp"], cfg)
        if cfg.sliding_window is not None and cfg.sliding_window < S:
            k = transformer._pack_swa_cache(k, S, cfg.sliding_window)
            v = transformer._pack_swa_cache(v, S, cfg.sliding_window)
        k = k.astype(jnp.dtype(cfg.dtype))
        v = v.astype(jnp.dtype(cfg.dtype))
        return x, (st, cv, k, v)

    h, (st, cv, k, v) = jax.lax.scan(
        transformer._maybe_remat(gbody, cfg), h, params["groups"]
    )
    cache = {"state": st, "conv": cv, "k": k, "v": v}
    if "tail" in params:
        h, (ts, tc) = jax.lax.scan(mbody, h, params["tail"])
        cache["tail_state"] = ts
        cache["tail_conv"] = tc
    h = L.apply_norm(h[:, -1:], params["final_norm"], cfg)
    logits = transformer.unembed(params, cfg, h)[:, 0]
    return logits, cache


def _encdec_prefill(params, cfg: ModelConfig, frames, tokens):
    from repro.models import layers as L

    enc_out = encdec.encode(params, cfg, frames)
    h = transformer.embed_tokens(params, cfg, tokens)
    h = h + encdec.sinusoid_pos(h.shape[1], cfg.d_model, h.dtype)[None]
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        x = carry
        hn = L.apply_norm(x, lp["attn_norm"], cfg)
        a, (k, v) = L.attention(hn, lp["attn"], cfg, positions=positions, return_kv=True)
        x = x + a
        hn = L.apply_norm(x, lp["cross_norm"], cfg)
        xk, xv = encdec._enc_kv(enc_out, lp, cfg)
        x = x + L.cross_attention(hn, (xk, xv), lp["cross"], cfg)
        hn = L.apply_norm(x, lp["mlp_norm"], cfg)
        x = x + L.mlp(hn, lp["mlp"], cfg)
        dt = jnp.dtype(cfg.dtype)
        return x, (k.astype(dt), v.astype(dt), xk.astype(dt), xv.astype(dt))

    h, (k, v, xk, xv) = jax.lax.scan(
        transformer._maybe_remat(body, cfg), h, params["dec_layers"]
    )
    h = L.apply_norm(h[:, -1:], params["final_norm"], cfg)
    logits = transformer.unembed(params, cfg, h)[:, 0]
    return logits, {"k": k, "v": v, "cross_k": xk, "cross_v": xv}


def cache_specs(cfg: ModelConfig, batch: int, context: int):
    if cfg.family == "ssm":
        return ssm_lm.ssm_cache_specs(cfg, batch, context)
    if cfg.family == "hybrid":
        return hybrid.hybrid_cache_specs(cfg, batch, context)
    if cfg.family == "audio":
        return encdec.encdec_cache_specs(cfg, batch, context)
    return transformer.cache_specs(cfg, batch, context)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    if cfg.family == "ssm":
        return ssm_lm.ssm_lm_decode_step(params, cfg, cache, tokens, pos)
    if cfg.family == "hybrid":
        return hybrid.hybrid_decode_step(params, cfg, cache, tokens, pos)
    if cfg.family == "audio":
        return encdec.encdec_decode_step(params, cfg, cache, tokens, pos)
    return transformer.lm_decode_step(params, cfg, cache, tokens, pos)


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ParamSpec tree describing every model input for `shape`.

    Used for dry-run avals AND in_shardings; materialised by the data
    pipeline for real runs (same single source of truth)."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: ParamSpec(s, ("batch", None), init="zeros", dtype="int32")

    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            d = {
                "frames": ParamSpec(
                    (B, cfg.enc_seq, cfg.d_model), ("batch", None, None), dtype=cfg.dtype
                ),
                "tokens": tok((B, S)),
            }
        elif cfg.family == "vlm":
            P = cfg.num_patches
            d = {
                "patches": ParamSpec(
                    (B, P, cfg.d_model), ("batch", None, None), dtype=cfg.dtype
                ),
                "tokens": tok((B, S - P)),
            }
        else:
            d = {"tokens": tok((B, S))}
        if shape.kind == "train":
            d["labels"] = tok((B, S))
        return d

    # decode: one new token against a seq_len-deep cache
    d = {
        "tokens": ParamSpec((B,), ("batch",), init="zeros", dtype="int32"),
        "pos": ParamSpec((), (), init="zeros", dtype="int32"),
        "cache": cache_specs(cfg, B, S),
    }
    return d
