"""Pure-SSM (Mamba2) language model assembly."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamSpec, shard
from repro.models import layers as L
from repro.models.mamba2 import mamba2_block
from repro.models.transformer import (
    add_leading,
    embed_tokens,
    norm_specs,
    unembed,
    _maybe_remat,
)


def mamba_layer_specs(cfg: ModelConfig):
    D, d_in, N, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = d_in + 2 * N
    return {
        "norm": norm_specs(cfg, D),
        "mamba": {
            "w_x": ParamSpec((D, d_in), ("fsdp", "ssm_inner")),
            "w_z": ParamSpec((D, d_in), ("fsdp", "ssm_inner")),
            "w_B": ParamSpec((D, N), ("fsdp", None)),
            "w_C": ParamSpec((D, N), ("fsdp", None)),
            "w_dt": ParamSpec((D, nh), ("fsdp", "ssm_heads")),
            "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), ("conv", None)),
            "A_log": ParamSpec((nh,), ("ssm_heads",), init="alog"),
            "D": ParamSpec((nh,), ("ssm_heads",), init="ones"),
            "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="dtbias"),
            "norm": ParamSpec((d_in,), ("ssm_inner",), init="ones"),
            "w_out": ParamSpec((d_in, D), ("ssm_inner", "fsdp")),
        },
    }


def ssm_lm_specs(cfg: ModelConfig):
    V, D = cfg.padded_vocab, cfg.d_model
    s = {
        "embed": ParamSpec((V, D), ("vocab", "fsdp"), init="small_normal"),
        "final_norm": norm_specs(cfg, D),
        "layers": add_leading(mamba_layer_specs(cfg), cfg.num_layers, "layers"),
    }
    if not cfg.tie_embeddings:
        s["head"] = ParamSpec((D, V), ("fsdp", "vocab"))
    return s


def mamba_layer_body(x, lp, cfg: ModelConfig):
    h = L.apply_norm(x, lp["norm"], cfg)
    y, _ = mamba2_block(h, lp["mamba"], cfg)
    return x + y


def ssm_lm_forward(params, cfg: ModelConfig, tokens):
    h = embed_tokens(params, cfg, tokens)
    h = shard(h, ("batch", "seq_sp", None))
    body = _maybe_remat(lambda c, lp: (mamba_layer_body(c, lp, cfg), None), cfg)
    h, _ = jax.lax.scan(body, h, params["layers"])
    h = L.apply_norm(h, params["final_norm"], cfg)
    return unembed(params, cfg, h), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode: O(1) recurrent state per layer
# ---------------------------------------------------------------------------


def ssm_cache_specs(cfg: ModelConfig, batch: int, context: int):
    del context  # state size is context-independent (the point of an SSM)
    nh, N, p = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = cfg.ssm_d_inner + 2 * N
    return {
        "state": ParamSpec(
            (cfg.num_layers, batch, nh, N, p),
            ("layers", "batch", "ssm_heads", None, None),
            init="zeros",
        ),
        "conv": ParamSpec(
            (cfg.num_layers, batch, cfg.ssm_conv - 1, conv_ch),
            ("layers", "batch", None, None),
            init="zeros",
            dtype=cfg.dtype,
        ),
    }


def ssm_lm_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    del pos  # SSM decode is position-free
    h = embed_tokens(params, cfg, tokens[:, None])

    def sbody(carry, xs):
        lp, st, cv = xs
        hn = L.apply_norm(carry, lp["norm"], cfg)
        y, (nst, ncv) = mamba2_block(hn, lp["mamba"], cfg, state=st, conv_cache=cv, decode=True)
        return carry + y, (nst, ncv)

    h, (ns, nc) = jax.lax.scan(sbody, h, (params["layers"], cache["state"], cache["conv"]))
    h = L.apply_norm(h, params["final_norm"], cfg)
    logits = unembed(params, cfg, h)[:, 0]
    return logits, {"state": ns, "conv": nc}
