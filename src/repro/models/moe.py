"""Mixture-of-Experts with sort-based capacity dispatch.

No |tokens| x |experts| one-hot matmuls: tokens are argsorted by expert
assignment, packed into an (E, C, D) buffer (capacity C), run through a
batched expert FFN, and combined by scatter-add.  Compiled FLOPs are
therefore ~ active-expert FLOPs x capacity_factor, keeping the roofline
"useful compute" ratio honest.

Routing is computed in fp32.  A load-balancing auxiliary loss (Switch
style) is returned for the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard


def _expert_ffn(xe, p, cfg: ModelConfig):
    """xe: (E, C, D) -> (E, C, D) batched SwiGLU."""
    w_i = p["wi"].astype(xe.dtype)  # (E, D, F)
    w_g = p["wg"].astype(xe.dtype)
    w_o = p["wo"].astype(xe.dtype)  # (E, F, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_i))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_g)
    h = shard(h, ("experts", None, "mlp"))
    return jnp.einsum("ecf,efd->ecd", h, w_o)


def moe_block(x, p, cfg: ModelConfig):
    """x: (B,S,D) -> (y (B,S,D), aux_loss scalar).

    Under a mesh this runs as a shard_map with *per-data-shard capacity*:
    each DP shard routes and packs only its own tokens (standard
    per-device-capacity MoE).  Without this, the (E,C,D) dispatch buffer
    has no batch dimension for SPMD to shard and XLA replicates the
    whole expert GEMM across the data axis (measured 9x FLOP blowup —
    EXPERIMENTS.md §Perf m2/m3)."""
    from repro.distributed.sharding import (
        current_rules,
        get_abstract_mesh,
        logical_to_spec,
    )
    from jax.sharding import PartitionSpec as P

    mesh = get_abstract_mesh()
    if mesh is None or not cfg.moe_shard_map:
        return _moe_local(x, p, cfg)

    rules = current_rules()
    bspec = logical_to_spec(("batch",), mesh, rules, dims=(x.shape[0],))
    batch_axes = bspec[0] if bspec else None
    mlp_spec = logical_to_spec((None, "mlp"), mesh, rules, dims=(1, cfg.moe_d_ff))
    mlp_axis = mlp_spec[1]

    def wspec(leaf_name):
        if leaf_name in ("wi", "wg"):
            return P(None, None, mlp_axis)
        if leaf_name == "wo":
            return P(None, mlp_axis, None)
        if leaf_name in ("shared_wi", "shared_wg"):
            return P(None, mlp_axis)
        if leaf_name == "shared_wo":
            return P(mlp_axis, None)
        return P(*([None] * p[leaf_name].ndim))

    p_specs = {k: wspec(k) for k in p}
    batch_axes_t = (
        batch_axes if isinstance(batch_axes, tuple) else
        ((batch_axes,) if batch_axes else ())
    )
    reduce_axes = tuple(a for a in batch_axes_t)

    def body(xl, pl):
        y, aux = _moe_local(xl, pl, cfg)
        if mlp_axis is not None:
            y = jax.lax.psum(y, mlp_axis)  # row-parallel expert wo
        if reduce_axes:
            aux = jax.lax.pmean(aux, reduce_axes)
        return y, aux

    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(batch_axes, None, None), p_specs),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(x, p)


def _moe_local(x, p, cfg: ModelConfig):
    """Shard-local MoE: x (B,S,D) with per-shard capacity."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    xt = x.reshape(T, D)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance aux (Switch): E * sum_e f_e * P_e ----
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    assign = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    ce = assign / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    C = int(max(1, round(T * K / E * cfg.capacity_factor)))
    C = min(C, T)
    flat_expert = expert_idx.reshape(-1)  # (T*K,)
    order = jnp.argsort(flat_expert)  # stable
    sorted_expert = flat_expert[order]
    # position of each routed token within its expert's slot run
    first = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")  # (E,)
    pos_in_e = jnp.arange(T * K) - first[sorted_expert]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_expert * C + pos_in_e, E * C)  # E*C = drop slot

    src_token = order // K  # original token of each routed slot
    buf = jnp.zeros((E * C, D), xt.dtype)
    buf = buf.at[dest].set(xt[src_token], mode="drop")
    xe = buf.reshape(E, C, D)
    xe = shard(xe, ("experts", None, None))

    ye = _expert_ffn(xe, p, cfg).reshape(E * C, D)

    # ---- combine ----
    gathered = ye.at[dest].get(mode="fill", fill_value=0)  # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    gates_sorted = gate_vals.reshape(-1)[order].astype(gathered.dtype)
    y = jnp.zeros((T, D), xt.dtype)
    y = y.at[src_token].add(gathered * gates_sorted[:, None])

    # ---- shared experts (qwen2-moe style fused shared expert) ----
    if cfg.shared_d_ff:
        h = jax.nn.silu(jnp.einsum("td,df->tf", xt, p["shared_wi"].astype(xt.dtype)))
        h = h * jnp.einsum("td,df->tf", xt, p["shared_wg"].astype(xt.dtype))
        sg = jax.nn.sigmoid(
            jnp.einsum("td,d->t", xt.astype(jnp.float32), p["shared_gate"].astype(jnp.float32))
        ).astype(xt.dtype)
        y = y + sg[:, None] * jnp.einsum("tf,fd->td", h, p["shared_wo"].astype(xt.dtype))

    return y.reshape(B, S, D), aux
