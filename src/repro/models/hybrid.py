"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention+MLP block
applied every `shared_attn_every` layers (weights reused, Zamba2's
parameter-sharing trick).

Layer layout for L layers, period G: the first (L // G) * G layers are
scanned as (L//G) groups of [G mamba layers + shared block]; the
remaining L %% G layers are a trailing mamba-only scan.
Each shared-block *application* gets its own KV cache at decode time
(weights are shared; state is not).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamSpec, shard
from repro.models import layers as L
from repro.models.ssm_lm import mamba_layer_body, mamba_layer_specs
from repro.models.transformer import (
    add_leading,
    attn_specs,
    embed_tokens,
    mlp_specs,
    norm_specs,
    unembed,
    _maybe_remat,
)


def _shared_block_specs(cfg: ModelConfig):
    return {
        "attn_norm": norm_specs(cfg, cfg.d_model),
        "attn": attn_specs(cfg),
        "mlp_norm": norm_specs(cfg, cfg.d_model),
        "mlp": mlp_specs(cfg, cfg.d_ff),
    }


def hybrid_groups(cfg: ModelConfig):
    g = cfg.shared_attn_every
    return cfg.num_layers // g, cfg.num_layers % g, g


def hybrid_specs(cfg: ModelConfig):
    V, D = cfg.padded_vocab, cfg.d_model
    ng, rem, g = hybrid_groups(cfg)
    ml = mamba_layer_specs(cfg)
    s = {
        "embed": ParamSpec((V, D), ("vocab", "fsdp"), init="small_normal"),
        "final_norm": norm_specs(cfg, D),
        "groups": add_leading(add_leading(ml, g, "layers"), ng, "groups"),
        "shared": _shared_block_specs(cfg),
    }
    if rem:
        s["tail"] = add_leading(ml, rem, "layers")
    if not cfg.tie_embeddings:
        s["head"] = ParamSpec((D, V), ("fsdp", "vocab"))
    return s


def _shared_block(x, sp, cfg: ModelConfig, positions):
    h = L.apply_norm(x, sp["attn_norm"], cfg)
    x = x + L.attention(h, sp["attn"], cfg, positions=positions)
    h = L.apply_norm(x, sp["mlp_norm"], cfg)
    x = x + L.mlp(h, sp["mlp"], cfg)
    return shard(x, ("batch", "seq_sp", None))


def hybrid_forward(params, cfg: ModelConfig, tokens):
    h = embed_tokens(params, cfg, tokens)
    h = shard(h, ("batch", "seq_sp", None))
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    mbody = _maybe_remat(lambda c, lp: (mamba_layer_body(c, lp, cfg), None), cfg)

    def group_body(carry, gp):
        x, _ = jax.lax.scan(mbody, carry, gp)
        x = _shared_block(x, params["shared"], cfg, positions)
        return x, None

    h, _ = jax.lax.scan(_maybe_remat(group_body, cfg), h, params["groups"])
    if "tail" in params:
        h, _ = jax.lax.scan(mbody, h, params["tail"])
    h = L.apply_norm(h, params["final_norm"], cfg)
    return unembed(params, cfg, h), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def hybrid_cache_specs(cfg: ModelConfig, batch: int, context: int):
    """Mamba states per layer + one KV cache per shared-block application.

    In the long_500k shape the shared block runs a sliding window
    (cfg.sliding_window set by the launcher) so the cache stays bounded.
    """
    ng, rem, g = hybrid_groups(cfg)
    nh, N, p = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = cfg.ssm_d_inner + 2 * N
    W = context if cfg.sliding_window is None else min(context, cfg.sliding_window)
    m, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "state": ParamSpec(
            (ng, g, batch, nh, N, p),
            ("groups", "layers", "batch", "ssm_heads", None, None),
            init="zeros",
        ),
        "conv": ParamSpec(
            (ng, g, batch, cfg.ssm_conv - 1, conv_ch),
            ("groups", "layers", "batch", None, None),
            init="zeros",
            dtype=cfg.dtype,
        ),
        "k": ParamSpec(
            (ng, batch, W, m, hd),
            ("groups", "batch", "kv_len", "kv_heads", None),
            init="zeros",
            dtype=cfg.dtype,
        ),
        "v": ParamSpec(
            (ng, batch, W, m, hd),
            ("groups", "batch", "kv_len", "kv_heads", None),
            init="zeros",
            dtype=cfg.dtype,
        ),
    }
    if rem:
        s["tail_state"] = ParamSpec(
            (rem, batch, nh, N, p),
            ("layers", "batch", "ssm_heads", None, None),
            init="zeros",
        )
        s["tail_conv"] = ParamSpec(
            (rem, batch, cfg.ssm_conv - 1, conv_ch),
            ("layers", "batch", None, None),
            init="zeros",
            dtype=cfg.dtype,
        )
    return s


def hybrid_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    from repro.models.mamba2 import mamba2_block

    h = embed_tokens(params, cfg, tokens[:, None])

    def mamba_step(carry, xs):
        lp, st, cv = xs
        hn = L.apply_norm(carry, lp["norm"], cfg)
        y, (nst, ncv) = mamba2_block(hn, lp["mamba"], cfg, state=st, conv_cache=cv, decode=True)
        return carry + y, (nst, ncv)

    def group_step(carry, xs):
        gp, st, cv, ck, cv_kv = xs
        x, (nst, ncv) = jax.lax.scan(mamba_step, carry, (gp, st, cv))
        sp = params["shared"]
        hn = L.apply_norm(x, sp["attn_norm"], cfg)
        a, nck, ncv_kv = L.decode_attention(hn, sp["attn"], cfg, ck, cv_kv, pos)
        x = x + a
        hn = L.apply_norm(x, sp["mlp_norm"], cfg)
        x = x + L.mlp(hn, sp["mlp"], cfg)
        return x, (nst, ncv, nck, ncv_kv)

    h, (ns, nc, nk, nv) = jax.lax.scan(
        group_step,
        h,
        (params["groups"], cache["state"], cache["conv"], cache["k"], cache["v"]),
    )
    new_cache = {"state": ns, "conv": nc, "k": nk, "v": nv}
    if "tail" in params:
        h, (ts, tc) = jax.lax.scan(
            mamba_step, h, (params["tail"], cache["tail_state"], cache["tail_conv"])
        )
        new_cache["tail_state"] = ts
        new_cache["tail_conv"] = tc
    h = L.apply_norm(h, params["final_norm"], cfg)
    logits = unembed(params, cfg, h)[:, 0]
    return logits, new_cache
